//! Table 8 — EB-GFN on the Ising model: mean −log RMSE between the learned
//! coupling matrix J_φ and the data-generating J = σ·A_N, across coupling
//! strengths σ (higher is better).
//!
//! Budget default: 3×3 torus (the `ising_small` artifact) over the paper's σ
//! grid; `make artifacts-paper` + GFNX_BENCH_PAPER=1 adds N = 9/10.
//!
//! Run: `cargo bench --bench table8_ising`

use gfnx::bench::harness::BenchTable;
use gfnx::coordinator::config::artifacts_dir;
use gfnx::coordinator::ebgfn::{EbGfnTrainer, SharedIsingReward};
use gfnx::data::ising_mcmc::generate_ising_dataset;
use gfnx::envs::ising::IsingEnv;
use gfnx::reward::ising::torus_adjacency;
use gfnx::runtime::Artifact;
use gfnx::util::rng::Rng;
use gfnx::util::stats::Welford;

fn run_sigma(n: usize, artifact: &str, sigma: f64, iters: u64, seeds: u64) -> (f64, f64) {
    let mut w = Welford::new();
    for seed in 0..seeds {
        let mut j_true = torus_adjacency(n);
        j_true.scale(sigma);
        let mut rng = Rng::new(seed * 31 + 7);
        let dataset = generate_ising_dataset(n, sigma, 2000, &mut rng);
        let reward = SharedIsingReward::zeros(n * n);
        let env = IsingEnv::lattice(n, reward.clone());
        let art = Artifact::load(&artifacts_dir(), artifact).expect("artifact");
        let mut trainer = EbGfnTrainer::new(&env, &art, reward, dataset, seed).unwrap();
        let mut best = f64::NEG_INFINITY;
        for _ in 0..iters {
            trainer.train_iter().unwrap();
            // Paper protocol: stop at the best J error (§B.5).
            best = best.max(trainer.neg_log_rmse(&j_true));
        }
        w.push(best);
    }
    (w.mean(), w.std())
}

fn main() {
    let iters: u64 = std::env::var("GFNX_BENCH_TRAIN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let seeds = 2u64;
    let mut table = BenchTable::new(
        "Table 8 — EB-GFN mean −log RMSE(J_φ, J) per coupling σ (higher better)",
        &["Lattice", "sigma", "-log RMSE (mean±std)"],
    );
    for sigma in [0.1, 0.2, 0.3, 0.4, 0.5, -0.1, -0.2] {
        let (mean, std) = run_sigma(3, "ising_small.tb", sigma, iters, seeds);
        table.row(&[
            "3x3".to_string(),
            format!("{sigma:+.1}"),
            format!("{mean:.2} ± {std:.2}"),
        ]);
    }
    if std::env::var("GFNX_BENCH_PAPER").is_ok() {
        for (n, art, sigmas) in [
            (9usize, "ising_n9.tb", vec![-0.1, -0.2]),
            (10, "ising_n10.tb", vec![0.1, 0.2, 0.3, 0.4, 0.5]),
        ] {
            for sigma in sigmas {
                let (mean, std) = run_sigma(n, art, sigma, iters, 1);
                table.row(&[
                    format!("{n}x{n}"),
                    format!("{sigma:+.1}"),
                    format!("{mean:.2} ± {std:.2}"),
                ]);
            }
        }
    }
    table.print();
}
