//! Table 8 — EB-GFN on the Ising model: mean −log RMSE between the learned
//! coupling matrix J_φ and the data-generating J = σ·A_N, across coupling
//! strengths σ (higher is better).
//!
//! Runs **artifact-free** on the native backend by default
//! (GFNX_BENCH_BACKEND=xla switches to the AOT graphs, which need
//! `make artifacts` + real xla-rs). Budget default: 3×3 torus over the
//! paper's σ grid; GFNX_BENCH_PAPER=1 adds N = 9/10.
//!
//! Run:   cargo bench --bench table8_ising
//! Env:   GFNX_BENCH_BACKEND      native (default) | xla
//!        GFNX_BENCH_TRAIN_ITERS  EB-GFN iterations per (σ, seed) (default 300)
//!        GFNX_BENCH_SAMPLES      MCMC dataset size (default 2000)
//!        GFNX_NATIVE_HIDDEN      MLP trunk width, native backend (default 64)
//!
//! Emits `BENCH_ebgfn.json` via the `BenchJson` harness.

use gfnx::bench::harness::{env_usize, BenchJson, BenchTable};
use gfnx::coordinator::config::artifacts_dir;
use gfnx::coordinator::ebgfn::{EbGfnTrainer, SharedIsingReward};
use gfnx::data::ising_mcmc::generate_ising_dataset;
use gfnx::envs::ising::IsingEnv;
use gfnx::reward::ising::torus_adjacency;
use gfnx::runtime::{Artifact, Backend, NativeBackend, NativeConfig};
use gfnx::util::json::Json;
use gfnx::util::rng::Rng;
use gfnx::util::stats::Welford;

struct Knobs {
    backend: String,
    iters: u64,
    samples: usize,
    hidden: usize,
}

fn knobs() -> Knobs {
    Knobs {
        backend: std::env::var("GFNX_BENCH_BACKEND").unwrap_or_else(|_| "native".to_string()),
        iters: env_usize("GFNX_BENCH_TRAIN_ITERS", 300) as u64,
        samples: env_usize("GFNX_BENCH_SAMPLES", 2000),
        hidden: env_usize("GFNX_NATIVE_HIDDEN", 64),
    }
}

/// One EB-GFN run; returns the best −log RMSE(J_φ, J_true) (paper protocol:
/// stop at the best J error, §B.5).
fn run_once<B: Backend>(
    mut trainer: EbGfnTrainer<'_, B>,
    j_true: &gfnx::util::linalg::Mat,
    iters: u64,
) -> f64 {
    let mut best = f64::NEG_INFINITY;
    for _ in 0..iters {
        trainer.train_iter().unwrap();
        best = best.max(trainer.neg_log_rmse(j_true));
    }
    best
}

fn run_sigma(n: usize, artifact: &str, sigma: f64, seeds: u64, k: &Knobs) -> (f64, f64) {
    let mut w = Welford::new();
    for seed in 0..seeds {
        let mut j_true = torus_adjacency(n);
        j_true.scale(sigma);
        let mut rng = Rng::new(seed * 31 + 7);
        let dataset = generate_ising_dataset(n, sigma, k.samples, &mut rng);
        let reward = SharedIsingReward::zeros(n * n);
        let env = IsingEnv::lattice(n, reward.clone());
        let best = match k.backend.as_str() {
            "native" => {
                let cfg = NativeConfig::for_env(&env, 16, "tb").with_hidden(k.hidden);
                let backend = NativeBackend::new(cfg, seed).unwrap();
                let trainer =
                    EbGfnTrainer::with_backend(&env, backend, reward, dataset, seed).unwrap();
                run_once(trainer, &j_true, k.iters)
            }
            "xla" => {
                let art = Artifact::load(&artifacts_dir(), artifact)
                    .expect("artifact (run `make artifacts`, or use GFNX_BENCH_BACKEND=native)");
                let trainer = EbGfnTrainer::new(&env, &art, reward, dataset, seed).unwrap();
                run_once(trainer, &j_true, k.iters)
            }
            other => panic!("GFNX_BENCH_BACKEND={other:?} (native | xla)"),
        };
        w.push(best);
    }
    (w.mean(), w.std())
}

fn main() {
    let k = knobs();
    let seeds = 2u64;
    println!(
        "EB-GFN Table 8 on the {} backend ({} iters, {} samples)",
        k.backend, k.iters, k.samples
    );
    let mut table = BenchTable::new(
        "Table 8 — EB-GFN mean −log RMSE(J_φ, J) per coupling σ (higher better)",
        &["Lattice", "sigma", "-log RMSE (mean±std)"],
    );
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for sigma in [0.1, 0.2, 0.3, 0.4, 0.5, -0.1, -0.2] {
        let (mean, std) = run_sigma(3, "ising_small.tb", sigma, seeds, &k);
        rows.push(("3x3".to_string(), sigma, mean, std));
    }
    if std::env::var("GFNX_BENCH_PAPER").is_ok() {
        for (n, art, sigmas) in [
            (9usize, "ising_n9.tb", vec![-0.1, -0.2]),
            (10, "ising_n10.tb", vec![0.1, 0.2, 0.3, 0.4, 0.5]),
        ] {
            for sigma in sigmas {
                let (mean, std) = run_sigma(n, art, sigma, 1, &k);
                rows.push((format!("{n}x{n}"), sigma, mean, std));
            }
        }
    }
    for (lattice, sigma, mean, std) in &rows {
        table.row(&[
            lattice.clone(),
            format!("{sigma:+.1}"),
            format!("{mean:.2} ± {std:.2}"),
        ]);
    }
    table.print();

    let mut bj = BenchJson::new("ebgfn");
    bj.meta("backend", Json::Str(k.backend.clone()));
    bj.meta("iters", Json::Num(k.iters as f64));
    bj.meta("samples", Json::Num(k.samples as f64));
    bj.meta("seeds", Json::Num(seeds as f64));
    for (lattice, sigma, mean, std) in &rows {
        bj.row(Json::obj(vec![
            ("lattice", Json::Str(lattice.clone())),
            ("sigma", Json::Num(*sigma)),
            ("neg_log_rmse_mean", Json::Num(*mean)),
            ("neg_log_rmse_std", Json::Num(*std)),
        ]));
    }
    match bj.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_ebgfn.json write failed: {e}"),
    }
}
