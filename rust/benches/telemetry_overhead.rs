//! telemetry_overhead — proves the telemetry hot path is near-free when
//! disabled, and quantifies its cost when enabled.
//!
//! Three measurements:
//!
//! 1. **Disabled span cost** (the claim that matters): ns per `span!` call
//!    site when telemetry is off — one `Relaxed` atomic load + branch.
//!    Measured against an identical loop without the macro.
//! 2. **Enabled span cost**: ns per `span!` when on (two `Instant::now()`
//!    calls + a few `fetch_add`s).
//! 3. **End-to-end**: native TB training it/s with telemetry off vs on,
//!    plus spans-per-iteration counted from the instrumented run's registry
//!    — giving a *predicted* disabled-mode overhead
//!    (`spans/iter x disabled-span-ns / iter-ns`), which is asserted to be
//!    under a few percent. This is the invariant CI enforces: shipping the
//!    instrumented binary costs (nearly) nothing unless `--telemetry` is on.
//! 4. **Trace sites**: ns per `trace::try_start` when tracing is off (one
//!    `Relaxed` load, asserted < 100 ns) and the amortized cost at the
//!    default 1/64 sampling rate including the sampled records' full
//!    mint-and-push path — asserted under 3% of a training iteration (one
//!    traced unit per step/request).
//!
//! Run:   cargo bench --bench telemetry_overhead
//! Env:   GFNX_TELEMETRY_PROBE   span-probe loop count (default 2_000_000)
//!        GFNX_TELEMETRY_ITERS   train iters per timed window (default 20)
//!        GFNX_BENCH_REPEATS     timed windows (default 3)
//!
//! Emits `BENCH_telemetry.json` via the `BenchJson` harness.

use gfnx::bench::harness::{env_usize, itps_json, measure_it_per_sec, BenchJson, BenchTable};
use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::{NativeBackend, NativeConfig};
use gfnx::util::json::Json;
use std::time::Instant;

/// ns/op of `body` over `n` iterations.
fn ns_per_op<F: FnMut(usize)>(n: usize, mut body: F) -> f64 {
    let t0 = Instant::now();
    for i in 0..n {
        body(i);
    }
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn trainer(env: &HypergridEnv<HypergridReward>) -> Trainer<'_, HypergridEnv<HypergridReward>, NativeBackend> {
    let cfg = NativeConfig::for_env(env, 16, "tb").with_hidden(64).with_workers(1);
    let backend = NativeBackend::new(cfg, 0).expect("native backend");
    Trainer::with_backend(env, backend, 0, EpsSchedule::none()).expect("trainer")
}

/// Total span events recorded across all histograms of the global registry.
fn total_span_events() -> u64 {
    let j = gfnx::telemetry::global().to_json();
    let Some(h) = j.get("histograms").and_then(Json::as_obj) else { return 0 };
    h.values()
        .map(|v| v.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64)
        .sum()
}

fn main() {
    let probe_n = env_usize("GFNX_TELEMETRY_PROBE", 2_000_000);
    let iters = env_usize("GFNX_TELEMETRY_ITERS", 20);
    let repeats = env_usize("GFNX_BENCH_REPEATS", 3);
    gfnx::telemetry::set_enabled(false);
    println!(
        "telemetry_overhead: {probe_n} span probes, {iters} train iters x {repeats} windows"
    );

    // 1) The disabled fast path vs an identical macro-free loop.
    let baseline_ns = ns_per_op(probe_n, |i| {
        std::hint::black_box(i);
    });
    let disabled_ns = ns_per_op(probe_n, |i| {
        let _t = gfnx::span!("overhead.probe");
        std::hint::black_box(i);
    });
    let per_span_off = (disabled_ns - baseline_ns).max(0.0);

    // 2) The enabled path at the same call-site shape.
    gfnx::telemetry::set_enabled(true);
    let enabled_ns = ns_per_op(probe_n, |i| {
        let _t = gfnx::span!("overhead.probe.on");
        std::hint::black_box(i);
    });
    gfnx::telemetry::set_enabled(false);
    let per_span_on = (enabled_ns - baseline_ns).max(0.0);
    println!(
        "  span! cost: disabled {per_span_off:.2} ns (loop {baseline_ns:.2} -> {disabled_ns:.2}), \
         enabled {per_span_on:.1} ns"
    );

    // 3) End-to-end training, telemetry off vs on.
    let env = HypergridEnv::new(2, 8, HypergridReward::standard(8));
    let mut tr = trainer(&env);
    let off = measure_it_per_sec(2, repeats, iters, || {
        let (s, _) = tr.train_iter(&ExtraSource::None).unwrap();
        assert!(s.loss.is_finite());
    });
    gfnx::telemetry::global().reset();
    gfnx::telemetry::set_enabled(true);
    let mut tr = trainer(&env);
    // The closure runs for warmup calls too; count every instrumented call.
    let mut instrumented_iters = 0usize;
    let on = measure_it_per_sec(2, repeats, iters, || {
        let (s, _) = tr.train_iter(&ExtraSource::None).unwrap();
        assert!(s.loss.is_finite());
        instrumented_iters += 1;
    });
    gfnx::telemetry::set_enabled(false);
    let spans_per_iter = total_span_events() as f64 / instrumented_iters.max(1) as f64;

    // Predicted disabled-mode overhead: every span the instrumented run
    // recorded costs `per_span_off` ns when telemetry is off.
    let iter_ns_off = 1e9 / off.mean.max(1e-12);
    let predicted_pct = 100.0 * spans_per_iter * per_span_off / iter_ns_off;
    let on_vs_off_pct = 100.0 * (1.0 - on.mean / off.mean.max(1e-12));
    println!("  train it/s: off {off}, on {on} ({on_vs_off_pct:+.1}% slower when on)");
    println!(
        "  {spans_per_iter:.0} span events/iter -> predicted disabled-mode overhead \
         {predicted_pct:.4}% of an iteration"
    );

    // The invariants this bench exists to enforce.
    assert!(
        per_span_off < 100.0,
        "disabled span! costs {per_span_off:.1} ns — the off fast path regressed"
    );
    assert!(
        predicted_pct < 3.0,
        "disabled-mode telemetry predicted to cost {predicted_pct:.2}% of an iteration \
         ({spans_per_iter:.0} spans x {per_span_off:.1} ns vs {iter_ns_off:.0} ns/iter)"
    );

    // 4) Trace call sites. Disabled: `try_start` is one Relaxed load.
    // Enabled at the default 1/64 rate: most calls add a counter fetch_add;
    // one in 64 pays the full mint + record + ring-push path (finish()
    // included, so the sampled branch is the real one, not a stub).
    use gfnx::telemetry::trace;
    trace::set_trace_rate(0.0);
    let trace_off_ns = ns_per_op(probe_n, |i| {
        std::hint::black_box(trace::try_start("overhead.trace"));
        std::hint::black_box(i);
    });
    let per_trace_off = (trace_off_ns - baseline_ns).max(0.0);
    trace::set_trace_rate(trace::DEFAULT_RATE);
    let trace_on_ns = ns_per_op(probe_n, |i| {
        if let Some(tr) = trace::try_start("overhead.trace") {
            tr.finish(true);
        }
        std::hint::black_box(i);
    });
    trace::set_trace_rate(0.0);
    let per_trace_on = (trace_on_ns - baseline_ns).max(0.0);
    // One traced unit (request / engine step) per iteration: the amortized
    // enabled cost as a fraction of the measured iteration.
    let trace_enabled_pct = 100.0 * per_trace_on / iter_ns_off;
    println!(
        "  trace site: disabled {per_trace_off:.2} ns, enabled@default {per_trace_on:.1} ns \
         -> {trace_enabled_pct:.4}% of an iteration"
    );
    assert!(
        per_trace_off < 100.0,
        "disabled trace::try_start costs {per_trace_off:.1} ns — the off fast path regressed"
    );
    assert!(
        trace_enabled_pct < 3.0,
        "tracing at the default rate predicted to cost {trace_enabled_pct:.2}% of an iteration \
         ({per_trace_on:.1} ns vs {iter_ns_off:.0} ns/iter)"
    );

    let mut table = BenchTable::new(
        "telemetry_overhead — span cost and end-to-end impact",
        &["Metric", "Value"],
    );
    table.row_strs(&["span! disabled (ns/call)", &format!("{per_span_off:.2}")]);
    table.row_strs(&["span! enabled (ns/call)", &format!("{per_span_on:.1}")]);
    table.row_strs(&["train it/s (telemetry off)", &format!("{off}")]);
    table.row_strs(&["train it/s (telemetry on)", &format!("{on}")]);
    table.row_strs(&["span events / iteration", &format!("{spans_per_iter:.0}")]);
    table.row_strs(&["predicted overhead when off", &format!("{predicted_pct:.4}%")]);
    table.row_strs(&["trace site disabled (ns/call)", &format!("{per_trace_off:.2}")]);
    table.row_strs(&["trace site enabled@1/64 (ns/call)", &format!("{per_trace_on:.1}")]);
    table.row_strs(&["trace overhead at default rate", &format!("{trace_enabled_pct:.4}%")]);
    table.print();

    let mut bj = BenchJson::new("telemetry");
    bj.meta("probe_n", Json::Num(probe_n as f64));
    bj.meta("iters", Json::Num(iters as f64));
    bj.meta("repeats", Json::Num(repeats as f64));
    bj.row(Json::obj(vec![
        ("span_disabled_ns", Json::Num(per_span_off)),
        ("span_enabled_ns", Json::Num(per_span_on)),
        ("it_per_sec_off", itps_json(&off)),
        ("it_per_sec_on", itps_json(&on)),
        ("spans_per_iter", Json::Num(spans_per_iter)),
        ("predicted_overhead_pct_off", Json::Num(predicted_pct)),
        ("trace_disabled_ns", Json::Num(per_trace_off)),
        ("trace_enabled_ns", Json::Num(per_trace_on)),
        ("trace_overhead_pct", Json::Num(trace_enabled_pct)),
        ("telemetry", gfnx::telemetry::global().phases_json()),
    ]));
    match bj.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_telemetry.json write failed: {e}"),
    }
}
