//! Figure 7 — Jensen–Shannon divergence between the learned distribution
//! over DAGs and the exact enumerated posterior, versus wall-clock, MDB
//! objective with the BGe score (paper §B.4).
//!
//! Run: `cargo bench --bench fig7_bayesnet_jsd`

use gfnx::bench::harness::BenchTable;
use gfnx::coordinator::buffer::TerminalCounter;
use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::data::ancestral::ancestral_sample;
use gfnx::data::erdos_renyi::sample_er_dag;
use gfnx::envs::bayesnet::{BayesNetEnv, BayesNetState};
use gfnx::metrics::dag_enum::{dag_index, enumerate_dags, exact_posterior};
use gfnx::metrics::jsd::jsd_from_counts;
use gfnx::metrics::marginals::{
    edge_marginals, marginal_correlation, markov_blanket_marginals, path_marginals,
};
use gfnx::reward::bge::{bge_table, BgeParams};
use gfnx::runtime::Artifact;
use gfnx::util::rng::Rng;
use std::time::Instant;

fn main() {
    let iters: u64 = std::env::var("GFNX_BENCH_TRAIN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    let d = 5usize;
    // Paper protocol: 20 ER datasets; budget default benches 2 seeds (set
    // GFNX_BENCH_SEEDS=20 for the paper's count).
    let seeds: u64 = std::env::var("GFNX_BENCH_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);

    let dags = enumerate_dags(d);
    let mut table = BenchTable::new(
        "Figure 7 — JSD(learned ‖ exact posterior) vs wall-clock, MDB + BGe",
        &["Seed", "t (s)", "iters", "JSD", "edge-corr", "path-corr", "mb-corr"],
    );

    for seed in 0..seeds {
        let mut rng = Rng::new(seed);
        let g = sample_er_dag(d, 1.0, &mut rng);
        let data = ancestral_sample(&g, 100, 0.1, &mut rng);
        let table_scores = bge_table(&data, BgeParams::default_for(d));
        let posterior = exact_posterior(&dags, &table_scores);
        let env = BayesNetEnv::new(d, table_scores.clone());
        let art = Artifact::load(&artifacts_dir(), "bayesnet_d5.mdb").expect("artifact");
        let rc = run_config("bayesnet_d5", "mdb");
        let mut trainer = Trainer::new(&env, &art, seed, rc.explore).unwrap();
        let mut counter = TerminalCounter::new(dags.len(), rc.fifo_window);
        let t0 = Instant::now();
        let tref = &table_scores;
        let extra = ExtraSource::StateLogReward(&move |s: &BayesNetState, i: usize| {
            tref.log_score(s.adj[i])
        });
        for i in 0..=iters {
            let (_s, objs) = trainer.train_iter(&extra).unwrap();
            for o in &objs {
                if let Some(idx) = dag_index(&dags, *o) {
                    counter.push(idx);
                }
            }
            if i % (iters / 5).max(1) == 0 {
                let jsd = jsd_from_counts(&posterior, counter.counts());
                let total: u64 = counter.counts().iter().sum();
                let emp: Vec<f64> =
                    counter.counts().iter().map(|&c| c as f64 / total.max(1) as f64).collect();
                let corr = |f: fn(&[u64], &[f64], usize) -> Vec<f64>| {
                    marginal_correlation(&f(&dags, &posterior, d), &f(&dags, &emp, d), d)
                };
                table.row(&[
                    seed.to_string(),
                    format!("{:.1}", t0.elapsed().as_secs_f64()),
                    i.to_string(),
                    format!("{jsd:.4}"),
                    format!("{:.3}", corr(edge_marginals)),
                    format!("{:.3}", corr(path_marginals)),
                    format!("{:.3}", corr(markov_blanket_marginals)),
                ]);
            }
        }
    }
    table.print();
}
