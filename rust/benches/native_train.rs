//! native_train — end-to-end training throughput (iterations/second) of the
//! pure-Rust [`NativeBackend`]: one iteration = on-policy rollout + fused
//! loss/grad/Adam step, the paper's Table 1 unit of work — with **no AOT
//! artifacts and no XLA**.
//!
//! Measures TB on hypergrid and bitseq at batch 16 and 256 (the paper's
//! small/large batch regimes), plus the host-synchronized
//! [`BaselineTrainer`] at batch 16 — the per-sample-dispatch +
//! per-call-parameter-upload comparator of Tables 1–2 — so the it/s ratio
//! is measurable without artifacts. The registry table adds one
//! transformer-policy row (seq_small, per-family preset) next to its MLP
//! twin, so the model-layer cost is visible in the same document.
//!
//! Run:   cargo bench --bench native_train
//! Env:   GFNX_NATIVE_HIDDEN    MLP trunk width (default 128)
//!        GFNX_NATIVE_WORKERS   dispatch worker threads (default: all cores)
//!        GFNX_NATIVE_ITERS     iters per timed window at batch 16
//!                              (default 10; batch-256 runs use max(it/4, 2),
//!                              baseline runs max(it/8, 1))
//!        GFNX_BENCH_REPEATS    timed windows (default 3)
//!
//! Emits `BENCH_native.json` via the `BenchJson` harness.

use gfnx::bench::harness::{
    env_usize, itps_json, measure_it_per_sec, telemetry_phases, BenchJson, BenchTable,
};
use gfnx::coordinator::baseline::BaselineTrainer;
use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::registry::{self, EnvDriver, EnvFamily, EnvParams};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::bitseq::{bitseq_env, BitSeqConfig};
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::{NativeBackend, NativeConfig};
use gfnx::util::json::Json;
use gfnx::util::stats::ItPerSec;
use gfnx::util::threadpool::default_workers;

#[allow(clippy::too_many_arguments)]
fn bench_env<E: VecEnv>(
    env: &E,
    label: &str,
    mode: &str, // "fast" | "baseline"
    batch: usize,
    hidden: usize,
    workers: usize,
    iters: usize,
    repeats: usize,
) -> ItPerSec {
    let cfg = NativeConfig::for_env(env, batch, "tb")
        .with_hidden(hidden)
        .with_workers(workers);
    let backend = NativeBackend::new(cfg, 0).expect("native backend");
    let r = match mode {
        "fast" => {
            let mut trainer =
                Trainer::with_backend(env, backend, 0, EpsSchedule::none()).expect("trainer");
            measure_it_per_sec(1, repeats, iters, || {
                let (stats, _objs) = trainer.train_iter(&ExtraSource::None).unwrap();
                assert!(stats.loss.is_finite(), "{label}: loss diverged");
            })
        }
        "baseline" => {
            let mut trainer = BaselineTrainer::with_backend(env, backend, 0, EpsSchedule::none())
                .expect("baseline trainer");
            measure_it_per_sec(1, repeats, iters, || {
                let (stats, _objs) = trainer.train_iter(&ExtraSource::None).unwrap();
                assert!(stats.loss.is_finite(), "{label}: baseline loss diverged");
            })
        }
        other => panic!("mode {other:?}"),
    };
    println!("  {label:<24} {mode:<8} batch {batch:>3}: {r}");
    r
}

/// Registry-driven bench row: build `config` through the env registry and
/// time `loss` training iterations (extras — phylo's energies, bayesnet's
/// log-scores — are supplied by the registry, so fldb/mdb run for real).
struct RegistryBench {
    loss: &'static str,
    /// "mlp" | "transformer" (transformer uses the registry's per-family
    /// preset — token-grid envs only).
    model: &'static str,
    batch: usize,
    hidden: usize,
    workers: usize,
    iters: usize,
    repeats: usize,
}

impl EnvDriver for RegistryBench {
    type Out = ItPerSec;

    fn drive<E>(
        self,
        env: &E,
        extra: &ExtraSource<'_, E>,
        fam: &'static EnvFamily,
        config: &str,
    ) -> anyhow::Result<ItPerSec>
    where
        E: VecEnv,
        E::State: Clone,
        E::Obj: PartialEq + std::fmt::Debug,
    {
        let mut cfg = NativeConfig::for_env(env, self.batch, self.loss)
            .with_hidden(self.hidden)
            .with_workers(self.workers);
        if self.model == "transformer" {
            let arch = registry::transformer_arch(fam, &env.spec())?;
            cfg = cfg.with_model(gfnx::runtime::ModelSpec::Transformer(arch));
        }
        let backend = NativeBackend::new(cfg, 0)?;
        let mut trainer = Trainer::with_backend(env, backend, 0, EpsSchedule::none())?;
        let r = measure_it_per_sec(1, self.repeats, self.iters, || {
            let (stats, _objs) = trainer.train_iter(extra).unwrap();
            assert!(stats.loss.is_finite(), "{config}: loss diverged");
        });
        println!(
            "  {config:<24} {:<8} {:<12} batch {:>3}: {r}",
            self.loss, self.model, self.batch
        );
        Ok(r)
    }
}

fn main() {
    let hidden = env_usize("GFNX_NATIVE_HIDDEN", 128);
    let workers = env_usize("GFNX_NATIVE_WORKERS", default_workers());
    let iters16 = env_usize("GFNX_NATIVE_ITERS", 10);
    let iters256 = (iters16 / 4).max(2);
    let iters_base = (iters16 / 8).max(1);
    let repeats = env_usize("GFNX_BENCH_REPEATS", 3);
    println!(
        "native TB training throughput (hidden {hidden}, {workers} workers, \
         {repeats} windows)"
    );

    let hg = HypergridEnv::new(2, 8, HypergridReward::standard(8));
    let (bs, _modes) = bitseq_env(BitSeqConfig::small());

    let rows: Vec<(&str, &str, usize, ItPerSec)> = vec![
        ("hypergrid_small", "fast", 16,
         bench_env(&hg, "hypergrid_small", "fast", 16, hidden, workers, iters16, repeats)),
        ("hypergrid_small", "fast", 256,
         bench_env(&hg, "hypergrid_small", "fast", 256, hidden, workers, iters256, repeats)),
        ("hypergrid_small", "baseline", 16,
         bench_env(&hg, "hypergrid_small", "baseline", 16, hidden, workers, iters_base, repeats)),
        ("bitseq_small", "fast", 16,
         bench_env(&bs, "bitseq_small", "fast", 16, hidden, workers, iters16, repeats)),
        ("bitseq_small", "fast", 256,
         bench_env(&bs, "bitseq_small", "fast", 256, hidden, workers, iters256, repeats)),
        ("bitseq_small", "baseline", 16,
         bench_env(&bs, "bitseq_small", "baseline", 16, hidden, workers, iters_base, repeats)),
    ];
    // Tables 1–2 ratio, artifact-free: fast vs baseline at the same batch.
    let speedup = |env_name: &str| -> Option<f64> {
        let fast = rows.iter().find(|r| r.0 == env_name && r.1 == "fast" && r.2 == 16)?;
        let base = rows.iter().find(|r| r.0 == env_name && r.1 == "baseline")?;
        Some(fast.3.mean / base.3.mean)
    };

    let mut table = BenchTable::new(
        "native_train — TB training it/s, pure-Rust backend (no artifacts)",
        &["Env", "Mode", "Batch", "it/s", "Speedup vs baseline"],
    );
    for (env, mode, batch, r) in &rows {
        let sp = if *mode == "fast" && *batch == 16 {
            speedup(env).map(|s| format!("{s:.1}x")).unwrap_or_default()
        } else {
            String::new()
        };
        table.row(&[env.to_string(), mode.to_string(), batch.to_string(), r.to_string(), sp]);
    }
    table.print();

    // Registry rows: one per newly CLI-trainable family (tb everywhere,
    // plus the extras-dependent objectives on their home envs).
    println!("registry envs (native backend, batch 16):");
    let reg_rows: Vec<(&str, &str, &str, ItPerSec)> = [
        ("seq_small", "tb", "mlp"),
        ("seq_small", "tb", "transformer"),
        ("tfbind8", "tb", "mlp"),
        ("qm9", "tb", "mlp"),
        ("amp_small", "tb", "mlp"),
        ("phylo_small", "fldb", "mlp"),
        ("bayesnet_d5", "mdb", "mlp"),
    ]
    .into_iter()
    .map(|(config, loss, model)| {
        let bench = RegistryBench {
            loss,
            model,
            batch: 16,
            hidden,
            workers,
            iters: iters16,
            repeats,
        };
        let r = registry::with_env(config, EnvParams::default(), bench)
            .unwrap_or_else(|e| panic!("{config}.{loss} ({model}): {e}"));
        (config, loss, model, r)
    })
    .collect();
    let mut reg_table = BenchTable::new(
        "native_train — registry envs (one row per newly-trainable family)",
        &["Config", "Loss", "Model", "Batch", "it/s"],
    );
    for (config, loss, model, r) in &reg_rows {
        reg_table.row(&[
            config.to_string(),
            loss.to_string(),
            model.to_string(),
            "16".to_string(),
            r.to_string(),
        ]);
    }
    reg_table.print();

    // Phase-timing breakdown: one short *instrumented* pass, run after all
    // timed windows so the it/s numbers above stay uninstrumented-mode.
    // Attached to the hypergrid fast/16 row as a `telemetry` sub-object.
    let phases = telemetry_phases(|| {
        let cfg = NativeConfig::for_env(&hg, 16, "tb")
            .with_hidden(hidden)
            .with_workers(workers);
        let backend = NativeBackend::new(cfg, 0).expect("native backend");
        let mut tr =
            Trainer::with_backend(&hg, backend, 0, EpsSchedule::none()).expect("trainer");
        for _ in 0..iters16 {
            let (stats, _objs) = tr.train_iter(&ExtraSource::None).unwrap();
            assert!(stats.loss.is_finite());
        }
    });

    let mut bj = BenchJson::new("native");
    bj.meta("backend", Json::Str("native".to_string()));
    bj.meta("loss", Json::Str("tb".to_string()));
    bj.meta("hidden", Json::Num(hidden as f64));
    bj.meta("workers", Json::Num(workers as f64));
    bj.meta("repeats", Json::Num(repeats as f64));
    for (env, mode, batch, r) in &rows {
        let mut fields = vec![
            ("env", Json::Str(env.to_string())),
            ("mode", Json::Str(mode.to_string())),
            ("batch", Json::Num(*batch as f64)),
            ("it_per_sec", itps_json(r)),
        ];
        if *env == "hypergrid_small" && *mode == "fast" && *batch == 16 {
            fields.push(("telemetry", phases.clone()));
        }
        bj.row(Json::obj(fields));
    }
    for (config, loss, model, r) in &reg_rows {
        bj.row(Json::obj(vec![
            ("env", Json::Str(config.to_string())),
            ("mode", Json::Str(format!("registry:{loss}"))),
            ("model", Json::Str(model.to_string())),
            ("batch", Json::Num(16.0)),
            ("it_per_sec", itps_json(r)),
        ]));
    }
    for env_name in ["hypergrid_small", "bitseq_small"] {
        if let Some(s) = speedup(env_name) {
            bj.meta(&format!("speedup_{env_name}"), Json::Num(s));
        }
    }
    match bj.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_native.json write failed: {e}"),
    }
}
