//! Ablations — decomposing *why* the compiled/vectorized path wins
//! (the mechanism behind Tables 1–2), plus coordinator design choices:
//!
//!  A. policy-call granularity: one vectorized call per env step vs one
//!     padded call per sample per step (the baseline's dispatch pattern);
//!  B. parameter transfer: device-cached parameter buffers vs re-upload
//!     before every call (host-synchronized pattern);
//!  C. rollout staging: reused obs/mask buffers vs fresh allocation.
//!
//! Run: `cargo bench --bench ablations`

use gfnx::bench::harness::{measure_it_per_sec, BenchTable};
use gfnx::coordinator::config::artifacts_dir;
use gfnx::coordinator::rollout::RolloutCtx;
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::Artifact;

fn main() {
    let env = HypergridEnv::new(4, 20, HypergridReward::standard(20));
    let art = Artifact::load(&artifacts_dir(), "hypergrid_4d_20.tb").expect("artifact");
    let mut state = art.init_state().unwrap();
    let spec = env.spec();
    let b = art.batch();
    let ctx = RolloutCtx::for_artifact(&art);
    let obs = ctx.obs.clone();
    let mut fwd_mask = ctx.fwd_mask.clone();
    let mut bwd_mask = ctx.bwd_mask.clone();
    for i in 0..b {
        fwd_mask[i * spec.n_actions] = 1.0;
        bwd_mask[i * spec.n_bwd_actions] = 1.0;
    }

    let mut table = BenchTable::new(
        "Ablations — mechanism decomposition (policy calls/second)",
        &["Variant", "calls/s", "slowdown vs fast"],
    );

    // A: vectorized, cached params (the fast path).
    let fast = measure_it_per_sec(5, 3, 50, || {
        state.policy(&art, &obs, &fwd_mask, &bwd_mask).unwrap();
    });

    // B: re-upload parameters before every call.
    let reupload = measure_it_per_sec(3, 3, 30, || {
        state.refresh_param_bufs().unwrap();
        state.policy(&art, &obs, &fwd_mask, &bwd_mask).unwrap();
    });

    // C: per-sample dispatch — b calls each covering one row (padded), as a
    // host-side per-sample training loop would issue.
    let per_sample = measure_it_per_sec(1, 3, 4, || {
        for _row in 0..b {
            state.policy(&art, &obs, &fwd_mask, &bwd_mask).unwrap();
        }
    });

    // D: per-sample dispatch + per-call re-upload (the full baseline).
    let per_sample_reupload = measure_it_per_sec(1, 3, 2, || {
        for _row in 0..b {
            state.refresh_param_bufs().unwrap();
            state.policy(&art, &obs, &fwd_mask, &bwd_mask).unwrap();
        }
    });

    table.row(&[
        "vectorized + cached params".into(),
        format!("{:.1}", fast.mean),
        "1.0x".into(),
    ]);
    table.row(&[
        "vectorized + re-upload".into(),
        format!("{:.1}", reupload.mean),
        format!("{:.1}x", fast.mean / reupload.mean),
    ]);
    table.row(&[
        format!("per-sample x{b} + cached"),
        format!("{:.1}", per_sample.mean),
        format!("{:.1}x", fast.mean / per_sample.mean),
    ]);
    table.row(&[
        format!("per-sample x{b} + re-upload"),
        format!("{:.1}", per_sample_reupload.mean),
        format!("{:.1}x", fast.mean / per_sample_reupload.mean),
    ]);
    table.print();
}
