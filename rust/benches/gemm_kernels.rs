//! GEMM micro-benchmark: the tiled/panel-packed kernels vs the pre-tiling
//! scalar kernels, at serve-typical shapes.
//!
//! The comparator is a faithful vendored copy of the pre-PR hot path:
//! per-row scalar loops with f64 accumulation, re-converting the weight
//! matrix row-by-row, dispatched on spawn-per-call scoped threads with the
//! spawn-calibrated 2¹⁸ work quantum, and concatenating per-block `Vec`s.
//! Measuring against the vendored copy (same binary, same toolchain) keeps
//! the before/after honest without needing two checkouts.
//!
//! Grid: batch ∈ {16, 64, 256} × hidden (`GFNX_GEMM_HIDDEN`, default 256)
//! × mode ∈ {scalar, det, fast} × workers ∈ {1, default}. Emits
//! `BENCH_gemm.json` with GFLOP/s per cell plus `speedup_vs_scalar` /
//! `speedup_fast_vs_det` meta fields, and (unless
//! `GFNX_GEMM_MIN_SPEEDUP=0`) asserts the acceptance bar: deterministic
//! tiled ≥ 2× scalar at batch 256, fast strictly faster than deterministic.
//!
//! Knobs: `GFNX_GEMM_ITERS` (calls per timed window, default 10),
//! `GFNX_BENCH_REPEATS` (windows, default 5), `GFNX_GEMM_HIDDEN`,
//! `GFNX_GEMM_MIN_SPEEDUP` (default 2.0).

use gfnx::bench::harness::{env_usize, itps_json, measure_it_per_sec, BenchJson, BenchTable};
use gfnx::runtime::native::gemm::dense_rows_mode;
use gfnx::util::json::Json;
use gfnx::util::rng::Rng;
use gfnx::util::threadpool::default_workers;

// --- vendored pre-PR scalar path -------------------------------------------

const OLD_PAR_FLOP_QUANTUM: usize = 1 << 18;

fn old_effective_workers(workers: usize, rows: usize, flops: usize) -> usize {
    (flops / OLD_PAR_FLOP_QUANTUM).max(1).min(workers.max(1)).min(rows.max(1))
}

/// The pre-pool `parallel_map`: scoped spawn/join on every call.
fn spawn_parallel_map<T: Send, F: Fn(usize) -> T + Sync>(
    n: usize,
    workers: usize,
    f: F,
) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = slots.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index is claimed exactly once.
                unsafe { (slots_ptr as *mut Option<T>).add(i).write(Some(v)) };
            });
        }
    });
    slots.into_iter().map(|v| v.unwrap()).collect()
}

/// The pre-tiling `dense_rows`: per-row scalar loops, f64 accumulation,
/// per-block output `Vec`s concatenated at the end.
#[allow(clippy::too_many_arguments)]
fn scalar_dense_rows(
    x: &[f32],
    n: usize,
    k: usize,
    w: &[f32],
    bias: &[f32],
    m: usize,
    relu: bool,
    workers: usize,
) -> Vec<f32> {
    let workers = old_effective_workers(workers, n, n * k * m);
    let rows_per = ((n + workers - 1) / workers).max(1);
    let n_chunks = (n + rows_per - 1) / rows_per;
    let blocks = spawn_parallel_map(n_chunks, workers, |c| {
        let lo = c * rows_per;
        let hi = ((c + 1) * rows_per).min(n);
        let mut out = vec![0f32; (hi - lo) * m];
        let mut acc = vec![0f64; m];
        for r in lo..hi {
            for (j, a) in acc.iter_mut().enumerate() {
                *a = bias[j] as f64;
            }
            let xrow = &x[r * k..(r + 1) * k];
            for (t, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let xv = xv as f64;
                let wrow = &w[t * m..(t + 1) * m];
                for j in 0..m {
                    acc[j] += xv * wrow[j] as f64;
                }
            }
            let orow = &mut out[(r - lo) * m..(r - lo + 1) * m];
            for j in 0..m {
                let v = acc[j];
                orow[j] = if relu && v < 0.0 { 0.0 } else { v as f32 };
            }
        }
        out
    });
    let mut out = Vec::with_capacity(n * m);
    for b in blocks {
        out.extend_from_slice(&b);
    }
    out
}

// ---------------------------------------------------------------------------

fn envf(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Cell {
    batch: usize,
    mode: &'static str,
    workers: usize,
    gflops: f64,
    itps: gfnx::util::stats::ItPerSec,
}

fn main() {
    let hidden = env_usize("GFNX_GEMM_HIDDEN", 256);
    let iters = env_usize("GFNX_GEMM_ITERS", 10);
    let repeats = env_usize("GFNX_BENCH_REPEATS", 5);
    let min_speedup = envf("GFNX_GEMM_MIN_SPEEDUP", 2.0);
    let (k, m) = (hidden, hidden);
    let batches = [16usize, 64, 256];
    let worker_grid = [1usize, default_workers()];

    let mut rng = Rng::new(7);
    let mut x = vec![0f32; *batches.iter().max().unwrap() * k];
    let mut w = vec![0f32; k * m];
    let mut b = vec![0f32; m];
    rng.fill_normal_f32(&mut x, 1.0);
    rng.fill_normal_f32(&mut w, 1.0);
    rng.fill_normal_f32(&mut b, 1.0);

    let mut cells: Vec<Cell> = Vec::new();
    let mut table = BenchTable::new(
        &format!("Forward GEMM [n, {k}] × [{k}, {m}] (dense_rows)"),
        &["batch", "mode", "workers", "GFLOP/s", "calls/s"],
    );

    for &n in &batches {
        let flops = (2 * n * k * m) as f64;
        for &workers in &worker_grid {
            for mode in ["scalar", "det", "fast"] {
                let xs = &x[..n * k];
                let r = measure_it_per_sec(2, repeats, iters, || {
                    let out = match mode {
                        "scalar" => scalar_dense_rows(xs, n, k, &w, &b, m, true, workers),
                        "det" => dense_rows_mode(xs, n, k, &w, &b, m, true, workers, false),
                        _ => dense_rows_mode(xs, n, k, &w, &b, m, true, workers, true),
                    };
                    std::hint::black_box(&out);
                });
                let gflops = r.mean * flops / 1e9;
                table.row(&[
                    n.to_string(),
                    mode.to_string(),
                    workers.to_string(),
                    format!("{gflops:.2}"),
                    format!("{:.1}±{:.1}", r.mean, r.sem3),
                ]);
                cells.push(Cell { batch: n, mode, workers, gflops, itps: r });
            }
        }
    }
    table.print();

    let pick = |batch: usize, mode: &str, workers: usize| -> f64 {
        cells
            .iter()
            .find(|c| c.batch == batch && c.mode == mode && c.workers == workers)
            .map(|c| c.gflops)
            .unwrap_or(0.0)
    };
    let wmax = default_workers();
    let speedup = pick(256, "det", wmax) / pick(256, "scalar", wmax).max(1e-12);
    let fast_speedup = pick(256, "fast", wmax) / pick(256, "det", wmax).max(1e-12);
    println!("det vs scalar speedup at batch 256 / hidden {hidden}: {speedup:.2}x");
    println!("fast vs det speedup at batch 256 / hidden {hidden}: {fast_speedup:.2}x");

    let mut bj = BenchJson::new("gemm");
    bj.meta("hidden", Json::Num(hidden as f64));
    bj.meta("iters", Json::Num(iters as f64));
    bj.meta("repeats", Json::Num(repeats as f64));
    bj.meta("default_workers", Json::Num(wmax as f64));
    bj.meta("speedup_vs_scalar", Json::Num(speedup));
    bj.meta("speedup_fast_vs_det", Json::Num(fast_speedup));
    for c in &cells {
        bj.row(Json::obj(vec![
            ("kernel", Json::Str("dense_rows".into())),
            ("n", Json::Num(c.batch as f64)),
            ("k", Json::Num(k as f64)),
            ("m", Json::Num(m as f64)),
            ("mode", Json::Str(c.mode.into())),
            ("workers", Json::Num(c.workers as f64)),
            ("gflops", Json::Num(c.gflops)),
            ("calls_per_sec", itps_json(&c.itps)),
        ]));
    }
    match bj.write() {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_gemm.json write failed: {e}"),
    }

    // Acceptance bar (ISSUE 7): ≥2× deterministic dispatch throughput vs
    // the pre-PR scalar path at batch 256 / hidden 256, fast strictly
    // faster still. GFNX_GEMM_MIN_SPEEDUP=0 disables the gate (e.g. for
    // exploratory runs on loaded machines).
    if min_speedup > 0.0 {
        assert!(
            speedup >= min_speedup,
            "tiled deterministic GEMM speedup {speedup:.2}x below the \
             {min_speedup:.2}x bar at batch 256 / hidden {hidden}"
        );
        assert!(
            fast_speedup > 1.0,
            "fastmath mode ({fast_speedup:.2}x vs det) must beat deterministic mode"
        );
    }
}
