//! Figure 6 — Pearson correlation between terminating-state log-probability
//! and log-reward on sampled trees, versus wall-clock, FLDB objective, for
//! the scaled DS-style phylogenetic datasets.
//!
//! The default artifact set covers `phylo_small` (6 species). If the
//! paper-scale artifacts (phylo_ds1…) were built via `make artifacts-paper`,
//! they are benchmarked too.
//!
//! Run: `cargo bench --bench fig6_phylo`

use gfnx::bench::harness::BenchTable;
use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::eval::reward_correlation;
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::data::phylo_data::{ds_config, ds_reward_c, synthetic_alignment};
use gfnx::envs::phylo::PhyloEnv;
use gfnx::runtime::Artifact;
use gfnx::util::rng::Rng;
use std::time::Instant;

fn bench_dataset(table: &mut BenchTable, label: &str, env: &PhyloEnv, artifact: &str, iters: u64) {
    let art = match Artifact::load(&artifacts_dir(), artifact) {
        Ok(a) => a,
        Err(_) => {
            table.row(&[
                label.to_string(),
                "—".to_string(),
                "—".to_string(),
                "(artifact not built)".to_string(),
            ]);
            return;
        }
    };
    let rc = run_config(artifact.split_once('.').unwrap().0, "fldb");
    let mut trainer = Trainer::new(env, &art, 0, rc.explore).unwrap();
    let t0 = Instant::now();
    for i in 0..=iters {
        let env_ref = trainer.env;
        let extra = ExtraSource::Energy(&move |s, idx| env_ref.energy(s, idx));
        trainer.train_iter(&extra).unwrap();
        if i % (iters / 5).max(1) == 0 {
            // Eval protocol: correlation on 32 trees sampled from the policy
            // (paper §B.3), scored with the MC backward estimator.
            let mut trees = Vec::new();
            while trees.len() < 32 {
                trees.extend(trainer.sample_objs().unwrap());
            }
            trees.truncate(32);
            trees.dedup();
            let corr = reward_correlation(
                env,
                &trainer.backend,
                &mut trainer.ctx,
                &mut trainer.rng,
                &trees,
                4,
            )
            .unwrap();
            table.row(&[
                label.to_string(),
                format!("{:.1}", t0.elapsed().as_secs_f64()),
                i.to_string(),
                format!("{corr:+.3}"),
            ]);
        }
    }
}

fn main() {
    let iters: u64 = std::env::var("GFNX_BENCH_TRAIN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let mut table = BenchTable::new(
        "Figure 6 — Pearson(log P̂_θ, log R) vs wall-clock, phylogenetics (FLDB)",
        &["Dataset", "t (s)", "iters", "corr"],
    );
    {
        let mut rng = Rng::new(7);
        let aln = synthetic_alignment(6, 8, 0.15, &mut rng);
        let env = PhyloEnv::new(aln, 16.0, 4.0);
        bench_dataset(&mut table, "small (6 sp)", &env, "phylo_small.fldb", iters);
    }
    // Paper-scale DS1–DS8 analogues, if built.
    for ds in 1..=8usize {
        let (n, m) = ds_config(ds);
        let mut rng = Rng::new(100 + ds as u64);
        let aln = synthetic_alignment(n, m, 0.15, &mut rng);
        let env = PhyloEnv::new(aln, ds_reward_c(ds), 4.0);
        bench_dataset(
            &mut table,
            &format!("DS-{ds} ({n} sp)"),
            &env,
            &format!("phylo_ds{ds}.fldb"),
            iters / 2,
        );
    }
    table.print();
}
