//! serve_qps — sampling throughput (objects/second) of the continuous-
//! batching serve engine vs the padded `forward_rollout` baseline, on a
//! mixed-length workload: hypergrid with t_max ≫ typical trajectory length,
//! so a padded batch spends most of its dispatches dragging finished rows
//! along while the slowest trajectory drains.
//!
//! Both paths share the same host-side [`UniformPolicy`] with an identical
//! synthetic fixed-shape dispatch cost (the cost of one dispatch does not
//! depend on how many rows are live — the defining property of an
//! accelerator dispatch), so the measured ratio isolates the *scheduling*
//! effect: slot refill vs padding. No AOT artifacts required. A final
//! section times the native transformer policy on seq_small with its
//! per-slot KV cache on vs off (bitwise-equal outputs, O(T) vs O(T²)
//! attention per decode step).
//!
//! Run:   cargo bench --bench serve_qps
//! Env:   GFNX_SERVE_B        slot-table width / batch (default 64)
//!        GFNX_SERVE_H        hypergrid side (default 48 → t_max 95)
//!        GFNX_SERVE_OBJS     objects per timed window (default 4096)
//!        GFNX_SERVE_SYNTH    synthetic dispatch-work rounds (default 8)
//!        GFNX_SERVE_POLICY   dispatch backend: uniform (synthetic cost) or
//!                            native (real MLP dispatch; default uniform)
//!        GFNX_BENCH_REPEATS  timed windows (default 5)
//!
//! Emits `BENCH_serve.json` (see `bench::harness::BenchJson`).

use gfnx::bench::harness::{itps_json, measure_items_per_sec, BenchJson, BenchTable};
use gfnx::coordinator::registry::{self, EnvDriver, EnvFamily, EnvParams};
use gfnx::coordinator::rollout::{forward_rollout_with_policy, ExtraSource, RolloutCtx};
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::policy::{BatchPolicy, PolicyShape, UniformPolicy};
use gfnx::runtime::{ModelSpec, NativeBackend, NativeConfig, NativePolicy};
use gfnx::serve::{sample_stream, traj_seed, SampleRequest, SamplerService, TrajJob};
use gfnx::util::json::Json;
use gfnx::util::rng::Rng;
use gfnx::util::stats::ItPerSec;

/// Seq-env transformer decode row: the KV-cached incremental path (O(T)
/// attention per step, per-slot caches keyed by committed prefixes) vs full
/// re-encode (O(T²) per step), same weights, same per-trajectory seeds.
/// Outputs are bitwise-equal by construction (the runtime's KV-equivalence
/// test asserts it); this measures what the equality costs/saves.
struct TransformerDecode {
    b: usize,
    objs: usize,
    repeats: usize,
}

impl EnvDriver for TransformerDecode {
    type Out = (ItPerSec, ItPerSec);

    fn drive<E>(
        self,
        env: &E,
        _extra: &ExtraSource<'_, E>,
        fam: &'static EnvFamily,
        _config: &str,
    ) -> anyhow::Result<(ItPerSec, ItPerSec)>
    where
        E: VecEnv,
        E::State: Clone,
        E::Obj: PartialEq + std::fmt::Debug,
    {
        let arch = registry::transformer_arch(fam, &env.spec())?;
        let base = NativeBackend::new(
            NativeConfig::for_env(env, self.b, "tb").with_model(ModelSpec::Transformer(arch)),
            0,
        )?
        .to_policy();
        let mut run = |mut policy: NativePolicy| {
            let mut window = 0u64;
            measure_items_per_sec(1, self.repeats, || {
                let seed_base = 77_000 * window;
                window += 1;
                let mut next = 0usize;
                let mut produced = 0usize;
                sample_stream(
                    env,
                    &mut policy,
                    || {
                        if next < self.objs {
                            let j = TrajJob {
                                request: 0,
                                traj_index: next,
                                seed: traj_seed(seed_base, next as u64),
                                temperature: 1.0,
                            };
                            next += 1;
                            Some(j)
                        } else {
                            None
                        }
                    },
                    |_r| produced += 1,
                )
                .unwrap();
                produced
            })
        };
        let kv = run(base.clone());
        let full = run(base.with_kv_cache(false));
        Ok((kv, full))
    }
}

fn envv(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env(h: usize) -> HypergridEnv<HypergridReward> {
    HypergridEnv::new(2, h, HypergridReward::standard(h))
}

/// Dispatch-policy factory for the selected backend.
///
/// `uniform` (the default) burns a synthetic cost that is strictly a
/// function of the batch *shape* — the cleanest isolation of the
/// scheduling effect, and what the acceptance bar is stated against.
/// `native` dispatches the real MLP; its cost is *mostly* shape-fixed, but
/// dead-slot rows are staged with zeroed observations and the dense
/// kernels skip zero input columns, so padding rows run cheaper than live
/// ones — treat native-mode speedups as an end-to-end measurement, not a
/// pure scheduling comparison.
fn make_policy(
    e: &HypergridEnv<HypergridReward>,
    shape: PolicyShape,
    backend: &str,
    synth: usize,
) -> Box<dyn BatchPolicy> {
    match backend {
        "native" => {
            let cfg = NativeConfig::for_env(e, shape.batch, "tb").with_hidden(64);
            let policy = NativeBackend::new(cfg, 0)
                .expect("native backend")
                .to_policy()
                .with_fastmath(gfnx::runtime::fastmath_from_env());
            Box::new(policy)
        }
        _ => Box::new(UniformPolicy::with_work(shape, synth)),
    }
}

fn main() {
    let b = envv("GFNX_SERVE_B", 64);
    let h = envv("GFNX_SERVE_H", 48);
    let objs_per_window = envv("GFNX_SERVE_OBJS", 4096);
    let synth = envv("GFNX_SERVE_SYNTH", 8);
    let repeats = envv("GFNX_BENCH_REPEATS", 5);
    let backend = std::env::var("GFNX_SERVE_POLICY").unwrap_or_else(|_| "uniform".to_string());
    if !matches!(backend.as_str(), "uniform" | "native") {
        eprintln!("error: GFNX_SERVE_POLICY={backend:?} (expected uniform | native)");
        std::process::exit(2);
    }

    let e = env(h);
    let spec = e.spec();
    let shape = PolicyShape::of_env(&e, b);
    println!(
        "workload: hypergrid 2d side={h} (t_max={}), B={b}, {} objs/window, synth={synth}, policy={backend}",
        spec.t_max, objs_per_window
    );

    // --- Padded baseline: forward_rollout, B objects per drain. ----------
    let mut padded_dispatch_note = 0u64;
    let padded = {
        let mut policy = make_policy(&e, shape, &backend, synth);
        let mut ctx = RolloutCtx::for_shape(&shape);
        let mut rng = Rng::new(1);
        measure_items_per_sec(1, repeats, || {
            let mut produced = 0usize;
            while produced < objs_per_window {
                let (batch, objs) = forward_rollout_with_policy(
                    &e,
                    policy.as_mut(),
                    &mut ctx,
                    &mut rng,
                    0.0,
                    &ExtraSource::None,
                )
                .unwrap();
                // Dispatches in a padded drain = the slowest row's length.
                padded_dispatch_note += batch.length.iter().copied().max().unwrap_or(0) as u64;
                produced += objs.len();
            }
            produced
        })
    };

    // --- Continuous batching: same thread, same policy economics. --------
    let mut refill_stats = gfnx::serve::StreamStats::default();
    let refill = {
        let mut policy = make_policy(&e, shape, &backend, synth);
        let mut window = 0u64;
        measure_items_per_sec(1, repeats, || {
            let seed_base = 10_000 * window;
            window += 1;
            let mut next = 0usize;
            let mut produced = 0usize;
            let stats = sample_stream(
                &e,
                policy.as_mut(),
                || {
                    if next < objs_per_window {
                        let j = TrajJob {
                            request: 0,
                            traj_index: next,
                            seed: gfnx::serve::traj_seed(seed_base, next as u64),
                            temperature: 1.0,
                        };
                        next += 1;
                        Some(j)
                    } else {
                        None
                    }
                },
                |_r| produced += 1,
            )
            .unwrap();
            refill_stats.merge(&stats);
            produced
        })
    };

    // --- Full service (worker thread + queue + tickets). ------------------
    let service = {
        let backend_name = backend.clone();
        let svc: SamplerService<Vec<i32>> = SamplerService::spawn(env(h), move || {
            let e = env(h);
            Ok(make_policy(&e, shape, &backend_name, synth))
        });
        let n_requests = 8;
        let per_request = objs_per_window / n_requests;
        let mut window = 0u64;
        let r = measure_items_per_sec(1, repeats, || {
            window += 1;
            let tickets: Vec<_> = (0..n_requests)
                .map(|k| {
                    svc.submit(SampleRequest {
                        n_samples: per_request,
                        seed: window * 1000 + k as u64,
                    })
                })
                .collect();
            tickets.into_iter().map(|t| t.wait().unwrap().len()).sum()
        });
        let snap = svc.stats();
        svc.shutdown();
        (r, snap)
    };

    // --- Transformer decode: per-slot KV cache on vs off (seq env). ------
    let objs_tf = (objs_per_window / 16).max(64);
    let (tf_kv, tf_full) = registry::with_env(
        "seq_small",
        EnvParams::default(),
        TransformerDecode { b, objs: objs_tf, repeats },
    )
    .expect("seq_small transformer decode");
    let kv_speedup = tf_kv.mean / tf_full.mean;

    let speedup = refill.mean / padded.mean;
    let occupancy = refill_stats.occupancy();

    let mut table = BenchTable::new(
        "serve_qps — objects/second, padded rollout vs continuous batching",
        &["Mode", "objs/s", "Occupancy", "Speedup"],
    );
    table.row(&[
        "padded forward_rollout".to_string(),
        padded.to_string(),
        "—".to_string(),
        "1.0x".to_string(),
    ]);
    table.row(&[
        "slot-refill engine".to_string(),
        refill.to_string(),
        format!("{:.1}%", 100.0 * occupancy),
        format!("{speedup:.2}x"),
    ]);
    table.row(&[
        "service (thread+queue)".to_string(),
        service.0.to_string(),
        format!("{:.1}%", 100.0 * service.1.occupancy()),
        format!("{:.2}x", service.0.mean / padded.mean),
    ]);
    table.print();

    let mut tf_table = BenchTable::new(
        "serve_qps — transformer decode on seq_small (same weights, same seeds, \
         bitwise-equal outputs)",
        &["Mode", "objs/s", "Speedup"],
    );
    tf_table.row(&[
        "full re-encode (O(T²)/step)".to_string(),
        tf_full.to_string(),
        "1.0x".to_string(),
    ]);
    tf_table.row(&[
        "KV-cached decode (O(T)/step)".to_string(),
        tf_kv.to_string(),
        format!("{kv_speedup:.2}x"),
    ]);
    tf_table.print();

    let mut bj = BenchJson::new("serve");
    bj.meta("policy_backend", Json::Str(backend.clone()));
    bj.meta("env", Json::Str(format!("hypergrid_2d_{h}")));
    bj.meta("t_max", Json::Num(spec.t_max as f64));
    bj.meta("batch", Json::Num(b as f64));
    bj.meta("objs_per_window", Json::Num(objs_per_window as f64));
    bj.meta("synth_work", Json::Num(synth as f64));
    bj.meta("repeats", Json::Num(repeats as f64));
    bj.meta("padded_dispatches_total", Json::Num(padded_dispatch_note as f64));
    bj.meta("refill_dispatches_total", Json::Num(refill_stats.dispatches as f64));
    bj.row(row_json("padded_forward_rollout", &padded, None, 1.0));
    bj.row(row_json("slot_refill_engine", &refill, Some(occupancy), speedup));
    bj.row(row_json(
        "sampler_service",
        &service.0,
        Some(service.1.occupancy()),
        service.0.mean / padded.mean,
    ));
    bj.meta("transformer_env", Json::Str("seq_small".to_string()));
    bj.meta("transformer_objs_per_window", Json::Num(objs_tf as f64));
    bj.row(Json::obj(vec![
        ("mode", Json::Str("seq_transformer_full_reencode".to_string())),
        ("objs_per_sec", itps_json(&tf_full)),
        ("speedup_vs_full_reencode", Json::Num(1.0)),
    ]));
    bj.row(Json::obj(vec![
        ("mode", Json::Str("seq_transformer_kv_decode".to_string())),
        ("objs_per_sec", itps_json(&tf_kv)),
        ("speedup_vs_full_reencode", Json::Num(kv_speedup)),
    ]));
    match bj.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_serve.json write failed: {e}"),
    }

    println!(
        "\ncontinuous batching speedup over padded rollout: {speedup:.2}x \
         (target ≥ 1.3x; slot occupancy {:.1}%)",
        100.0 * occupancy
    );
    println!(
        "transformer KV-cached decode speedup over full re-encode: {kv_speedup:.2}x"
    );
    if speedup < 1.3 {
        eprintln!("WARNING: speedup below the 1.3x acceptance bar");
    }
}

fn row_json(mode: &str, qps: &ItPerSec, occupancy: Option<f64>, speedup: f64) -> Json {
    let mut fields = vec![
        ("mode", Json::Str(mode.to_string())),
        ("objs_per_sec", itps_json(qps)),
        ("speedup_vs_padded", Json::Num(speedup)),
    ];
    fields.push((
        "occupancy",
        occupancy.map(Json::Num).unwrap_or(Json::Null),
    ));
    Json::obj(fields)
}
