//! Table 2 — it/s on the small (2-d, H=20) and large (8-d, H=10) hypergrids
//! for DB / TB / SubTB, baseline vs gfnx-rs.
//!
//! Run: `cargo bench --bench table2_hypergrid`

use gfnx::bench::harness::{measure_it_per_sec, BenchTable};
use gfnx::coordinator::baseline::BaselineTrainer;
use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::Artifact;

fn main() {
    let repeats = 3;
    let iters = 8;
    let mut table = BenchTable::new(
        "Table 2 — hypergrid it/s (small 20², large 10⁸ grids)",
        &["Grid", "Objective", "Baseline", "gfnx-rs", "Speedup"],
    );
    for (grid, d, h, prefix) in [
        ("2-d, H=20", 2usize, 20usize, "hypergrid_2d_20"),
        ("8-d, H=10", 8, 10, "hypergrid_8d_10"),
    ] {
        let env = HypergridEnv::new(d, h, HypergridReward::standard(h));
        for obj in ["db", "tb", "subtb"] {
            let name = format!("{prefix}.{obj}");
            let art = Artifact::load(&artifacts_dir(), &name)
                .expect("artifact (run `make artifacts`)");
            let rc = run_config(prefix, obj);
            let mut fast_tr = Trainer::new(&env, &art, 0, rc.explore).unwrap();
            let fast = measure_it_per_sec(2, repeats, iters, || {
                fast_tr.train_iter(&ExtraSource::None).unwrap();
            });
            let mut base_tr = BaselineTrainer::new(&env, &art, 0, rc.explore).unwrap();
            let base = measure_it_per_sec(1, 2, 2, || {
                base_tr.train_iter(&ExtraSource::None).unwrap();
            });
            table.row(&[
                grid.to_string(),
                obj.to_uppercase(),
                base.to_string(),
                fast.to_string(),
                format!("{:.1}x", fast.mean / base.mean),
            ]);
        }
    }
    table.print();
}
