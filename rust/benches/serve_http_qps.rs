//! serve_http_qps — end-to-end HTTP sampling throughput (requests/second)
//! of the network front end: many concurrent keep-alive clients posting
//! mixed-size `/sample` requests over real TCP sockets, multiplexed onto
//! one slot-refill [`SamplerService`].
//!
//! Two workload rows:
//!   - `hypergrid_mlp` — the native MLP policy on hypergrid_small (mixed
//!     trajectory lengths from the grid walk),
//!   - `seq_transformer_kv` — the native transformer on seq_small with its
//!     per-slot KV cache on (the serving configuration).
//!
//! Every measured request crosses the full stack: HTTP parse → admission
//! (bounded queue) → per-client fairness lane → slot-refill drain →
//! JSON response. The queue capacity is set well above the in-flight
//! request count so the bench measures throughput, not shedding; the
//! `serve.shed` counter is exported as meta and expected to be 0.
//!
//! Run:   cargo bench --bench serve_http_qps
//! Env:   GFNX_HTTP_CLIENTS   concurrent connections (default 8)
//!        GFNX_HTTP_REQS      requests per client per window (default 12)
//!        GFNX_HTTP_B         service slot-table width (default 32)
//!        GFNX_BENCH_REPEATS  timed windows (default 3)
//!
//! Emits `BENCH_http.json` (see `bench::harness::BenchJson`).

use gfnx::bench::harness::{itps_json, measure_items_per_sec, BenchJson, BenchTable};
use gfnx::coordinator::registry::{self, EnvDriver, EnvFamily, EnvParams};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::envs::VecEnv;
use gfnx::runtime::{BatchPolicy, ModelSpec, NativeBackend, NativeConfig};
use gfnx::serve::conn::HttpClient;
use gfnx::serve::{HttpServer, HttpServerConfig, SamplerService, ServeIdentity, ServeSnapshot};
use gfnx::telemetry::Registry;
use gfnx::util::json::Json;
use gfnx::util::stats::ItPerSec;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One HTTP throughput row: stand up the full server stack for this env,
/// hammer it with concurrent keep-alive clients, tear it down.
struct HttpWorkload {
    transformer: bool,
    b: usize,
    clients: usize,
    reqs_per_client: usize,
    repeats: usize,
}

/// Request sizes cycled across clients/requests — small pings mixed with
/// batch pulls, so the worker's round-robin interleaving is exercised.
const REQUEST_NS: [usize; 4] = [1, 4, 16, 48];

impl EnvDriver for HttpWorkload {
    type Out = (ItPerSec, u64, ServeSnapshot);

    fn drive<E>(
        self,
        env: &E,
        _extra: &ExtraSource<'_, E>,
        fam: &'static EnvFamily,
        config: &str,
    ) -> anyhow::Result<(ItPerSec, u64, ServeSnapshot)>
    where
        E: VecEnv + Clone + Send + Sync + 'static,
        E::State: Clone,
        E::Obj: PartialEq + std::fmt::Debug + Send + 'static + gfnx::serve::ObjJson,
    {
        let mut cfg = NativeConfig::for_env(env, self.b, "tb").with_hidden(64);
        if self.transformer {
            let arch = registry::transformer_arch(fam, &env.spec())?;
            cfg = cfg.with_model(ModelSpec::Transformer(arch));
        }
        let policy = NativeBackend::new(cfg, 0)?
            .to_policy()
            .with_fastmath(gfnx::runtime::fastmath_from_env())
            .with_kv_cache(true);
        let factory = move || Ok(Box::new(policy) as Box<dyn BatchPolicy>);
        let svc = Arc::new(SamplerService::spawn_with(
            env.clone(),
            factory,
            Arc::new(Registry::new()),
            Some(4096),
        ));
        let identity = ServeIdentity {
            family: fam.name.to_string(),
            config: config.to_string(),
            model: if self.transformer { "transformer" } else { "mlp" }.to_string(),
        };
        let http = HttpServer::serve(
            "127.0.0.1:0",
            Arc::clone(&svc),
            identity,
            HttpServerConfig::default(),
        )?;
        let addr = http.local_addr().to_string();

        let total_objs = Arc::new(AtomicU64::new(0));
        let mut window = 0u64;
        let qps = measure_items_per_sec(1, self.repeats, || {
            window += 1;
            let handles: Vec<_> = (0..self.clients)
                .map(|c| {
                    let addr = addr.clone();
                    let objs = Arc::clone(&total_objs);
                    let reqs = self.reqs_per_client;
                    let w = window;
                    std::thread::spawn(move || {
                        let mut client = HttpClient::connect(&addr).expect("connect");
                        let mut done = 0usize;
                        for r in 0..reqs {
                            let n = REQUEST_NS[(c + r) % REQUEST_NS.len()];
                            let seed = w * 1_000_000 + (c as u64) * 1000 + r as u64;
                            let body = format!("{{\"n\": {n}, \"seed\": {seed}}}");
                            let (status, resp) =
                                client.post_json("/sample", &body).expect("request");
                            assert_eq!(
                                status,
                                200,
                                "sample failed: {}",
                                String::from_utf8_lossy(&resp)
                            );
                            objs.fetch_add(n as u64, Ordering::Relaxed);
                            done += 1;
                        }
                        done
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("client thread")).sum()
        });

        http.shutdown();
        let snap = svc.stats();
        drop(svc);
        Ok((qps, total_objs.load(Ordering::Relaxed), snap))
    }
}

fn envv(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let clients = envv("GFNX_HTTP_CLIENTS", 8);
    let reqs = envv("GFNX_HTTP_REQS", 12);
    let b = envv("GFNX_HTTP_B", 32);
    let repeats = envv("GFNX_BENCH_REPEATS", 3);
    println!(
        "workload: {clients} concurrent connections x {reqs} keep-alive requests/window, \
         n cycled over {REQUEST_NS:?}, slot width {b}"
    );

    let rows = [
        ("hypergrid_mlp", "hypergrid_small", false),
        ("seq_transformer_kv", "seq_small", true),
    ];
    let mut table = BenchTable::new(
        "serve_http_qps — HTTP requests/second through the full network stack",
        &["Workload", "reqs/s", "objs served", "Occupancy"],
    );
    let mut bj = BenchJson::new("http");
    bj.meta("clients", Json::Num(clients as f64));
    bj.meta("reqs_per_client", Json::Num(reqs as f64));
    bj.meta("batch", Json::Num(b as f64));
    bj.meta("repeats", Json::Num(repeats as f64));
    bj.meta(
        "request_ns",
        Json::Arr(REQUEST_NS.iter().map(|&n| Json::Num(n as f64)).collect()),
    );

    for (label, config, transformer) in rows {
        let (qps, objs, snap) = registry::with_env(
            config,
            EnvParams::default(),
            HttpWorkload { transformer, b, clients, reqs_per_client: reqs, repeats },
        )
        .expect(config);
        assert_eq!(snap.shed, 0, "throughput bench should not shed");
        table.row(&[
            label.to_string(),
            qps.to_string(),
            objs.to_string(),
            format!("{:.1}%", 100.0 * snap.occupancy()),
        ]);
        bj.row(Json::obj(vec![
            ("workload", Json::Str(label.to_string())),
            ("config", Json::Str(config.to_string())),
            ("requests_per_sec", itps_json(&qps)),
            ("objects_served", Json::Num(objs as f64)),
            ("occupancy", Json::Num(snap.occupancy())),
            ("shed", Json::Num(snap.shed as f64)),
            ("requests_completed", Json::Num(snap.requests_completed as f64)),
        ]));
        println!("{label}: {qps} reqs/s ({objs} objects)");
    }
    table.print();

    match bj.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_http.json write failed: {e}"),
    }
}
