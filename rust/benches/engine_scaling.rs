//! engine_scaling — rollout throughput of the asynchronous actor–learner
//! engine vs actor count, plus the serial `Trainer` comparator.
//!
//! One unit of work = one trajectory batch consumed by the learner (a
//! fused train step's worth of rollouts), so `batches/s × B` is
//! trajectories (rollouts) per second. The workload is deliberately
//! **rollout-heavy** (long hypergrid_2d_20 trajectories, narrow trunk,
//! single-threaded dispatch matmuls): rollouts cost `t_max` sequential
//! dispatches + env stepping + RNG per batch while the fused step is one
//! pass, so actor threads — not the learner — are the bottleneck the
//! engine parallelizes away.
//!
//! Run:   cargo bench --bench engine_scaling
//! Env:   GFNX_ENGINE_ITERS     learner steps per timed run (default 240)
//!        GFNX_ENGINE_HIDDEN    MLP trunk width (default 16)
//!        GFNX_ENGINE_BATCH     batch width B (default 16)
//!        GFNX_ENGINE_PUBLISH   publish every K steps (default 4)
//!        GFNX_BENCH_REPEATS    timed runs per row (default 3)
//!
//! Emits `BENCH_engine.json` (workspace root by default) via `BenchJson`.

use gfnx::bench::harness::{env_usize, itps_json, telemetry_phases, BenchJson, BenchTable};
use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::engine::{self, EngineConfig, EngineStats};
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::{NativeBackend, NativeConfig};
use gfnx::util::json::Json;
use gfnx::util::stats::ItPerSec;

struct Work {
    iters: u64,
    hidden: usize,
    batch: usize,
    publish: u64,
    repeats: usize,
}

fn bench_env() -> HypergridEnv<HypergridReward> {
    HypergridEnv::new(2, 20, HypergridReward::standard(20))
}

fn backend(w: &Work, env: &HypergridEnv<HypergridReward>) -> NativeBackend {
    // workers = 1: the engine's parallelism is actor threads, not matmul
    // row blocks — nested pools would fight over the cores.
    let cfg = NativeConfig::for_env(env, w.batch, "tb")
        .with_hidden(w.hidden)
        .with_workers(1);
    NativeBackend::new(cfg, 0).expect("native backend")
}

/// One engine run; returns its stats (timing included).
fn engine_run(w: &Work, actors: usize, iters: u64) -> EngineStats {
    let env = bench_env();
    let mut be = backend(w, &env);
    let mut cfg = EngineConfig::new(actors, w.publish, 0);
    cfg.queue_depth = 2 * actors;
    engine::train(
        &env,
        &mut be,
        EpsSchedule::none(),
        &ExtraSource::None,
        &cfg,
        iters,
        |_| Ok(()),
    )
    .expect("engine run")
}

/// Serial-`Trainer` comparator: same backend, same batch count, one thread.
fn serial_run(w: &Work, iters: u64) -> f64 {
    let env = bench_env();
    let be = backend(w, &env);
    let mut tr = Trainer::with_backend(&env, be, 0, EpsSchedule::none()).expect("trainer");
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let (s, _) = tr.train_iter(&ExtraSource::None).unwrap();
        assert!(s.loss.is_finite());
    }
    iters as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let w = Work {
        iters: env_usize("GFNX_ENGINE_ITERS", 240) as u64,
        hidden: env_usize("GFNX_ENGINE_HIDDEN", 16),
        batch: env_usize("GFNX_ENGINE_BATCH", 16),
        publish: env_usize("GFNX_ENGINE_PUBLISH", 4) as u64,
        repeats: env_usize("GFNX_BENCH_REPEATS", 3),
    };
    println!(
        "engine_scaling: hypergrid_2d_20 / tb, hidden {}, batch {}, publish every {}, \
         {} steps x {} runs",
        w.hidden, w.batch, w.publish, w.iters, w.repeats
    );

    let actor_counts = [1usize, 2, 4];
    let warmup = (w.iters / 4).max(20);

    // Serial comparator.
    serial_run(&w, warmup);
    let serial_samples: Vec<f64> = (0..w.repeats).map(|_| serial_run(&w, w.iters)).collect();
    let serial = ItPerSec::from_samples(&serial_samples);
    println!("  serial trainer          : {serial} batches/s");

    struct Row {
        actors: usize,
        rate: ItPerSec,
        staleness_mean: f64,
        staleness_max: u64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for &actors in &actor_counts {
        engine_run(&w, actors, warmup);
        let mut samples = Vec::with_capacity(w.repeats);
        let mut last: Option<EngineStats> = None;
        for _ in 0..w.repeats {
            let stats = engine_run(&w, actors, w.iters);
            samples.push(stats.batches_per_sec());
            last = Some(stats);
        }
        let stats = last.unwrap();
        let rate = ItPerSec::from_samples(&samples);
        println!(
            "  engine {actors} actor(s)       : {rate} batches/s \
             (staleness mean {:.2}, max {})",
            stats.mean_staleness(),
            stats.max_staleness()
        );
        rows.push(Row {
            actors,
            rate,
            staleness_mean: stats.mean_staleness(),
            staleness_max: stats.max_staleness(),
        });
    }

    let base = rows[0].rate.mean;
    let speedup_4v1 = rows.last().map(|r| r.rate.mean / base.max(1e-12)).unwrap_or(0.0);

    let mut table = BenchTable::new(
        "engine_scaling — actor-learner rollout throughput (hypergrid_2d_20 / tb)",
        &["Mode", "Actors", "Batches/s", "Trajectories/s", "Speedup vs 1 actor", "Staleness (mean/max)"],
    );
    table.row(&[
        "serial".to_string(),
        "-".to_string(),
        format!("{serial}"),
        format!("{:.1}", serial.mean * w.batch as f64),
        String::new(),
        "-".to_string(),
    ]);
    for r in &rows {
        table.row(&[
            "engine".to_string(),
            r.actors.to_string(),
            format!("{}", r.rate),
            format!("{:.1}", r.rate.mean * w.batch as f64),
            format!("{:.2}x", r.rate.mean / base.max(1e-12)),
            format!("{:.2}/{}", r.staleness_mean, r.staleness_max),
        ]);
    }
    table.print();
    println!("4-actor vs 1-actor rollout throughput: {speedup_4v1:.2}x");

    // Phase-timing breakdowns: short *instrumented* passes run after every
    // timed window, so the throughput numbers above stay uninstrumented.
    // Attached as `telemetry` sub-objects to the serial row and the
    // largest-actor engine row.
    let tel_iters = (w.iters / 4).max(20);
    let serial_phases = telemetry_phases(|| {
        serial_run(&w, tel_iters);
    });
    let max_actors = *actor_counts.last().unwrap();
    let engine_phases = telemetry_phases(|| {
        engine_run(&w, max_actors, tel_iters);
    });

    let mut bj = BenchJson::new("engine");
    bj.meta("env", Json::Str("hypergrid_2d_20".to_string()));
    bj.meta("loss", Json::Str("tb".to_string()));
    bj.meta("hidden", Json::Num(w.hidden as f64));
    bj.meta("batch", Json::Num(w.batch as f64));
    bj.meta("iters", Json::Num(w.iters as f64));
    bj.meta("publish_every", Json::Num(w.publish as f64));
    bj.meta("repeats", Json::Num(w.repeats as f64));
    bj.meta("speedup_4v1", Json::Num(speedup_4v1));
    bj.row(Json::obj(vec![
        ("mode", Json::Str("serial".to_string())),
        ("actors", Json::Num(0.0)),
        ("batches_per_sec", itps_json(&serial)),
        ("rollouts_per_sec", Json::Num(serial.mean * w.batch as f64)),
        ("telemetry", serial_phases),
    ]));
    for r in &rows {
        let mut fields = vec![
            ("mode", Json::Str("engine".to_string())),
            ("actors", Json::Num(r.actors as f64)),
            ("batches_per_sec", itps_json(&r.rate)),
            ("rollouts_per_sec", Json::Num(r.rate.mean * w.batch as f64)),
            ("staleness_mean", Json::Num(r.staleness_mean)),
            ("staleness_max", Json::Num(r.staleness_max as f64)),
        ];
        if r.actors == max_actors {
            fields.push(("telemetry", engine_phases.clone()));
        }
        bj.row(Json::obj(fields));
    }
    match bj.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_engine.json write failed: {e}"),
    }
}
