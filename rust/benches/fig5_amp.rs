//! Figure 5 — top-100 mean reward and diversity (mean pairwise edit
//! distance) versus wall-clock on the AMP environment, TB objective.
//!
//! Run: `cargo bench --bench fig5_amp`

use gfnx::bench::harness::BenchTable;
use gfnx::coordinator::config::artifacts_dir;
use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::amp::amp_env_sized;
use gfnx::envs::VecEnv;
use gfnx::metrics::diversity::TopK;
use gfnx::runtime::Artifact;
use std::time::Instant;

fn main() {
    let iters: u64 = std::env::var("GFNX_BENCH_TRAIN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(800);
    let env = amp_env_sized(0, 1e-3, 8);
    let art = Artifact::load(&artifacts_dir(), "amp_small.tb").expect("artifact");
    let mut trainer = Trainer::new(&env, &art, 0, EpsSchedule::Constant(1e-2)).unwrap();
    let mut topk = TopK::new(100);

    let mut table = BenchTable::new(
        "Figure 5 — AMP top-100 reward & diversity vs wall-clock (TB)",
        &["t (s)", "iters", "top-100 mean R", "diversity"],
    );
    let t0 = Instant::now();
    for i in 0..=iters {
        let (_s, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
        for o in &objs {
            topk.push(env.log_reward_obj(o).exp(), o);
        }
        if i % (iters / 8).max(1) == 0 {
            table.row(&[
                format!("{:.1}", t0.elapsed().as_secs_f64()),
                i.to_string(),
                format!("{:.4}", topk.mean_reward()),
                format!("{:.2}", topk.diversity()),
            ]);
        }
    }
    table.print();
}
