//! Figure 3 — Pearson correlation between log R(x) and the Monte-Carlo
//! log P̂_θ(x) on the flip test set, versus wall-clock, for TB and DB on
//! the bit-sequence environment.
//!
//! Run: `cargo bench --bench fig3_bitseq_corr`

use gfnx::bench::harness::BenchTable;
use gfnx::coordinator::config::artifacts_dir;
use gfnx::coordinator::eval::reward_correlation;
use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::data::modes::generate_test_set;
use gfnx::envs::bitseq::{bitseq_env, test_set_tokens, BitSeqConfig};
use gfnx::runtime::Artifact;
use gfnx::util::rng::Rng;
use std::time::Instant;

fn main() {
    let iters: u64 = std::env::var("GFNX_BENCH_TRAIN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(900);
    let cfg = BitSeqConfig::small();
    let (env, modes) = bitseq_env(cfg);
    let mut rng = Rng::new(42);
    let test = test_set_tokens(cfg, &generate_test_set(&modes, &mut rng));
    // Budget: subsample the paper's |M|·n test set.
    let test: Vec<_> = test.into_iter().step_by(4).collect();

    let mut table = BenchTable::new(
        "Figure 3 — Pearson(log R, log P̂_θ) vs wall-clock, bitseq",
        &["Objective", "t (s)", "iters", "corr"],
    );
    for obj in ["tb", "db"] {
        let art = Artifact::load(&artifacts_dir(), &format!("bitseq_small.{obj}"))
            .expect("artifact (run `make artifacts`)");
        let mut trainer = Trainer::new(&env, &art, 0, EpsSchedule::Constant(1e-3)).unwrap();
        let t0 = Instant::now();
        for i in 0..=iters {
            trainer.train_iter(&ExtraSource::None).unwrap();
            if i % (iters / 6).max(1) == 0 {
                let corr = reward_correlation(
                    &env,
                    &trainer.backend,
                    &mut trainer.ctx,
                    &mut trainer.rng,
                    &test,
                    6,
                )
                .unwrap();
                table.row(&[
                    obj.to_uppercase(),
                    format!("{:.1}", t0.elapsed().as_secs_f64()),
                    i.to_string(),
                    format!("{corr:+.3}"),
                ]);
            }
        }
    }
    table.print();
}
