//! Figure 4 — TV between the exact target (enumerable: 4⁸ DNA sequences,
//! 11⁵ molecules) and the empirical sampling distribution versus wall-clock,
//! TB objective, with the perfect-sampler floor.
//!
//! Run: `cargo bench --bench fig4_tfbind_qm9`

use gfnx::bench::harness::BenchTable;
use gfnx::coordinator::buffer::TerminalCounter;
use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::VecEnv;
use gfnx::metrics::tv::{perfect_sampler_tv, tv_from_counts};
use gfnx::runtime::Artifact;
use gfnx::util::rng::Rng;
use std::time::Instant;

fn run_env<E, F>(
    table: &mut BenchTable,
    label: &str,
    env: &E,
    exact: &[f64],
    flat: F,
    artifact: &str,
    iters: u64,
) where
    E: VecEnv,
    F: Fn(&E::Obj) -> usize,
{
    let art = Artifact::load(&artifacts_dir(), artifact).expect("artifact");
    let (cfg_name, loss) = artifact.split_once('.').unwrap();
    let rc = run_config(cfg_name, loss);
    let mut trainer = Trainer::new(env, &art, 0, rc.explore).unwrap();
    let window = 24_000usize;
    let mut counter = TerminalCounter::new(exact.len(), window);
    let t0 = Instant::now();
    for i in 0..=iters {
        let (_s, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
        for o in &objs {
            counter.push(flat(o));
        }
        if i % (iters / 6).max(1) == 0 {
            table.row(&[
                label.to_string(),
                format!("{:.1}", t0.elapsed().as_secs_f64()),
                i.to_string(),
                format!("{:.4}", tv_from_counts(exact, counter.counts())),
            ]);
        }
    }
    let mut rng = Rng::new(1);
    table.row(&[
        format!("{label} perfect sampler"),
        "—".to_string(),
        "—".to_string(),
        format!("{:.4}", perfect_sampler_tv(exact, window, &mut rng)),
    ]);
}

fn main() {
    let iters: u64 = std::env::var("GFNX_BENCH_TRAIN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1200);
    let mut table = BenchTable::new(
        "Figure 4 — TV vs wall-clock (TB): TFBind8 and QM9",
        &["Env", "t (s)", "iters", "TV"],
    );
    {
        use gfnx::envs::tfbind8::{exact_target, tfbind8_env};
        use gfnx::reward::proxy::TfBindReward;
        let env = tfbind8_env(0, 10.0);
        let exact = exact_target(&env);
        run_env(
            &mut table,
            "TFBind8",
            &env,
            &exact,
            |o: &Vec<i16>| TfBindReward::flatten(o),
            "tfbind8.tb",
            iters,
        );
    }
    {
        use gfnx::envs::qm9::{exact_target, flatten, qm9_env};
        let env = qm9_env(0, 10.0);
        let exact = exact_target(&env);
        run_env(
            &mut table,
            "QM9",
            &env,
            &exact,
            |o: &Vec<i16>| flatten(o),
            "qm9.tb",
            iters,
        );
    }
    table.print();
}
