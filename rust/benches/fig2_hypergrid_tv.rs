//! Figure 2 — total variation between the exact target distribution and the
//! empirical distribution of sampled terminals, versus wall-clock seconds,
//! for DB / TB / SubTB on the 4-d H=20 hypergrid, with the perfect-sampler
//! floor.
//!
//! Run: `cargo bench --bench fig2_hypergrid_tv`
//! Env: GFNX_BENCH_TRAIN_ITERS overrides the per-objective budget.

use gfnx::bench::harness::BenchTable;
use gfnx::coordinator::buffer::TerminalCounter;
use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::metrics::tv::{perfect_sampler_tv, tv_from_counts};
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::Artifact;
use gfnx::util::rng::Rng;
use gfnx::util::stats::softmax_from_logs;
use std::time::Instant;

fn main() {
    let iters: u64 = std::env::var("GFNX_BENCH_TRAIN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1500);
    let env = HypergridEnv::new(4, 20, HypergridReward::standard(20));
    let n_states = env.num_terminal_states();
    let exact = softmax_from_logs(
        &(0..n_states)
            .map(|i| env.log_reward_obj(&env.unflatten(i)))
            .collect::<Vec<_>>(),
    );

    // Perfect-sampler floor at the same sample budget the FIFO holds.
    let window = 24_000usize.min((iters as usize) * 16);
    let mut rng = Rng::new(0);
    let floor = perfect_sampler_tv(&exact, window, &mut rng);

    let mut table = BenchTable::new(
        "Figure 2 — TV vs wall-clock, hypergrid 4d·20 (floor = perfect sampler)",
        &["Objective", "t (s)", "iters", "TV"],
    );
    for obj in ["db", "tb", "subtb"] {
        let art = Artifact::load(&artifacts_dir(), &format!("hypergrid_4d_20.{obj}"))
            .expect("artifact (run `make artifacts`)");
        let rc = run_config("hypergrid_4d_20", obj);
        let mut trainer = Trainer::new(&env, &art, 0, rc.explore).unwrap();
        let mut counter = TerminalCounter::new(n_states, window);
        let t0 = Instant::now();
        let checkpoints = 6u64;
        for i in 0..=iters {
            let (_stats, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
            for o in &objs {
                counter.push(env.flat_index(o));
            }
            if i % (iters / checkpoints).max(1) == 0 {
                let tv = tv_from_counts(&exact, counter.counts());
                table.row(&[
                    obj.to_uppercase(),
                    format!("{:.1}", t0.elapsed().as_secs_f64()),
                    i.to_string(),
                    format!("{tv:.4}"),
                ]);
            }
        }
    }
    table.row(&[
        "perfect sampler".to_string(),
        "—".to_string(),
        "—".to_string(),
        format!("{floor:.4}"),
    ]);
    table.print();
}
