//! Table 1 — iterations/second, host-synchronized baseline vs gfnx-rs fast
//! path, across every environment family and objective the paper lists.
//!
//! The baseline reproduces the *mechanism* of torchgfn/author PyTorch
//! implementations (per-sample policy dispatch + per-call parameter
//! re-upload + scalar env stepping; see coordinator::baseline). Absolute
//! numbers depend on this CPU testbed; the paper's claim under reproduction
//! is the *ratio and its ordering* across environments.
//!
//! Run: `cargo bench --bench table1_throughput`
//! Env: GFNX_BENCH_REPEATS / GFNX_BENCH_ITERS override the measurement size.

use gfnx::bench::harness::{itps_json, measure_it_per_sec, BenchJson, BenchTable};
use gfnx::coordinator::baseline::BaselineTrainer;
use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::VecEnv;
use gfnx::runtime::Artifact;
use gfnx::util::stats::ItPerSec;

fn envv(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Single source of the measurement knobs: (repeats, iters). Used by both
/// the measurement loop and the JSON meta emission so they cannot diverge.
fn bench_params() -> (usize, usize) {
    (envv("GFNX_BENCH_REPEATS", 3), envv("GFNX_BENCH_ITERS", 8))
}

struct Row {
    env: &'static str,
    objective: &'static str,
    baseline: Option<ItPerSec>,
    fast: ItPerSec,
}

fn bench_pair<E: VecEnv>(
    env: &E,
    artifact: &str,
    extra: &ExtraSource<'_, E>,
    with_baseline: bool,
) -> (Option<ItPerSec>, ItPerSec) {
    let (repeats, iters) = bench_params();
    let art = Artifact::load(&artifacts_dir(), artifact).expect("artifact (run `make artifacts`)");
    let (cfg_name, loss) = artifact.split_once('.').unwrap();
    let rc = run_config(cfg_name, loss);

    let mut fast_tr = Trainer::new(env, &art, 0, rc.explore).unwrap();
    let fast = measure_it_per_sec(2, repeats, iters, || {
        fast_tr.train_iter(extra).unwrap();
    });

    let baseline = with_baseline.then(|| {
        let mut base_tr = BaselineTrainer::new(env, &art, 0, rc.explore).unwrap();
        measure_it_per_sec(1, repeats.min(2), (iters / 4).max(1), || {
            base_tr.train_iter(extra).unwrap();
        })
    });
    (baseline, fast)
}

fn main() {
    let mut rows: Vec<Row> = Vec::new();

    // --- Hypergrid 4d·20, DB / TB / SubTB (paper rows 1–3). ------------
    {
        use gfnx::envs::hypergrid::HypergridEnv;
        use gfnx::reward::hypergrid::HypergridReward;
        let env = HypergridEnv::new(4, 20, HypergridReward::standard(20));
        for (obj, art) in [
            ("DB", "hypergrid_4d_20.db"),
            ("TB", "hypergrid_4d_20.tb"),
            ("SubTB", "hypergrid_4d_20.subtb"),
        ] {
            let (b, f) = bench_pair(&env, art, &ExtraSource::None, true);
            rows.push(Row { env: "Hypergrid (20^4)", objective: obj, baseline: b, fast: f });
        }
    }

    // --- Bit sequences, DB / TB. ------------------------------------------
    {
        use gfnx::envs::bitseq::{bitseq_env, BitSeqConfig};
        let (env, _modes) = bitseq_env(BitSeqConfig::small());
        for (obj, art) in [("DB", "bitseq_small.db"), ("TB", "bitseq_small.tb")] {
            let (b, f) = bench_pair(&env, art, &ExtraSource::None, true);
            rows.push(Row { env: "Bitseq (n=24, k=4)", objective: obj, baseline: b, fast: f });
        }
    }

    // --- TFBind8, TB. -------------------------------------------------------
    {
        use gfnx::envs::tfbind8::tfbind8_env;
        let env = tfbind8_env(0, 10.0);
        let (b, f) = bench_pair(&env, "tfbind8.tb", &ExtraSource::None, true);
        rows.push(Row { env: "TFBind8", objective: "TB", baseline: b, fast: f });
    }

    // --- QM9, TB. ---------------------------------------------------------
    {
        use gfnx::envs::qm9::qm9_env;
        let env = qm9_env(0, 10.0);
        let (b, f) = bench_pair(&env, "qm9.tb", &ExtraSource::None, true);
        rows.push(Row { env: "QM9", objective: "TB", baseline: b, fast: f });
    }

    // --- AMP, TB. --------------------------------------------------------
    {
        use gfnx::envs::amp::amp_env_sized;
        let env = amp_env_sized(0, 1e-3, 8);
        let (b, f) = bench_pair(&env, "amp_small.tb", &ExtraSource::None, true);
        rows.push(Row { env: "AMP (len<=8)", objective: "TB", baseline: b, fast: f });
    }

    // --- Phylogenetics, FLDB. -----------------------------------------------
    {
        use gfnx::data::phylo_data::synthetic_alignment;
        use gfnx::envs::phylo::PhyloEnv;
        use gfnx::util::rng::Rng;
        let mut rng = Rng::new(7);
        let aln = synthetic_alignment(6, 8, 0.15, &mut rng);
        let env = PhyloEnv::new(aln, 16.0, 4.0);
        let env_ref = &env;
        let extra = ExtraSource::Energy(&move |s, i| env_ref.energy(s, i));
        let (b, f) = bench_pair(&env, "phylo_small.fldb", &extra, true);
        rows.push(Row { env: "Phylo (6 species)", objective: "FLDB", baseline: b, fast: f });
    }

    // --- Structure learning, MDB. -----------------------------------------
    {
        use gfnx::data::ancestral::ancestral_sample;
        use gfnx::data::erdos_renyi::sample_er_dag;
        use gfnx::envs::bayesnet::{BayesNetEnv, BayesNetState};
        use gfnx::reward::lingauss::lingauss_table;
        use gfnx::util::rng::Rng;
        let mut rng = Rng::new(8);
        let g = sample_er_dag(5, 1.0, &mut rng);
        let data = ancestral_sample(&g, 100, 0.1, &mut rng);
        let table = lingauss_table(&data, 0.1, 1.0);
        let env = BayesNetEnv::new(5, table.clone());
        let table_ref = &table;
        let extra = ExtraSource::StateLogReward(
            &move |s: &BayesNetState, i: usize| table_ref.log_score(s.adj[i]),
        );
        let (b, f) = bench_pair(&env, "bayesnet_d5.mdb", &extra, true);
        rows.push(Row { env: "Structure Learning", objective: "MDB", baseline: b, fast: f });
    }

    // --- Ising, TB (no open-source baseline in the paper: "—"). --------------
    {
        use gfnx::envs::ising::IsingEnv;
        use gfnx::reward::ising::IsingReward;
        let env = IsingEnv::lattice(3, IsingReward::torus(3, 0.2));
        let (_b, f) = bench_pair(&env, "ising_small.tb", &ExtraSource::None, false);
        rows.push(Row { env: "Ising (N=3)", objective: "TB", baseline: None, fast: f });
    }

    // --- Render. -----------------------------------------------------------
    let mut table = BenchTable::new(
        "Table 1 — it/s, host-synchronized baseline vs gfnx-rs",
        &["Environment", "Objective", "Baseline", "gfnx-rs", "Speedup"],
    );
    for r in &rows {
        let (b_s, speed) = match r.baseline {
            Some(b) => (b.to_string(), format!("{:.1}x", r.fast.mean / b.mean)),
            None => ("—".to_string(), "—".to_string()),
        };
        table.row(&[
            r.env.to_string(),
            r.objective.to_string(),
            b_s,
            r.fast.to_string(),
            speed,
        ]);
    }
    table.print();

    // --- Machine-readable emission (perf trajectory). ----------------------
    use gfnx::util::json::Json;
    let mut bj = BenchJson::new("table1");
    let (repeats, iters) = bench_params();
    bj.meta("repeats", Json::Num(repeats as f64));
    bj.meta("iters", Json::Num(iters as f64));
    for r in &rows {
        bj.row(Json::obj(vec![
            ("env", Json::Str(r.env.to_string())),
            ("objective", Json::Str(r.objective.to_string())),
            (
                "baseline_it_per_sec",
                r.baseline.as_ref().map(itps_json).unwrap_or(Json::Null),
            ),
            ("fast_it_per_sec", itps_json(&r.fast)),
            (
                "speedup",
                r.baseline
                    .map(|b| Json::Num(r.fast.mean / b.mean))
                    .unwrap_or(Json::Null),
            ),
        ]));
    }
    match bj.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_table1.json write failed: {e}"),
    }
}
