//! Minimal work-alike of the `anyhow` crate (vendored for offline builds).
//!
//! Covers the API surface gfnx uses: [`Result`], [`Error`], and the
//! [`anyhow!`], [`bail!`], [`ensure!`] macros. The semantics mirror the real
//! crate where it matters:
//!
//! - `Error` wraps any `std::error::Error + Send + Sync + 'static` via a
//!   blanket `From`, so `?` works on `std::io::Error` and friends;
//! - `Error` deliberately does **not** implement `std::error::Error` itself
//!   (that is what makes the blanket conversion coherent);
//! - `anyhow!` accepts either a format string or a single displayable value.

use std::fmt;

/// A type-erased error: a display message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// The underlying source error, if this `Error` wrapped one.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source();
        while let Some(e) = src {
            write!(f, "\n\nCaused by:\n    {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("x = {x} and {}", 4);
        assert_eq!(e.to_string(), "x = 3 and 4");
        assert!(fails(true).is_ok());
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        let e = read().unwrap_err();
        assert!(e.source().is_some());
        let dbg = format!("{e:?}");
        assert!(!dbg.is_empty());
    }

    #[test]
    fn bail_short_circuits() {
        fn f() -> Result<()> {
            bail!("stop {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop 1");
    }
}
