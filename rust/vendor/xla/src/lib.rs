//! API stub of [xla-rs] 0.5.x (vendored for offline builds).
//!
//! The build image has neither crates.io access nor `libxla_extension`, so
//! this crate mirrors the subset of the xla-rs surface that gfnx's `runtime`
//! module uses. Host-side plumbing ([`Literal`], [`PjRtBuffer`], reshape,
//! tuple decomposition) is fully functional; only
//! [`PjRtLoadedExecutable::execute`] / [`PjRtLoadedExecutable::execute_b`]
//! are unimplemented, returning [`Error::Unimplemented`] — there is no XLA
//! runtime here. Everything that does not execute a compiled HLO graph
//! (environments, host-policy rollouts, the serve subsystem, benches over
//! `UniformPolicy`) works unchanged against this stub, and the signatures
//! match xla-rs so swapping in the real crate requires no call-site edits.
//!
//! [xla-rs]: https://github.com/LaurentMazare/xla-rs

use std::rc::Rc;

/// Errors surfaced by the (stub) XLA runtime.
#[derive(Clone, Debug)]
pub enum Error {
    /// The operation needs the real XLA runtime, which this stub lacks.
    Unimplemented(&'static str),
    /// Shape/dtype mismatch in host-side literal plumbing.
    Shape(String),
    /// Filesystem-level failure loading an HLO artifact.
    Io(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unimplemented(what) => write!(
                f,
                "{what}: unavailable in the vendored xla stub (install the real \
                 xla-rs crate + libxla_extension to execute AOT artifacts)"
            ),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

type XlaResult<T> = std::result::Result<T, Error>;

/// Element types gfnx's manifests can reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    F32,
    F64,
}

/// Typed literal payload (public only because [`ArrayElement`] mentions it).
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    F64(Vec<f64>),
    Tuple(Vec<Literal>),
}

/// Native element types storable in a [`Literal`] (mirror of xla-rs's
/// `NativeType`/`ArrayElement`).
pub trait ArrayElement: Copy + Sized + 'static {
    const TY: ElementType;
    fn wrap(data: Vec<Self>) -> Payload;
    fn unwrap(p: &Payload) -> Option<&[Self]>;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(data: Vec<f32>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(p: &Payload) -> Option<&[f32]> {
        match p {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(data: Vec<i32>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(p: &Payload) -> Option<&[i32]> {
        match p {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl ArrayElement for f64 {
    const TY: ElementType = ElementType::F64;
    fn wrap(data: Vec<f64>) -> Payload {
        Payload::F64(data)
    }
    fn unwrap(p: &Payload) -> Option<&[f64]> {
        match p {
            Payload::F64(v) => Some(v),
            _ => None,
        }
    }
}

/// A host-side typed tensor (or tuple of tensors).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: ArrayElement>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], payload: T::wrap(data.to_vec()) }
    }

    /// Tuple literal from parts.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], payload: Payload::Tuple(parts) }
    }

    fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::F64(v) => v.len(),
            Payload::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() || matches!(self.payload, Payload::Tuple(_)) {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Element type of this literal.
    pub fn ty(&self) -> XlaResult<ElementType> {
        match &self.payload {
            Payload::F32(_) => Ok(ElementType::F32),
            Payload::I32(_) => Ok(ElementType::S32),
            Payload::F64(_) => Ok(ElementType::F64),
            Payload::Tuple(_) => Err(Error::Shape("ty() on tuple literal".into())),
        }
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: ArrayElement>(&self) -> XlaResult<Vec<T>> {
        T::unwrap(&self.payload)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error::Shape(format!("literal is not {:?}", T::TY)))
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: ArrayElement>(&self) -> XlaResult<T> {
        T::unwrap(&self.payload)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error::Shape("empty or mistyped literal".into()))
    }

    /// Copy raw elements into a destination slice (lengths must match).
    pub fn copy_raw_to<T: ArrayElement>(&self, dst: &mut [T]) -> XlaResult<()> {
        let src = T::unwrap(&self.payload)
            .ok_or_else(|| Error::Shape(format!("literal is not {:?}", T::TY)))?;
        if src.len() != dst.len() {
            return Err(Error::Shape(format!(
                "copy_raw_to length mismatch: {} vs {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        match &self.payload {
            Payload::Tuple(v) => Ok(v.clone()),
            _ => Err(Error::Shape("to_tuple() on non-tuple literal".into())),
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (stub: retains only the source path).
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    /// Load an HLO text file. Succeeds when the file is readable; the text
    /// is not interpreted by the stub.
    pub fn from_text_file(path: &str) -> XlaResult<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        Ok(HloModuleProto { _path: path.to_string() })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle. `Rc`-based (not `Send`), matching xla-rs's CPU client
/// threading model: one client per thread, clones share the underlying
/// runtime.
#[derive(Clone)]
pub struct PjRtClient {
    _rc: Rc<()>,
}

impl PjRtClient {
    /// The CPU client. Always constructible in the stub.
    pub fn cpu() -> XlaResult<PjRtClient> {
        Ok(PjRtClient { _rc: Rc::new(()) })
    }

    /// "Compile" a computation. The stub returns a handle whose `execute*`
    /// methods report [`Error::Unimplemented`].
    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { _priv: () })
    }

    /// Upload a host buffer as a device buffer (host-side copy in the stub).
    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> XlaResult<PjRtBuffer> {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = Literal::vec1(data).reshape(&dims_i64)?;
        Ok(PjRtBuffer { lit })
    }
}

/// A device-resident buffer (host-side in the stub).
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Ok(self.lit.clone())
    }
}

impl AsRef<PjRtBuffer> for PjRtBuffer {
    fn as_ref(&self) -> &PjRtBuffer {
        self
    }
}

/// A compiled executable handle. Execution needs the real XLA runtime.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments. Unimplemented in the stub.
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device-buffer arguments. Unimplemented in the stub.
    pub fn execute_b<T: AsRef<PjRtBuffer>>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.ty().unwrap(), ElementType::F32);
        assert!(lit.reshape(&[3, 3]).is_err());
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        let mut dst = [0f32; 4];
        lit.copy_raw_to::<f32>(&mut dst).unwrap();
        assert_eq!(dst, [1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
        assert!(parts[1].to_tuple().is_err());
    }

    #[test]
    fn client_plumbs_buffers_but_not_execution() {
        let c = PjRtClient::cpu().unwrap();
        let buf = c.buffer_from_host_buffer(&[1.0f32, 2.0], &[2, 1], None).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        let exe = c.compile(&XlaComputation::from_proto(
            &HloModuleProto { _path: String::new() },
        )).unwrap();
        assert!(exe.execute::<Literal>(&[]).is_err());
    }
}
