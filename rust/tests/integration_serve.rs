//! End-to-end tests of the continuous-batching sampling service.
//!
//! These run against host-side policies (no AOT artifacts needed): the full
//! stack under test is envs → slot engine → worker thread → queue → tickets.

use gfnx::envs::bitseq::{bitseq_env, BitSeqConfig};
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::policy::{BatchPolicy, PolicyShape, UniformPolicy};
use gfnx::serve::{SampleOutput, SampleRequest, SamplerService};

fn hypergrid(h: usize) -> HypergridEnv<HypergridReward> {
    HypergridEnv::new(2, h, HypergridReward::standard(h))
}

fn spawn_hypergrid(h: usize, b: usize) -> SamplerService<Vec<i32>> {
    let env = hypergrid(h);
    let shape = PolicyShape::of_env(&env, b);
    SamplerService::spawn(env, move || {
        Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
    })
}

fn key(outs: &[SampleOutput<Vec<i32>>]) -> Vec<(Vec<i32>, u64, u64, usize)> {
    outs.iter()
        .map(|o| (o.obj.clone(), o.log_pf.to_bits(), o.log_reward.to_bits(), o.length))
        .collect()
}

#[test]
fn service_answers_requests_with_exact_counts() {
    let svc = spawn_hypergrid(8, 8);
    let outs = svc.sample(37, 5).unwrap();
    assert_eq!(outs.len(), 37);
    let env = hypergrid(8);
    for (i, o) in outs.iter().enumerate() {
        assert_eq!(o.traj_index, i, "outputs sorted by trajectory index");
        assert!(o.length >= 1 && o.length <= env.spec().t_max);
        assert!(o.log_pf < 0.0);
        assert_eq!(o.log_reward, env.log_reward_obj(&o.obj));
    }
    let stats = svc.stats();
    assert_eq!(stats.trajectories_completed, 37);
    assert_eq!(stats.requests_completed, 1);
    svc.shutdown();
}

#[test]
fn service_output_is_bit_reproducible_for_fixed_seed() {
    // Same seed → identical bits, across service instances and slot widths.
    let a = spawn_hypergrid(8, 4).sample(24, 123).unwrap();
    let b = spawn_hypergrid(8, 4).sample(24, 123).unwrap();
    let c = spawn_hypergrid(8, 16).sample(24, 123).unwrap();
    assert_eq!(key(&a), key(&b), "same service config must reproduce bits");
    assert_eq!(key(&a), key(&c), "slot-table width must not affect results");
    // A different seed diverges.
    let d = spawn_hypergrid(8, 4).sample(24, 124).unwrap();
    assert_ne!(key(&a), key(&d));
}

#[test]
fn repeated_requests_on_one_service_are_reproducible() {
    let svc = spawn_hypergrid(8, 8);
    let a = svc.sample(16, 77).unwrap();
    let b = svc.sample(16, 77).unwrap();
    assert_eq!(key(&a), key(&b), "the service must be stateless across requests");
    svc.shutdown();
}

#[test]
fn concurrent_requests_all_complete_and_stay_deterministic() {
    let svc = spawn_hypergrid(10, 8);
    // Submit a burst of tickets before waiting on any: the worker merges
    // them into the same slot table.
    let tickets: Vec<_> = (0..6)
        .map(|k| svc.submit(SampleRequest { n_samples: 5 + 3 * k, seed: 1000 + k as u64 }))
        .collect();
    let results: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    for (k, outs) in results.iter().enumerate() {
        assert_eq!(outs.len(), 5 + 3 * k);
    }
    let stats = svc.stats();
    assert_eq!(stats.requests_completed, 6);
    assert!(stats.occupancy() > 0.0);
    svc.shutdown();
    // Each request's result equals the same request served alone.
    for k in 0..6usize {
        let alone = spawn_hypergrid(10, 8)
            .sample(5 + 3 * k, 1000 + k as u64)
            .unwrap();
        assert_eq!(key(&results[k]), key(&alone), "request {k} affected by batch-mates");
    }
}

#[test]
fn zero_sample_request_completes_immediately() {
    let svc = spawn_hypergrid(6, 4);
    let outs = svc.sample(0, 9).unwrap();
    assert!(outs.is_empty());
    svc.shutdown();
}

#[test]
fn failed_policy_factory_errors_instead_of_hanging() {
    let env = hypergrid(6);
    let failing: SamplerService<Vec<i32>> =
        SamplerService::spawn(env, || Err(anyhow::anyhow!("no policy available")));
    // Whether the request lands before or after the worker closes the
    // queue, it must error (never hang).
    let err = failing.sample(4, 0).unwrap_err();
    assert!(
        err.to_string().contains("policy init failed")
            || err.to_string().contains("shut down"),
        "unexpected error: {err}"
    );
}

#[test]
fn service_runs_on_bitseq_fixed_length_sequences() {
    let (env, _modes) = bitseq_env(BitSeqConfig::small());
    let spec = env.spec();
    let shape = PolicyShape::of_env(&env, 8);
    let svc: SamplerService<Vec<i16>> = SamplerService::spawn(env, move || {
        Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
    });
    let outs = svc.sample(20, 42).unwrap();
    assert_eq!(outs.len(), 20);
    for o in &outs {
        assert_eq!(o.length, spec.t_max, "non-autoregressive bitseq is fixed length");
        assert!(o.obj.iter().all(|&t| t >= 0), "every position filled");
        assert!(o.log_reward.is_finite());
    }
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// HTTP front end over real TCP sockets: the full network stack under test is
// conn parse → admission (bounded queue) → fairness lanes → drain → JSON.
// ---------------------------------------------------------------------------

mod http_stack {
    use super::*;
    use gfnx::serve::conn::HttpClient;
    use gfnx::serve::{HttpServer, HttpServerConfig, SamplerService, ServeIdentity};
    use gfnx::telemetry::Registry;
    use gfnx::util::json::Json;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    /// A policy whose FIRST eval stalls for `hold`, then behaves uniformly.
    /// Lets a test wedge the worker mid-drain deterministically (no timing
    /// races: while the worker sleeps in eval, nothing drains the queue).
    struct SlowStart {
        inner: UniformPolicy,
        hold: Duration,
        held: bool,
    }

    impl BatchPolicy for SlowStart {
        fn shape(&self) -> PolicyShape {
            BatchPolicy::shape(&self.inner)
        }
        fn eval(
            &mut self,
            obs: &[f32],
            fwd: &[f32],
            bwd: &[f32],
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            if !self.held {
                self.held = true;
                std::thread::sleep(self.hold);
            }
            self.inner.eval(obs, fwd, bwd)
        }
    }

    fn serve_http(
        queue_cap: Option<usize>,
        hold: Duration,
        b: usize,
    ) -> (HttpServer, Arc<SamplerService<Vec<i32>>>) {
        let env = hypergrid(8);
        let shape = PolicyShape::of_env(&env, b);
        let svc = Arc::new(SamplerService::spawn_with(
            env,
            move || {
                Ok(Box::new(SlowStart { inner: UniformPolicy::new(shape), hold, held: false })
                    as Box<dyn BatchPolicy>)
            },
            Arc::new(Registry::new()),
            queue_cap,
        ));
        let identity = ServeIdentity {
            family: "hypergrid".to_string(),
            config: "hypergrid_small".to_string(),
            model: "mlp".to_string(),
        };
        let http = HttpServer::serve(
            "127.0.0.1:0",
            Arc::clone(&svc),
            identity,
            HttpServerConfig::default(),
        )
        .unwrap();
        (http, svc)
    }

    #[test]
    fn flood_against_bounded_queue_sheds_with_503_not_oom() {
        // Wedge the worker (first eval holds 800 ms), then flood 10 requests
        // at a cap-2 queue: exactly 2 are admitted, 8 get 503 + Retry-After.
        let (http, svc) = serve_http(Some(2), Duration::from_millis(800), 4);
        let addr = http.local_addr().to_string();
        let mut wedge = HttpClient::connect(&addr).unwrap();
        let wedge_thread = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = HttpClient::connect(&addr).unwrap();
                c.post_json("/sample", "{\"n\": 8, \"seed\": 1}").unwrap()
            })
        };
        std::thread::sleep(Duration::from_millis(200)); // worker now asleep in eval
        let floods: Vec<_> = (0..10)
            .map(|k| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut c = HttpClient::connect(&addr).unwrap();
                    let body = format!("{{\"n\": 2, \"seed\": {}}}", 100 + k);
                    c.post_json("/sample", &body).unwrap().0
                })
            })
            .collect();
        let statuses: Vec<u16> = floods.into_iter().map(|h| h.join().unwrap()).collect();
        let ok = statuses.iter().filter(|&&s| s == 200).count();
        let shed = statuses.iter().filter(|&&s| s == 503).count();
        assert_eq!((ok, shed), (2, 8), "statuses: {statuses:?}");
        let (s, _) = wedge_thread.join().unwrap();
        assert_eq!(s, 200, "the wedging request itself completes");
        // The shed counter made it to the registry served by /stats.
        let (s, body) = wedge.get("/stats").unwrap();
        assert_eq!(s, 200);
        let stats = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let counters = stats.req("registry").unwrap().req("counters").unwrap();
        let shed_count = counters.req("serve.shed").unwrap().as_f64().unwrap();
        assert_eq!(shed_count as usize, 8);
        http.shutdown();
        drop(svc);
    }

    #[test]
    fn expired_deadline_gets_504_within_twice_the_deadline() {
        // Wedge the worker past the request's deadline: the heap sweep fails
        // it mid-drain, and the handler's 2x wait_timeout bounds the answer
        // even if the worker stayed wedged.
        let (http, svc) = serve_http(None, Duration::from_millis(700), 4);
        let addr = http.local_addr().to_string();
        let mut client = HttpClient::connect(&addr).unwrap();
        let t0 = Instant::now();
        let (status, body) = client
            .post_json("/sample", "{\"n\": 64, \"seed\": 3, \"deadline_ms\": 250}")
            .unwrap();
        let elapsed = t0.elapsed();
        assert_eq!(status, 504, "{}", String::from_utf8_lossy(&body));
        assert!(
            elapsed < Duration::from_millis(2 * 250 + 750),
            "504 took {elapsed:?}, budget is 2x the 250 ms deadline (+ slack)"
        );
        // The service survives the expiry: a follow-up request succeeds.
        let (status, _) = client.post_json("/sample", "{\"n\": 3, \"seed\": 4}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(svc.stats().requests_timedout, 1);
        http.shutdown();
        drop(svc);
    }

    #[test]
    fn traced_request_reconciles_with_recorded_latency() {
        // At rate 1.0 the POST /sample below is sampled; its waterfall's
        // queue_wait + drain segments are stamped from the same two Instants
        // the worker uses to record serve.request_latency, so with exactly
        // one request against a fresh registry the sums must match to the
        // nanosecond (ns values are far below 2^53, so f64 is exact).
        let _guard = gfnx::telemetry::flag_test_lock();
        gfnx::telemetry::trace::set_trace_rate(1.0);
        gfnx::telemetry::trace::reset_sampler();
        let (http, svc) = serve_http(None, Duration::ZERO, 4);
        let mut client = HttpClient::connect(&http.local_addr().to_string()).unwrap();
        let (status, _) = client.post_json("/sample", "{\"n\": 6, \"seed\": 21}").unwrap();
        // Same keep-alive connection: the handler finished the trace before
        // it started reading this GET, so the record is already in the ring.
        let (trace_status, trace_body) = client.get("/trace?n=8").unwrap();
        gfnx::telemetry::trace::set_trace_rate(0.0);
        assert_eq!(status, 200);
        assert_eq!(trace_status, 200);
        let traces = Json::parse(std::str::from_utf8(&trace_body).unwrap()).unwrap();
        let recs = traces.req_arr("traces").unwrap();
        let rec = recs
            .iter()
            .find(|r| r.get("kind").and_then(Json::as_str) == Some("http_request"))
            .expect("a sampled http_request trace");
        let seg_ns = |name: &str| -> f64 {
            rec.req_arr("segments")
                .unwrap()
                .iter()
                .find(|s| s.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("segment '{name}' missing: {rec}"))
                .req("dur_ns")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let queued_plus_drained = seg_ns("queue_wait") + seg_ns("drain");
        let (status, body) = client.get("/stats").unwrap();
        assert_eq!(status, 200);
        let stats = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let lat = stats
            .req("registry")
            .unwrap()
            .req("histograms")
            .unwrap()
            .req("serve.request_latency")
            .unwrap();
        assert_eq!(lat.req("count").unwrap().as_f64(), Some(1.0));
        let recorded_ns = lat.req("sum").unwrap().as_f64().unwrap();
        assert_eq!(
            queued_plus_drained, recorded_ns,
            "queue_wait + drain must equal the recorded request latency exactly"
        );
        http.shutdown();
        drop(svc);
    }

    #[test]
    fn stats_and_health_routes_answer_over_real_sockets() {
        let (http, svc) = serve_http(None, Duration::ZERO, 4);
        let mut client = HttpClient::connect(&http.local_addr().to_string()).unwrap();
        let (status, _) = client.post_json("/sample", "{\"n\": 5, \"seed\": 11}").unwrap();
        assert_eq!(status, 200);
        let (status, body) = client.get("/stats").unwrap();
        assert_eq!(status, 200);
        let stats = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(stats.req("family").unwrap().as_str(), Some("hypergrid"));
        assert_eq!(stats.req("model").unwrap().as_str(), Some("mlp"));
        let counters = stats.req("registry").unwrap().req("counters").unwrap();
        let completed =
            counters.req("serve.requests_completed").unwrap().as_f64().unwrap();
        assert!(completed >= 1.0);
        let (status, body) = client.get("/healthz").unwrap();
        assert_eq!(status, 200);
        assert!(String::from_utf8_lossy(&body).contains("true"));
        http.shutdown();
        drop(svc);
    }
}
