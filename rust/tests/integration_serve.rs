//! End-to-end tests of the continuous-batching sampling service.
//!
//! These run against host-side policies (no AOT artifacts needed): the full
//! stack under test is envs → slot engine → worker thread → queue → tickets.

use gfnx::envs::bitseq::{bitseq_env, BitSeqConfig};
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::policy::{BatchPolicy, PolicyShape, UniformPolicy};
use gfnx::serve::{SampleOutput, SampleRequest, SamplerService};

fn hypergrid(h: usize) -> HypergridEnv<HypergridReward> {
    HypergridEnv::new(2, h, HypergridReward::standard(h))
}

fn spawn_hypergrid(h: usize, b: usize) -> SamplerService<Vec<i32>> {
    let env = hypergrid(h);
    let shape = PolicyShape::of_env(&env, b);
    SamplerService::spawn(env, move || {
        Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
    })
}

fn key(outs: &[SampleOutput<Vec<i32>>]) -> Vec<(Vec<i32>, u64, u64, usize)> {
    outs.iter()
        .map(|o| (o.obj.clone(), o.log_pf.to_bits(), o.log_reward.to_bits(), o.length))
        .collect()
}

#[test]
fn service_answers_requests_with_exact_counts() {
    let svc = spawn_hypergrid(8, 8);
    let outs = svc.sample(37, 5).unwrap();
    assert_eq!(outs.len(), 37);
    let env = hypergrid(8);
    for (i, o) in outs.iter().enumerate() {
        assert_eq!(o.traj_index, i, "outputs sorted by trajectory index");
        assert!(o.length >= 1 && o.length <= env.spec().t_max);
        assert!(o.log_pf < 0.0);
        assert_eq!(o.log_reward, env.log_reward_obj(&o.obj));
    }
    let stats = svc.stats();
    assert_eq!(stats.trajectories_completed, 37);
    assert_eq!(stats.requests_completed, 1);
    svc.shutdown();
}

#[test]
fn service_output_is_bit_reproducible_for_fixed_seed() {
    // Same seed → identical bits, across service instances and slot widths.
    let a = spawn_hypergrid(8, 4).sample(24, 123).unwrap();
    let b = spawn_hypergrid(8, 4).sample(24, 123).unwrap();
    let c = spawn_hypergrid(8, 16).sample(24, 123).unwrap();
    assert_eq!(key(&a), key(&b), "same service config must reproduce bits");
    assert_eq!(key(&a), key(&c), "slot-table width must not affect results");
    // A different seed diverges.
    let d = spawn_hypergrid(8, 4).sample(24, 124).unwrap();
    assert_ne!(key(&a), key(&d));
}

#[test]
fn repeated_requests_on_one_service_are_reproducible() {
    let svc = spawn_hypergrid(8, 8);
    let a = svc.sample(16, 77).unwrap();
    let b = svc.sample(16, 77).unwrap();
    assert_eq!(key(&a), key(&b), "the service must be stateless across requests");
    svc.shutdown();
}

#[test]
fn concurrent_requests_all_complete_and_stay_deterministic() {
    let svc = spawn_hypergrid(10, 8);
    // Submit a burst of tickets before waiting on any: the worker merges
    // them into the same slot table.
    let tickets: Vec<_> = (0..6)
        .map(|k| svc.submit(SampleRequest { n_samples: 5 + 3 * k, seed: 1000 + k as u64 }))
        .collect();
    let results: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    for (k, outs) in results.iter().enumerate() {
        assert_eq!(outs.len(), 5 + 3 * k);
    }
    let stats = svc.stats();
    assert_eq!(stats.requests_completed, 6);
    assert!(stats.occupancy() > 0.0);
    svc.shutdown();
    // Each request's result equals the same request served alone.
    for k in 0..6usize {
        let alone = spawn_hypergrid(10, 8)
            .sample(5 + 3 * k, 1000 + k as u64)
            .unwrap();
        assert_eq!(key(&results[k]), key(&alone), "request {k} affected by batch-mates");
    }
}

#[test]
fn zero_sample_request_completes_immediately() {
    let svc = spawn_hypergrid(6, 4);
    let outs = svc.sample(0, 9).unwrap();
    assert!(outs.is_empty());
    svc.shutdown();
}

#[test]
fn failed_policy_factory_errors_instead_of_hanging() {
    let env = hypergrid(6);
    let failing: SamplerService<Vec<i32>> =
        SamplerService::spawn(env, || Err(anyhow::anyhow!("no policy available")));
    // Whether the request lands before or after the worker closes the
    // queue, it must error (never hang).
    let err = failing.sample(4, 0).unwrap_err();
    assert!(
        err.to_string().contains("policy init failed")
            || err.to_string().contains("shut down"),
        "unexpected error: {err}"
    );
}

#[test]
fn service_runs_on_bitseq_fixed_length_sequences() {
    let (env, _modes) = bitseq_env(BitSeqConfig::small());
    let spec = env.spec();
    let shape = PolicyShape::of_env(&env, 8);
    let svc: SamplerService<Vec<i16>> = SamplerService::spawn(env, move || {
        Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
    });
    let outs = svc.sample(20, 42).unwrap();
    assert_eq!(outs.len(), 20);
    for o in &outs {
        assert_eq!(o.length, spec.t_max, "non-autoregressive bitseq is fixed length");
        assert!(o.obj.iter().all(|&t| t >= 0), "every position filled");
        assert!(o.log_reward.is_finite());
    }
    svc.shutdown();
}
