//! Integration tests over the real AOT artifacts (require `make artifacts`).
//!
//! These exercise the full L3→L2→L1 stack: rust envs staging observations,
//! the PJRT-compiled policy graph (with the Pallas masked-softmax inside),
//! and the fused train step.

use gfnx::coordinator::eval::log_p_theta_hat;
use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::{
    backward_rollout_score, forward_rollout, ExtraSource, RolloutCtx,
};
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::metrics::tv::tv_from_counts;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::Artifact;
use gfnx::util::rng::Rng;
use gfnx::util::stats::softmax_from_logs;
use std::path::PathBuf;

/// Artifacts are produced by `make artifacts` (JAX AOT lowering) and are
/// not checked in; these tests skip gracefully when they are absent so the
/// suite stays green in artifact-less environments. Every test starts with
/// `let Some(dir) = artifacts_dir() else { return };`.
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("hypergrid_small.tb.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: AOT artifacts missing — run `make artifacts` AND build \
             against the real xla-rs crate (see rust/vendor/README.md) to enable"
        );
        None
    }
}

fn small_env() -> HypergridEnv<HypergridReward> {
    HypergridEnv::new(2, 8, HypergridReward::standard(8))
}

#[test]
fn policy_outputs_valid_distributions() {
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
    let ts = art.init_state().unwrap();
    let spec = env.spec();
    let b = art.batch();
    let state = env.reset(b);
    let mut ctx = RolloutCtx::for_artifact(&art);
    // Stage initial states manually via a zero-eps rollout context.
    let mut obs = vec![0f32; b * spec.obs_dim];
    let mut fwd_mask = vec![0f32; b * spec.n_actions];
    let mut bwd_mask = vec![0f32; b * spec.n_bwd_actions];
    let mut scratch = vec![false; spec.n_actions];
    let mut bscratch = vec![false; spec.n_bwd_actions];
    for i in 0..b {
        env.obs_into(&state, i, &mut obs[i * spec.obs_dim..(i + 1) * spec.obs_dim]);
        env.fwd_mask_into(&state, i, &mut scratch);
        for (j, &m) in scratch.iter().enumerate() {
            fwd_mask[i * spec.n_actions + j] = if m { 1.0 } else { 0.0 };
        }
        env.bwd_mask_into(&state, i, &mut bscratch);
        bwd_mask[i * spec.n_bwd_actions] = 1.0; // s0: sentinel
    }
    let (fwd_logp, bwd_logp, flow) = ts.policy(&art, &obs, &fwd_mask, &bwd_mask).unwrap();
    assert_eq!(fwd_logp.len(), b * spec.n_actions);
    assert_eq!(bwd_logp.len(), b * spec.n_bwd_actions);
    assert_eq!(flow.len(), b);
    for i in 0..b {
        let mut p = 0.0f64;
        for j in 0..spec.n_actions {
            let lp = fwd_logp[i * spec.n_actions + j] as f64;
            if fwd_mask[i * spec.n_actions + j] != 0.0 {
                p += lp.exp();
            } else {
                assert!(lp < -1e20, "illegal action got finite logp");
            }
        }
        assert!((p - 1.0).abs() < 1e-4, "row {i} sums to {p}");
    }
    let _ = ctx.obs.len();
}

#[test]
fn forward_rollout_produces_consistent_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
    let ts = art.init_state().unwrap();
    let mut ctx = RolloutCtx::for_artifact(&art);
    let mut rng = Rng::new(0);
    let (batch, objs) =
        forward_rollout(&env, &art, &ts, &mut ctx, &mut rng, 0.1, &ExtraSource::None).unwrap();
    let spec = env.spec();
    assert_eq!(objs.len(), art.batch());
    for i in 0..art.batch() {
        let len = batch.length[i] as usize;
        assert!(len >= 1 && len <= spec.t_max);
        // log_reward matches the extracted object's reward.
        let want = env.log_reward_obj(&objs[i]) as f32;
        assert!((batch.log_reward[i] - want).abs() < 1e-4);
        // Actions within range; padded entries zeroed.
        for t in 0..len {
            let a = batch.fwd_actions[i * spec.t_max + t];
            assert!(a >= 0 && (a as usize) < spec.n_actions);
        }
        assert!(batch.log_pf[i] <= 0.0);
        assert!(batch.log_pb[i] <= 1e-9);
    }
}

#[test]
fn train_step_runs_and_loss_decreases_with_training() {
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
    let mut trainer = Trainer::new(&env, &art, 7, EpsSchedule::Constant(0.05)).unwrap();
    let mut first = Vec::new();
    let mut last = Vec::new();
    for i in 0..120 {
        let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
        assert!(stats.loss.is_finite());
        if i < 20 {
            first.push(stats.loss as f64);
        }
        if i >= 100 {
            last.push(stats.loss as f64);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&last) < mean(&first),
        "TB loss should trend down: {} -> {}",
        mean(&first),
        mean(&last)
    );
}

#[test]
fn training_improves_tv_against_exact_target() {
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
    // Exact target over the 64 terminal states.
    let n_states = env.num_terminal_states();
    let logs: Vec<f64> = (0..n_states)
        .map(|idx| env.log_reward_obj(&env.unflatten(idx)))
        .collect();
    let exact = softmax_from_logs(&logs);

    let mut trainer = Trainer::new(&env, &art, 3, EpsSchedule::none()).unwrap();
    let sample_tv = |tr: &mut Trainer<HypergridEnv<HypergridReward>>| -> f64 {
        let mut counts = vec![0u64; n_states];
        for _ in 0..40 {
            for obj in tr.sample_objs().unwrap() {
                counts[tr.env.flat_index(&obj)] += 1;
            }
        }
        tv_from_counts(&exact, &counts)
    };
    let tv_before = sample_tv(&mut trainer);
    for _ in 0..400 {
        trainer.train_iter(&ExtraSource::None).unwrap();
    }
    let tv_after = sample_tv(&mut trainer);
    assert!(
        tv_after < tv_before - 0.05,
        "training should reduce TV: {tv_before:.3} -> {tv_after:.3}"
    );
}

#[test]
fn db_and_subtb_artifacts_train() {
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    for loss in ["db", "subtb"] {
        let art = Artifact::load(&dir, &format!("hypergrid_small.{loss}")).unwrap();
        let mut trainer = Trainer::new(&env, &art, 11, EpsSchedule::none()).unwrap();
        let mut losses = Vec::new();
        for _ in 0..40 {
            let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
            assert!(stats.loss.is_finite(), "{loss} loss not finite");
            losses.push(stats.loss as f64);
        }
        let head = losses[..10].iter().sum::<f64>() / 10.0;
        let tail = losses[30..].iter().sum::<f64>() / 10.0;
        assert!(tail < head, "{loss}: {head} -> {tail}");
    }
}

#[test]
fn backward_rollouts_score_finite_and_invert() {
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
    let ts = art.init_state().unwrap();
    let mut ctx = RolloutCtx::for_artifact(&art);
    let mut rng = Rng::new(5);
    // Build some terminal objects.
    let objs: Vec<Vec<i32>> = vec![vec![0, 0], vec![3, 7], vec![7, 7], vec![2, 5]];
    let scores = backward_rollout_score(&env, &art, &ts, &mut ctx, &mut rng, &objs).unwrap();
    assert_eq!(scores.len(), objs.len());
    for (i, (log_pf, log_pb, len)) in scores.iter().enumerate() {
        assert!(log_pf.is_finite() && *log_pf <= 0.0);
        assert!(log_pb.is_finite() && *log_pb <= 1e-9);
        // Trajectory length = |coords|₁ + 1 (the stop-undo).
        let want = objs[i].iter().map(|&c| c as usize).sum::<usize>() + 1;
        assert_eq!(*len, want, "obj {i}");
    }
}

#[test]
fn log_p_theta_hat_normalizes_for_tiny_grid() {
    // For an *untrained* policy P̂_θ is still a distribution in expectation;
    // check Σ_x exp(log P̂_θ(x)) ≈ 1 over the full 64-state space with
    // enough samples (MC noise bounded).
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
    let ts = art.init_state().unwrap();
    let mut ctx = RolloutCtx::for_artifact(&art);
    let mut rng = Rng::new(6);
    let mut total = 0.0f64;
    for idx in 0..env.num_terminal_states() {
        let obj = env.unflatten(idx);
        let lp = log_p_theta_hat(&env, &art, &ts, &mut ctx, &mut rng, &obj, 16).unwrap();
        total += lp.exp();
    }
    assert!(
        (total - 1.0).abs() < 0.25,
        "Σ P̂_θ = {total} (should be ≈ 1)"
    );
}
