//! Integration tests over the full train→sample→metric stack, generic over
//! the training [`Backend`].
//!
//! With AOT artifacts present (`make artifacts` + real xla-rs) they
//! exercise the PJRT-compiled graphs; without artifacts they run the same
//! assertions against the pure-Rust [`NativeBackend`], so the suite no
//! longer skips in artifact-less environments. Only the xla-specific
//! assertions (artifact loading) keep the skip.

use gfnx::coordinator::eval::log_p_theta_hat;
use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::{
    backward_rollout_score_with_policy, forward_rollout_with_policy, ExtraSource, RolloutCtx,
};
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::metrics::tv::tv_from_counts;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::{Artifact, Backend, BackendPolicy, NativeBackend, NativeConfig, XlaBackend};
use gfnx::util::rng::Rng;
use gfnx::util::stats::softmax_from_logs;
use std::path::PathBuf;

/// Artifacts are produced by `make artifacts` (JAX AOT lowering) and are
/// not checked in. When absent, the backend-generic tests fall back to the
/// native backend instead of skipping.
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("hypergrid_small.tb.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "AOT artifacts missing — running against the native backend \
             (xla-specific assertions skip; run `make artifacts` + real \
             xla-rs to cover the artifact path too)"
        );
        None
    }
}

fn small_env() -> HypergridEnv<HypergridReward> {
    HypergridEnv::new(2, 8, HypergridReward::standard(8))
}

fn native_backend(env: &HypergridEnv<HypergridReward>, loss: &str, seed: u64) -> NativeBackend {
    // Batch 16 mirrors the hypergrid_small artifact config.
    NativeBackend::new(NativeConfig::for_env(env, 16, loss).with_hidden(64), seed).unwrap()
}

/// Run `f` on the xla "tb" backend when artifacts exist, else on the native
/// backend — the single definition of the fallback for the borrowed-backend
/// tests (tests that own a `Trainer` dispatch explicitly, since `Trainer`
/// takes its backend by value).
fn with_any_backend(seed: u64, f: impl Fn(&HypergridEnv<HypergridReward>, &dyn Backend)) {
    let env = small_env();
    match artifacts_dir() {
        Some(dir) => {
            let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
            let backend = XlaBackend::new(&art).unwrap();
            f(&env, &backend);
        }
        None => f(&env, &native_backend(&env, "tb", seed)),
    }
}

#[test]
fn policy_outputs_valid_distributions() {
    with_any_backend(1, |env, backend| check_policy_distributions(env, backend));
}

fn check_policy_distributions<B: Backend + ?Sized>(
    env: &HypergridEnv<HypergridReward>,
    backend: &B,
) {
    let spec = env.spec();
    let b = backend.shape().batch;
    let state = env.reset(b);
    let mut obs = vec![0f32; b * spec.obs_dim];
    let mut fwd_mask = vec![0f32; b * spec.n_actions];
    let mut bwd_mask = vec![0f32; b * spec.n_bwd_actions];
    let mut scratch = vec![false; spec.n_actions];
    let mut bscratch = vec![false; spec.n_bwd_actions];
    for i in 0..b {
        env.obs_into(&state, i, &mut obs[i * spec.obs_dim..(i + 1) * spec.obs_dim]);
        env.fwd_mask_into(&state, i, &mut scratch);
        for (j, &m) in scratch.iter().enumerate() {
            fwd_mask[i * spec.n_actions + j] = if m { 1.0 } else { 0.0 };
        }
        env.bwd_mask_into(&state, i, &mut bscratch);
        bwd_mask[i * spec.n_bwd_actions] = 1.0; // s0: sentinel
    }
    let (fwd_logp, bwd_logp, flow) = backend.policy_dispatch(&obs, &fwd_mask, &bwd_mask).unwrap();
    assert_eq!(fwd_logp.len(), b * spec.n_actions);
    assert_eq!(bwd_logp.len(), b * spec.n_bwd_actions);
    assert_eq!(flow.len(), b);
    for i in 0..b {
        let mut p = 0.0f64;
        for j in 0..spec.n_actions {
            let lp = fwd_logp[i * spec.n_actions + j] as f64;
            if fwd_mask[i * spec.n_actions + j] != 0.0 {
                p += lp.exp();
            } else {
                assert!(lp < -1e20, "illegal action got finite logp");
            }
        }
        assert!((p - 1.0).abs() < 1e-4, "row {i} sums to {p}");
    }
}

#[test]
fn forward_rollout_produces_consistent_batches() {
    with_any_backend(2, |env, backend| check_forward_rollout(env, backend));
}

fn check_forward_rollout<B: Backend + ?Sized>(env: &HypergridEnv<HypergridReward>, backend: &B) {
    let shape = backend.shape();
    let mut ctx = RolloutCtx::for_shape(&shape);
    let mut rng = Rng::new(0);
    let mut policy = BackendPolicy { backend };
    let (batch, objs) =
        forward_rollout_with_policy(env, &mut policy, &mut ctx, &mut rng, 0.1, &ExtraSource::None)
            .unwrap();
    let spec = env.spec();
    assert_eq!(objs.len(), shape.batch);
    for i in 0..shape.batch {
        let len = batch.length[i] as usize;
        assert!(len >= 1 && len <= spec.t_max);
        // log_reward matches the extracted object's reward.
        let want = env.log_reward_obj(&objs[i]) as f32;
        assert!((batch.log_reward[i] - want).abs() < 1e-4);
        // Actions within range; padded entries zeroed.
        for t in 0..len {
            let a = batch.fwd_actions[i * spec.t_max + t];
            assert!(a >= 0 && (a as usize) < spec.n_actions);
        }
        assert!(batch.log_pf[i] <= 0.0);
        assert!(batch.log_pb[i] <= 1e-9);
    }
}

#[test]
fn train_step_runs_and_loss_decreases_with_training() {
    let env = small_env();
    match artifacts_dir() {
        Some(dir) => {
            let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
            let trainer = Trainer::new(&env, &art, 7, EpsSchedule::Constant(0.05)).unwrap();
            check_loss_decreases(trainer);
        }
        None => {
            let trainer = Trainer::with_backend(
                &env,
                native_backend(&env, "tb", 7),
                7,
                EpsSchedule::Constant(0.05),
            )
            .unwrap();
            check_loss_decreases(trainer);
        }
    }
}

fn check_loss_decreases<B: Backend>(mut trainer: Trainer<'_, HypergridEnv<HypergridReward>, B>) {
    let mut first = Vec::new();
    let mut last = Vec::new();
    for i in 0..120 {
        let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
        assert!(stats.loss.is_finite());
        if i < 20 {
            first.push(stats.loss as f64);
        }
        if i >= 100 {
            last.push(stats.loss as f64);
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&last) < mean(&first),
        "TB loss should trend down: {} -> {}",
        mean(&first),
        mean(&last)
    );
}

#[test]
fn training_improves_tv_against_exact_target() {
    let env = small_env();
    match artifacts_dir() {
        Some(dir) => {
            let art = Artifact::load(&dir, "hypergrid_small.tb").unwrap();
            let trainer = Trainer::new(&env, &art, 3, EpsSchedule::none()).unwrap();
            check_tv_improves(&env, trainer);
        }
        None => {
            let trainer = Trainer::with_backend(
                &env,
                native_backend(&env, "tb", 3),
                3,
                EpsSchedule::none(),
            )
            .unwrap();
            check_tv_improves(&env, trainer);
        }
    }
}

fn check_tv_improves<B: Backend>(
    env: &HypergridEnv<HypergridReward>,
    mut trainer: Trainer<'_, HypergridEnv<HypergridReward>, B>,
) {
    // Exact target over the 64 terminal states.
    let n_states = env.num_terminal_states();
    let logs: Vec<f64> = (0..n_states)
        .map(|idx| env.log_reward_obj(&env.unflatten(idx)))
        .collect();
    let exact = softmax_from_logs(&logs);
    let mut sample_tv = |tr: &mut Trainer<'_, HypergridEnv<HypergridReward>, B>| -> f64 {
        let mut counts = vec![0u64; n_states];
        for _ in 0..40 {
            for obj in tr.sample_objs().unwrap() {
                counts[tr.env.flat_index(&obj)] += 1;
            }
        }
        tv_from_counts(&exact, &counts)
    };
    let tv_before = sample_tv(&mut trainer);
    for _ in 0..400 {
        trainer.train_iter(&ExtraSource::None).unwrap();
    }
    let tv_after = sample_tv(&mut trainer);
    assert!(
        tv_after < tv_before - 0.05,
        "training should reduce TV: {tv_before:.3} -> {tv_after:.3}"
    );
}

#[test]
fn db_objective_trains() {
    let env = small_env();
    match artifacts_dir() {
        Some(dir) => {
            // xla covers subtb through the artifact graphs.
            for loss in ["db", "subtb"] {
                let art = Artifact::load(&dir, &format!("hypergrid_small.{loss}")).unwrap();
                let trainer = Trainer::new(&env, &art, 11, EpsSchedule::none()).unwrap();
                check_db_style_trains(trainer, loss, 40);
            }
        }
        None => {
            // Native covers subtb too (margins pre-validated by numpy
            // simulation of the exact math, like the db case).
            for loss in ["db", "subtb"] {
                let trainer = Trainer::with_backend(
                    &env,
                    native_backend(&env, loss, 11),
                    11,
                    EpsSchedule::none(),
                )
                .unwrap();
                check_db_style_trains(trainer, loss, 300);
            }
        }
    }
}

fn check_db_style_trains<B: Backend>(
    mut trainer: Trainer<'_, HypergridEnv<HypergridReward>, B>,
    loss: &str,
    iters: usize,
) {
    let mut losses = Vec::new();
    for _ in 0..iters {
        let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
        assert!(stats.loss.is_finite(), "{loss} loss not finite");
        losses.push(stats.loss as f64);
    }
    let w = (iters / 4).max(1);
    let head = losses[..w].iter().sum::<f64>() / w as f64;
    let tail = losses[iters - w..].iter().sum::<f64>() / w as f64;
    assert!(tail < head, "{loss}: {head} -> {tail}");
}

#[test]
fn backward_rollouts_score_finite_and_invert() {
    with_any_backend(5, |env, backend| check_backward_scores(env, backend));
}

fn check_backward_scores<B: Backend + ?Sized>(env: &HypergridEnv<HypergridReward>, backend: &B) {
    let mut ctx = RolloutCtx::for_shape(&backend.shape());
    let mut rng = Rng::new(5);
    let mut policy = BackendPolicy { backend };
    // Build some terminal objects.
    let objs: Vec<Vec<i32>> = vec![vec![0, 0], vec![3, 7], vec![7, 7], vec![2, 5]];
    let scores =
        backward_rollout_score_with_policy(env, &mut policy, &mut ctx, &mut rng, &objs).unwrap();
    assert_eq!(scores.len(), objs.len());
    for (i, (log_pf, log_pb, len)) in scores.iter().enumerate() {
        assert!(log_pf.is_finite() && *log_pf <= 0.0);
        assert!(log_pb.is_finite() && *log_pb <= 1e-9);
        // Trajectory length = |coords|₁ + 1 (the stop-undo).
        let want = objs[i].iter().map(|&c| c as usize).sum::<usize>() + 1;
        assert_eq!(*len, want, "obj {i}");
    }
}

#[test]
fn log_p_theta_hat_normalizes_for_tiny_grid() {
    // For an *untrained* policy P̂_θ is still a distribution in expectation;
    // check Σ_x exp(log P̂_θ(x)) ≈ 1 over the full 64-state space with
    // enough samples (MC noise bounded).
    with_any_backend(6, |env, backend| check_p_theta_normalizes(env, backend));
}

fn check_p_theta_normalizes<B: Backend + ?Sized>(
    env: &HypergridEnv<HypergridReward>,
    backend: &B,
) {
    let mut ctx = RolloutCtx::for_shape(&backend.shape());
    let mut rng = Rng::new(6);
    let mut total = 0.0f64;
    for idx in 0..env.num_terminal_states() {
        let obj = env.unflatten(idx);
        let lp = log_p_theta_hat(env, backend, &mut ctx, &mut rng, &obj, 16).unwrap();
        total += lp.exp();
    }
    assert!(
        (total - 1.0).abs() < 0.25,
        "Σ P̂_θ = {total} (should be ≈ 1)"
    );
}

/// The init-blob contract: when artifacts exist, the native backend must be
/// able to start from the artifact's manifest + blob without touching any
/// HLO (the initialization-compatibility half of the backend abstraction).
#[test]
fn native_backend_loads_artifact_init_blobs() {
    let Some(dir) = artifacts_dir() else { return };
    let env = small_env();
    let backend = NativeBackend::from_artifact_files(&dir, "hypergrid_small.tb").unwrap();
    assert_eq!(backend.shape().batch, 16);
    assert_eq!(backend.loss_name(), "tb");
    // The loaded params drive a valid dispatch.
    check_policy_distributions(&env, &backend);
}
