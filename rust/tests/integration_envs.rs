//! Cross-module integration: every default artifact's manifest must agree
//! with the corresponding Rust environment spec (the shapes are defined
//! twice — configs.py and rust envs — and this test is the contract check),
//! and each (env, artifact) pair must run a full training iteration.

use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::VecEnv;
use gfnx::runtime::{Artifact, Manifest};
use std::path::PathBuf;

/// Artifacts are produced by `make artifacts` (JAX AOT lowering) and are
/// not checked in; these tests skip gracefully when they are absent.
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("hypergrid_small.tb.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: AOT artifacts missing — run `make artifacts` AND build \
             against the real xla-rs crate (see rust/vendor/README.md) to enable"
        );
        None
    }
}

fn check_spec<E: VecEnv>(env: &E, manifest: &Manifest) {
    let spec = env.spec();
    let cfg = &manifest.config;
    assert_eq!(spec.obs_dim, cfg.obs_dim, "{}: obs_dim", manifest.name);
    assert_eq!(spec.n_actions, cfg.n_actions, "{}: n_actions", manifest.name);
    assert_eq!(
        spec.n_bwd_actions, cfg.n_bwd_actions,
        "{}: n_bwd_actions",
        manifest.name
    );
    assert_eq!(spec.t_max, cfg.t_max, "{}: t_max", manifest.name);
}

#[test]
fn hypergrid_manifests_match_env_specs() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::hypergrid::HypergridEnv;
    use gfnx::reward::hypergrid::HypergridReward;
    for (name, d, h) in [
        ("hypergrid_small.tb", 2usize, 8usize),
        ("hypergrid_2d_20.tb", 2, 20),
        ("hypergrid_4d_20.tb", 4, 20),
        ("hypergrid_8d_10.tb", 8, 10),
    ] {
        let m = Manifest::load(&dir, name).unwrap();
        let env = HypergridEnv::new(d, h, HypergridReward::standard(h));
        check_spec(&env, &m);
    }
}

#[test]
fn bitseq_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::bitseq::{bitseq_env, BitSeqConfig};
    let (env, _modes) = bitseq_env(BitSeqConfig::small());
    let art = Artifact::load(&dir, "bitseq_small.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 1, EpsSchedule::Constant(1e-3)).unwrap();
    let (stats, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(objs.len(), art.batch());
    // Non-autoregressive: every object is fully filled.
    for o in &objs {
        assert!(o.iter().all(|&t| t >= 0));
    }
}

#[test]
fn tfbind8_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::tfbind8::tfbind8_env;
    let env = tfbind8_env(0, 10.0);
    let art = Artifact::load(&dir, "tfbind8.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 2, EpsSchedule::Constant(0.5)).unwrap();
    let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 8.0); // fixed length
}

#[test]
fn qm9_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::qm9::qm9_env;
    let env = qm9_env(0, 10.0);
    let art = Artifact::load(&dir, "qm9.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 3, EpsSchedule::Constant(0.5)).unwrap();
    let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 5.0);
}

#[test]
fn amp_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::amp::amp_env_sized;
    let env = amp_env_sized(0, 1e-3, 8);
    let art = Artifact::load(&dir, "amp_small.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 4, EpsSchedule::Constant(1e-2)).unwrap();
    let (stats, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    // Variable length objects.
    assert!(objs.iter().any(|o| o.len() < 8) || objs.iter().any(|o| o.len() == 8));
}

#[test]
fn phylo_manifest_matches_and_trains_fldb() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::data::phylo_data::synthetic_alignment;
    use gfnx::envs::phylo::PhyloEnv;
    use gfnx::util::rng::Rng;
    let mut rng = Rng::new(7);
    let aln = synthetic_alignment(6, 8, 0.15, &mut rng);
    let env = PhyloEnv::new(aln, 16.0, 4.0);
    let art = Artifact::load(&dir, "phylo_small.fldb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 5, EpsSchedule::Constant(0.5)).unwrap();
    let energy = |s: &<PhyloEnv as VecEnv>::State, i: usize| trainer.env.energy(s, i);
    // Borrow rules: build the closure from a fresh env reference instead.
    let env_ref = trainer.env;
    let extra = ExtraSource::Energy(&move |s, i| env_ref.energy(s, i));
    let (stats, objs) = trainer.train_iter(&extra).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 5.0); // n − 1 merges
    for o in objs {
        assert_eq!(o.leaf_count(), 6);
    }
    let _ = energy;
}

#[test]
fn bayesnet_manifest_matches_and_trains_mdb() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::data::ancestral::ancestral_sample;
    use gfnx::data::erdos_renyi::sample_er_dag;
    use gfnx::envs::bayesnet::BayesNetEnv;
    use gfnx::reward::lingauss::lingauss_table;
    use gfnx::util::rng::Rng;
    let mut rng = Rng::new(8);
    let g = sample_er_dag(5, 1.0, &mut rng);
    let data = ancestral_sample(&g, 100, 0.1, &mut rng);
    let table = lingauss_table(&data, 0.1, 1.0);
    let env = BayesNetEnv::new(5, table.clone());
    let art = Artifact::load(&dir, "bayesnet_d5.mdb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 6, EpsSchedule::Constant(0.5)).unwrap();
    let table_ref = &table;
    let extra = ExtraSource::StateLogReward(&move |s: &gfnx::envs::bayesnet::BayesNetState, i: usize| {
        table_ref.log_score(s.adj[i])
    });
    let (stats, objs) = trainer.train_iter(&extra).unwrap();
    assert!(stats.loss.is_finite());
    for o in objs {
        assert!(gfnx::envs::bayesnet::is_acyclic(o, 5));
    }
}

#[test]
fn ising_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::ising::IsingEnv;
    use gfnx::reward::ising::IsingReward;
    let env = IsingEnv::lattice(3, IsingReward::torus(3, 0.2));
    let art = Artifact::load(&dir, "ising_small.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 7, EpsSchedule::none()).unwrap();
    let (stats, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 9.0);
    for o in objs {
        assert!(o.iter().all(|&s| s == 1 || s == -1));
    }
}
