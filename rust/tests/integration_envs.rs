//! Cross-module integration: every default artifact's manifest must agree
//! with the corresponding Rust environment spec (the shapes are defined
//! twice — configs.py and rust envs — and this test is the contract check),
//! and each (env, artifact) pair must run a full training iteration.

use gfnx::coordinator::explore::EpsSchedule;
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::VecEnv;
use gfnx::runtime::{Artifact, Manifest};
use std::path::PathBuf;

/// Artifacts are produced by `make artifacts` (JAX AOT lowering) and are
/// not checked in; these tests skip gracefully when they are absent.
fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("hypergrid_small.tb.manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: AOT artifacts missing — run `make artifacts` AND build \
             against the real xla-rs crate (see rust/vendor/README.md) to enable"
        );
        None
    }
}

fn check_spec<E: VecEnv>(env: &E, manifest: &Manifest) {
    let spec = env.spec();
    let cfg = &manifest.config;
    assert_eq!(spec.obs_dim, cfg.obs_dim, "{}: obs_dim", manifest.name);
    assert_eq!(spec.n_actions, cfg.n_actions, "{}: n_actions", manifest.name);
    assert_eq!(
        spec.n_bwd_actions, cfg.n_bwd_actions,
        "{}: n_bwd_actions",
        manifest.name
    );
    assert_eq!(spec.t_max, cfg.t_max, "{}: t_max", manifest.name);
}

#[test]
fn hypergrid_manifests_match_env_specs() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::hypergrid::HypergridEnv;
    use gfnx::reward::hypergrid::HypergridReward;
    for (name, d, h) in [
        ("hypergrid_small.tb", 2usize, 8usize),
        ("hypergrid_2d_20.tb", 2, 20),
        ("hypergrid_4d_20.tb", 4, 20),
        ("hypergrid_8d_10.tb", 8, 10),
    ] {
        let m = Manifest::load(&dir, name).unwrap();
        let env = HypergridEnv::new(d, h, HypergridReward::standard(h));
        check_spec(&env, &m);
    }
}

#[test]
fn bitseq_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::bitseq::{bitseq_env, BitSeqConfig};
    let (env, _modes) = bitseq_env(BitSeqConfig::small());
    let art = Artifact::load(&dir, "bitseq_small.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 1, EpsSchedule::Constant(1e-3)).unwrap();
    let (stats, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(objs.len(), art.batch());
    // Non-autoregressive: every object is fully filled.
    for o in &objs {
        assert!(o.iter().all(|&t| t >= 0));
    }
}

#[test]
fn tfbind8_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::tfbind8::tfbind8_env;
    let env = tfbind8_env(0, 10.0);
    let art = Artifact::load(&dir, "tfbind8.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 2, EpsSchedule::Constant(0.5)).unwrap();
    let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 8.0); // fixed length
}

#[test]
fn qm9_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::qm9::qm9_env;
    let env = qm9_env(0, 10.0);
    let art = Artifact::load(&dir, "qm9.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 3, EpsSchedule::Constant(0.5)).unwrap();
    let (stats, _) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 5.0);
}

#[test]
fn amp_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::amp::amp_env_sized;
    let env = amp_env_sized(0, 1e-3, 8);
    let art = Artifact::load(&dir, "amp_small.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 4, EpsSchedule::Constant(1e-2)).unwrap();
    let (stats, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    // Variable length objects.
    assert!(objs.iter().any(|o| o.len() < 8) || objs.iter().any(|o| o.len() == 8));
}

#[test]
fn phylo_manifest_matches_and_trains_fldb() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::data::phylo_data::synthetic_alignment;
    use gfnx::envs::phylo::PhyloEnv;
    use gfnx::util::rng::Rng;
    let mut rng = Rng::new(7);
    let aln = synthetic_alignment(6, 8, 0.15, &mut rng);
    let env = PhyloEnv::new(aln, 16.0, 4.0);
    let art = Artifact::load(&dir, "phylo_small.fldb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 5, EpsSchedule::Constant(0.5)).unwrap();
    let energy = |s: &<PhyloEnv as VecEnv>::State, i: usize| trainer.env.energy(s, i);
    // Borrow rules: build the closure from a fresh env reference instead.
    let env_ref = trainer.env;
    let extra = ExtraSource::Energy(&move |s, i| env_ref.energy(s, i));
    let (stats, objs) = trainer.train_iter(&extra).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 5.0); // n − 1 merges
    for o in objs {
        assert_eq!(o.leaf_count(), 6);
    }
    let _ = energy;
}

#[test]
fn bayesnet_manifest_matches_and_trains_mdb() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::data::ancestral::ancestral_sample;
    use gfnx::data::erdos_renyi::sample_er_dag;
    use gfnx::envs::bayesnet::BayesNetEnv;
    use gfnx::reward::lingauss::lingauss_table;
    use gfnx::util::rng::Rng;
    let mut rng = Rng::new(8);
    let g = sample_er_dag(5, 1.0, &mut rng);
    let data = ancestral_sample(&g, 100, 0.1, &mut rng);
    let table = lingauss_table(&data, 0.1, 1.0);
    let env = BayesNetEnv::new(5, table.clone());
    let art = Artifact::load(&dir, "bayesnet_d5.mdb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 6, EpsSchedule::Constant(0.5)).unwrap();
    let table_ref = &table;
    let extra = ExtraSource::StateLogReward(&move |s: &gfnx::envs::bayesnet::BayesNetState, i: usize| {
        table_ref.log_score(s.adj[i])
    });
    let (stats, objs) = trainer.train_iter(&extra).unwrap();
    assert!(stats.loss.is_finite());
    for o in objs {
        assert!(gfnx::envs::bayesnet::is_acyclic(o, 5));
    }
}

#[test]
fn ising_manifest_matches_and_trains() {
    let Some(dir) = artifacts_dir() else { return };
    use gfnx::envs::ising::IsingEnv;
    use gfnx::reward::ising::IsingReward;
    let env = IsingEnv::lattice(3, IsingReward::torus(3, 0.2));
    let art = Artifact::load(&dir, "ising_small.tb").unwrap();
    check_spec(&env, &art.manifest);
    let mut trainer = Trainer::new(&env, &art, 7, EpsSchedule::none()).unwrap();
    let (stats, objs) = trainer.train_iter(&ExtraSource::None).unwrap();
    assert!(stats.loss.is_finite());
    assert_eq!(stats.mean_length, 9.0);
    for o in objs {
        assert!(o.iter().all(|&s| s == 1 || s == -1));
    }
}

// ---------------------------------------------------------------------------
// Registry-driven, artifact-free suites (no `make artifacts` needed)
// ---------------------------------------------------------------------------

use gfnx::coordinator::registry::{self, EnvDriver, EnvFamily, EnvParams};
use gfnx::coordinator::rollout::{forward_rollout_with_policy, RolloutCtx};
use gfnx::runtime::policy::{PolicyShape, UniformPolicy};
use gfnx::runtime::{NativeBackend, NativeConfig};
use gfnx::util::rng::Rng;

/// The VecEnv conformance suite (reset/reset_row equivalence, step-mask
/// consistency, forward/backward inversion, inject/extract round-trips,
/// TrajBatch sentinel padding + zero extras, forward→backward replay
/// round-trip) over the default config of **all nine** registered
/// environment families.
#[test]
fn conformance_suite_covers_all_nine_envs() {
    struct Conformance;
    impl EnvDriver for Conformance {
        type Out = ();
        fn drive<E>(
            self,
            env: &E,
            _extra: &ExtraSource<'_, E>,
            fam: &'static EnvFamily,
            _config: &str,
        ) -> anyhow::Result<()>
        where
            E: VecEnv,
            E::State: Clone,
            E::Obj: PartialEq + std::fmt::Debug,
        {
            // Name-hashed seed so every family's suite explores distinct
            // walks (a length-based offset collides across families).
            let seed = fam
                .name
                .bytes()
                .fold(1000u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
            gfnx::testing::check_vec_env(env, 8, seed);
            Ok(())
        }
    }
    let fams = registry::families();
    assert_eq!(fams.len(), 9, "the registry must cover all nine environments");
    for f in fams {
        registry::with_env(f.default_config, EnvParams::default(), Conformance)
            .unwrap_or_else(|e| panic!("{}: {e}", f.name));
    }
}

/// Every registered family trains artifact-free on the native backend with
/// every objective the registry lists for it — the in-test form of
/// `cargo run -- train --env <E> --loss <L> --backend native` (extras
/// included: phylo trains fldb, bayesnet trains mdb).
#[test]
fn every_family_trains_every_registered_loss_natively() {
    struct TrainProbe;
    impl EnvDriver for TrainProbe {
        type Out = ();
        fn drive<E>(
            self,
            env: &E,
            extra: &ExtraSource<'_, E>,
            fam: &'static EnvFamily,
            config: &str,
        ) -> anyhow::Result<()>
        where
            E: VecEnv,
            E::State: Clone,
            E::Obj: PartialEq + std::fmt::Debug,
        {
            use gfnx::coordinator::explore::EpsSchedule;
            for loss in fam.losses {
                let cfg = NativeConfig::for_env(env, 4, loss).with_hidden(16);
                let backend = NativeBackend::new(cfg, 5).unwrap();
                let mut trainer =
                    Trainer::with_backend(env, backend, 5, EpsSchedule::Constant(0.1))
                        .unwrap();
                for _ in 0..2 {
                    let (stats, objs) = trainer
                        .train_iter(extra)
                        .unwrap_or_else(|e| panic!("{config}.{loss}: {e}"));
                    assert!(stats.loss.is_finite(), "{config}.{loss}: loss not finite");
                    assert_eq!(objs.len(), 4);
                }
            }
            Ok(())
        }
    }
    for f in registry::families() {
        registry::with_env(f.default_config, EnvParams::default(), TrainProbe)
            .unwrap_or_else(|e| panic!("{}: {e}", f.name));
    }
}

/// Every registered family also trains through the **asynchronous
/// actor–learner engine** with every objective the registry lists — the
/// in-test form of `train --env <E> --loss <L> --actors 2`, covering the
/// actor-side snapshot dispatch, the Sync extra sources (phylo fldb,
/// bayesnet mdb) and the learner-side MDB delta conversion for all nine
/// families.
#[test]
fn every_family_trains_every_registered_loss_through_the_engine() {
    struct EngineProbe;
    impl EnvDriver for EngineProbe {
        type Out = ();
        fn drive<E>(
            self,
            env: &E,
            extra: &ExtraSource<'_, E>,
            fam: &'static EnvFamily,
            config: &str,
        ) -> anyhow::Result<()>
        where
            E: VecEnv + Clone + Send + Sync + 'static,
            E::State: Clone,
            E::Obj: PartialEq + std::fmt::Debug + Send + 'static,
        {
            use gfnx::coordinator::explore::EpsSchedule;
            use gfnx::engine::{self, EngineConfig};
            for loss in fam.losses {
                let cfg = NativeConfig::for_env(env, 4, loss).with_hidden(16);
                let mut backend = NativeBackend::new(cfg, 7).unwrap();
                let stats = engine::train(
                    env,
                    &mut backend,
                    EpsSchedule::Constant(0.1),
                    extra,
                    &EngineConfig::new(2, 2, 7),
                    6,
                    |_| Ok(()),
                )
                .unwrap_or_else(|e| panic!("{config}.{loss} (engine): {e}"));
                assert_eq!(stats.iters, 6, "{config}.{loss}: engine step count");
                assert!(
                    stats.losses.iter().all(|l| l.is_finite()),
                    "{config}.{loss}: engine loss not finite"
                );
                assert_eq!(
                    stats.batches_per_actor.iter().sum::<u64>(),
                    6,
                    "{config}.{loss}: batch accounting"
                );
            }
            Ok(())
        }
    }
    for f in registry::families() {
        registry::with_env(f.default_config, EnvParams::default(), EngineProbe)
            .unwrap_or_else(|e| panic!("{}: {e}", f.name));
    }
}

/// Regression for the PR 1 stale-staging bug class, extras edition: with a
/// live `ExtraSource`, rows that finish early must end with the
/// *terminal* value in every padding slot (never a stale value from a
/// later staging of other rows), and every real slot must hold exactly
/// E(s_t) of the replayed trajectory.
#[test]
fn extra_channels_hold_exact_per_state_values_and_terminal_padding() {
    use gfnx::envs::hypergrid::HypergridEnv;
    use gfnx::reward::hypergrid::HypergridReward;
    let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
    let spec = env.spec();
    let b = 16; // heterogeneous lengths across the batch
    let shape = PolicyShape::of_env(&env, b);
    let mut policy = UniformPolicy::new(shape);
    let mut ctx = RolloutCtx::for_shape(&shape);
    let mut rng = Rng::new(31);
    let energy = |s: &<HypergridEnv<HypergridReward> as VecEnv>::State, i: usize| {
        1.0 + 0.5 * s.coords_of(i).iter().map(|&c| c as f64).sum::<f64>()
    };
    let (batch, objs) = forward_rollout_with_policy(
        &env, &mut policy, &mut ctx, &mut rng, 0.3, &ExtraSource::Energy(&energy),
    )
    .unwrap();
    assert!(
        batch.length.iter().any(|&l| (l as usize) < spec.t_max),
        "need at least one early-terminating row for the padding check"
    );
    for i in 0..b {
        let len = batch.length[i] as usize;
        // Replay the recorded actions to recover E(s_t) at every slot.
        let mut st = env.reset(1);
        for t in 0..=len {
            let want = energy(&st, 0) as f32;
            assert!(
                (batch.extra[i * batch.t1 + t] - want).abs() < 1e-6,
                "row {i} slot {t}: extra {} != E(s_t) {want}",
                batch.extra[i * batch.t1 + t]
            );
            if t < len {
                env.step(&mut st, &[batch.fwd_actions[i * (batch.t1 - 1) + t]]);
            }
        }
        // Padding slots repeat the terminal energy exactly.
        let term = 1.0 + 0.5 * objs[i].iter().map(|&c| c as f32).sum::<f32>();
        for t in len..batch.t1 {
            assert!(
                (batch.extra[i * batch.t1 + t] - term).abs() < 1e-6,
                "row {i} slot {t}: padded extra must be the terminal value"
            );
        }
    }
}

/// Replay batches accept MDB on its real environment: a frac = 1.0
/// bayesnet replay batch carries per-state log-scores in `extra` and is
/// bitwise-deterministic in seed + buffer (the fldb twin lives in
/// `coordinator::trainer`'s unit tests).
#[test]
fn bayesnet_mdb_replay_is_deterministic_with_real_extras() {
    struct MdbReplay;
    impl EnvDriver for MdbReplay {
        type Out = ();
        fn drive<E>(
            self,
            env: &E,
            extra: &ExtraSource<'_, E>,
            _fam: &'static EnvFamily,
            _config: &str,
        ) -> anyhow::Result<()>
        where
            E: VecEnv,
            E::State: Clone,
            E::Obj: PartialEq + std::fmt::Debug,
        {
            use gfnx::coordinator::explore::EpsSchedule;
            use gfnx::coordinator::trainer::ReplayConfig;
            // Bank terminal objects from an on-policy warmup trainer, then
            // compare two frac = 1.0 replay assemblies at the same seed.
            let assemble = |seed: u64| {
                let mk = || {
                    let cfg = NativeConfig::for_env(env, 4, "mdb").with_hidden(16);
                    NativeBackend::new(cfg, seed).unwrap()
                };
                let mut warm =
                    Trainer::with_backend(env, mk(), seed, EpsSchedule::none()).unwrap();
                let (_, warm_objs, _) = warm.assemble_batch(extra).unwrap();
                let mut tr = Trainer::with_backend(env, mk(), seed, EpsSchedule::none())
                    .unwrap()
                    .with_replay(ReplayConfig::new(16, 1.0))
                    .unwrap();
                tr.seed_replay(warm_objs).unwrap();
                let (batch, objs, replayed) = tr.assemble_batch(extra).unwrap();
                assert!(replayed, "frac = 1.0 with a warm buffer must replay");
                (batch, objs)
            };
            let (a, objs_a) = assemble(7);
            let (b, objs_b) = assemble(7);
            assert_eq!(objs_a, objs_b);
            assert_eq!(a.fwd_actions, b.fwd_actions);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.extra), bits(&b.extra));
            assert_eq!(bits(&a.obs), bits(&b.obs));
            // Real extras: the per-state log-scores are not all zero.
            assert!(
                a.extra.iter().any(|&x| x != 0.0),
                "mdb replay batch must carry real log-score extras"
            );
            // And MDB trains on a replayed batch end-to-end.
            let cfg = NativeConfig::for_env(env, 4, "mdb").with_hidden(16);
            let backend = NativeBackend::new(cfg, 7).unwrap();
            let mut tr = Trainer::with_backend(env, backend, 7, EpsSchedule::none())
                .unwrap()
                .with_replay(ReplayConfig::new(16, 1.0))
                .unwrap();
            tr.seed_replay(objs_a).unwrap();
            let (stats, _) = tr.train_iter(extra).unwrap();
            assert!(stats.loss.is_finite(), "mdb replay train step not finite");
            Ok(())
        }
    }
    registry::with_env("bayesnet_d5", EnvParams::default(), MdbReplay).unwrap();
}
