//! Ancestral sampling from a linear-Gaussian Bayesian network
//! (paper eq. (14)): X_j | Pa(X_j) ~ N( Σ w_ij X_i , σ_j² ), nodes visited
//! in topological order.

use super::erdos_renyi::GroundTruthDag;
use crate::util::linalg::Mat;
use crate::util::rng::Rng;

/// Sample `n` observations; returns an `n × d` data matrix.
pub fn ancestral_sample(g: &GroundTruthDag, n: usize, noise_var: f64, rng: &mut Rng) -> Mat {
    let d = g.d;
    let std = noise_var.sqrt();
    let mut data = Mat::zeros(n, d);
    for s in 0..n {
        for &v in &g.order {
            let mut mean = 0.0;
            for u in 0..d {
                if g.adj & (1u64 << (u * d + v)) != 0 {
                    mean += g.weights[u * d + v] * data.get(s, u);
                }
            }
            data.set(s, v, mean + std * rng.normal());
        }
    }
    data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::erdos_renyi::sample_er_dag;

    #[test]
    fn roots_have_noise_variance() {
        let mut rng = Rng::new(0);
        // Build a fixed chain 0→1 manually.
        let d = 2;
        let g = GroundTruthDag {
            d,
            adj: 1u64 << (0 * d + 1),
            weights: {
                let mut w = vec![0.0; 4];
                w[0 * d + 1] = 2.0;
                w
            },
            order: vec![0, 1],
        };
        let n = 50_000;
        let data = ancestral_sample(&g, n, 0.1, &mut rng);
        let mean0: f64 = (0..n).map(|s| data.get(s, 0)).sum::<f64>() / n as f64;
        let var0: f64 =
            (0..n).map(|s| (data.get(s, 0) - mean0).powi(2)).sum::<f64>() / n as f64;
        assert!(mean0.abs() < 0.01, "{mean0}");
        assert!((var0 - 0.1).abs() < 0.01, "{var0}");
        // Child: X1 = 2 X0 + ε ⇒ Var = 4·0.1 + 0.1 = 0.5.
        let mean1: f64 = (0..n).map(|s| data.get(s, 1)).sum::<f64>() / n as f64;
        let var1: f64 =
            (0..n).map(|s| (data.get(s, 1) - mean1).powi(2)).sum::<f64>() / n as f64;
        assert!((var1 - 0.5).abs() < 0.03, "{var1}");
    }

    #[test]
    fn shapes_and_determinism() {
        let mut rng1 = Rng::new(5);
        let g = sample_er_dag(5, 1.0, &mut rng1);
        let mut ra = Rng::new(9);
        let mut rb = Rng::new(9);
        let a = ancestral_sample(&g, 100, 0.1, &mut ra);
        let b = ancestral_sample(&g, 100, 0.1, &mut rb);
        assert_eq!(a.rows, 100);
        assert_eq!(a.cols, 5);
        assert_eq!(a.data, b.data);
    }
}
