//! Dataset and workload generators: everything the paper's experiments read
//! from disk or from proprietary sources is generated here, deterministically
//! from seeds (see DESIGN.md §3 for the substitution rationale).

pub mod modes;
pub mod erdos_renyi;
pub mod ancestral;
pub mod ising_mcmc;
pub mod phylo_data;
