//! Bit-sequence mode-set and test-set generation (Malkin et al. 2022
//! protocol, as used by gfnx appendix B.2).
//!
//! Modes are built by concatenating n/8 elements drawn with replacement from
//! the fixed 8-bit alphabet H; the evaluation test set takes every mode and
//! flips i random bits for each 0 ≤ i < n.

use crate::util::rng::Rng;

/// The fixed 8-bit building blocks H from the paper.
pub const H_BLOCKS: [[u8; 8]; 5] = [
    [0, 0, 0, 0, 0, 0, 0, 0],
    [1, 1, 1, 1, 1, 1, 1, 1],
    [1, 1, 1, 1, 0, 0, 0, 0],
    [0, 0, 0, 0, 1, 1, 1, 1],
    [0, 0, 1, 1, 1, 1, 0, 0],
];

/// Generate `m` modes of `n_bits` each (n_bits must be divisible by 8).
pub fn generate_modes(n_bits: usize, m: usize, rng: &mut Rng) -> Vec<Vec<u8>> {
    assert!(n_bits % 8 == 0, "mode length must be a multiple of 8");
    let blocks = n_bits / 8;
    (0..m)
        .map(|_| {
            let mut bits = Vec::with_capacity(n_bits);
            for _ in 0..blocks {
                bits.extend_from_slice(&H_BLOCKS[rng.below(H_BLOCKS.len())]);
            }
            bits
        })
        .collect()
}

/// Build the correlation test set: for every mode and every 0 ≤ i < n, flip
/// i distinct random bits. Returns |modes|·n bit strings.
pub fn generate_test_set(modes: &[Vec<u8>], rng: &mut Rng) -> Vec<Vec<u8>> {
    let n = modes.first().map_or(0, |m| m.len());
    let mut out = Vec::with_capacity(modes.len() * n);
    for mode in modes {
        for i in 0..n {
            let mut x = mode.clone();
            for pos in rng.choose_k(n, i) {
                x[pos] ^= 1;
            }
            out.push(x);
        }
    }
    out
}

/// Convert a bit string into k-bit tokens (low bit first within a token),
/// matching [`crate::reward::hamming::pack_tokens`].
pub fn bits_to_tokens(bits: &[u8], k: usize) -> Vec<i16> {
    assert!(bits.len() % k == 0);
    bits.chunks(k)
        .map(|chunk| {
            let mut v = 0i16;
            for (j, &b) in chunk.iter().enumerate() {
                if b != 0 {
                    v |= 1 << j;
                }
            }
            v
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reward::hamming::{hamming_packed, pack_tokens};

    #[test]
    fn modes_have_right_shape() {
        let mut rng = Rng::new(0);
        let modes = generate_modes(120, 60, &mut rng);
        assert_eq!(modes.len(), 60);
        assert!(modes.iter().all(|m| m.len() == 120));
        assert!(modes.iter().all(|m| m.iter().all(|&b| b <= 1)));
    }

    #[test]
    fn modes_are_block_structured() {
        let mut rng = Rng::new(1);
        let modes = generate_modes(24, 10, &mut rng);
        for m in &modes {
            for chunk in m.chunks(8) {
                assert!(
                    H_BLOCKS.iter().any(|h| h == chunk),
                    "chunk not from H: {chunk:?}"
                );
            }
        }
    }

    #[test]
    fn test_set_flip_counts() {
        let mut rng = Rng::new(2);
        let modes = generate_modes(16, 3, &mut rng);
        let test = generate_test_set(&modes, &mut rng);
        assert_eq!(test.len(), 3 * 16);
        // The i-th element of each mode's block differs in exactly i bits.
        for (mi, mode) in modes.iter().enumerate() {
            for i in 0..16 {
                let x = &test[mi * 16 + i];
                let d: usize = x.iter().zip(mode).filter(|(a, b)| a != b).count();
                assert_eq!(d, i);
            }
        }
    }

    #[test]
    fn bits_tokens_roundtrip_via_packing() {
        let bits: Vec<u8> = vec![1, 0, 1, 1, 0, 0, 1, 0, 1, 1, 1, 1, 0, 0, 0, 0];
        let tokens = bits_to_tokens(&bits, 4);
        let packed = pack_tokens(&tokens, 4);
        // Direct packing of the raw bits must agree.
        let mut direct = vec![0u64; 1];
        for (i, &b) in bits.iter().enumerate() {
            if b != 0 {
                direct[0] |= 1 << i;
            }
        }
        assert_eq!(hamming_packed(&packed, &direct), 0);
    }
}
