//! Erdős–Rényi ground-truth DAG sampling (paper §B.4 dataset generation):
//! a random topological order plus i.i.d. edge inclusion with probability
//! chosen so the expected in-degree matches the requested value.

use crate::util::rng::Rng;

/// A sampled ground-truth DAG with edge weights for the linear-Gaussian
/// generative model.
#[derive(Clone, Debug)]
pub struct GroundTruthDag {
    pub d: usize,
    /// Adjacency bitmask (bit u·d + v = edge u→v), acyclic by construction.
    pub adj: u64,
    /// Edge weights w[u·d + v] (N(0,1) draws; 0 where no edge).
    pub weights: Vec<f64>,
    /// Topological order used at sampling time.
    pub order: Vec<usize>,
}

/// Sample a DAG over `d ≤ 8` nodes with the given expected in-degree.
pub fn sample_er_dag(d: usize, expected_in_degree: f64, rng: &mut Rng) -> GroundTruthDag {
    assert!(d >= 2 && d <= 8);
    // Expected in-degree k with (d-1)/2 expected predecessors per node in a
    // uniform random order ⇒ inclusion probability 2k/(d-1), clamped.
    let p = (2.0 * expected_in_degree / (d as f64 - 1.0)).min(1.0);
    let mut order: Vec<usize> = (0..d).collect();
    rng.shuffle(&mut order);
    let mut adj = 0u64;
    let mut weights = vec![0.0; d * d];
    for i in 0..d {
        for j in (i + 1)..d {
            if rng.bernoulli(p) {
                let (u, v) = (order[i], order[j]);
                adj |= 1u64 << (u * d + v);
                weights[u * d + v] = rng.normal();
            }
        }
    }
    GroundTruthDag { d, adj, weights, order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::bayesnet::is_acyclic;
    use crate::testing::forall;

    #[test]
    fn sampled_graphs_are_acyclic() {
        forall("ER DAGs acyclic", 200, |rng| {
            let d = 2 + rng.below(7);
            let g = sample_er_dag(d, 1.0, rng);
            assert!(is_acyclic(g.adj, d));
        });
    }

    #[test]
    fn expected_edge_count_close() {
        let mut rng = Rng::new(0);
        let d = 5;
        let trials = 3000;
        let mut total = 0u64;
        for _ in 0..trials {
            total += sample_er_dag(d, 1.0, &mut rng).adj.count_ones() as u64;
        }
        // Expected edges = d · in-degree = 5.
        let mean = total as f64 / trials as f64;
        assert!((mean - 5.0).abs() < 0.2, "mean edges = {mean}");
    }

    #[test]
    fn weights_only_on_edges() {
        let mut rng = Rng::new(1);
        let g = sample_er_dag(6, 1.0, &mut rng);
        for u in 0..6 {
            for v in 0..6 {
                let has = g.adj & (1 << (u * 6 + v)) != 0;
                assert_eq!(g.weights[u * 6 + v] != 0.0, has);
            }
        }
    }
}
