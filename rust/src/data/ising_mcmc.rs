//! MCMC samplers for Ising ground-truth datasets (paper §B.5): the Wolff
//! cluster algorithm (Wang & Swendsen 1990) for ferromagnetic couplings and
//! heat-bath sweeps with parallel tempering (Hukushima & Nemoto 1996) for
//! the general case. These generate the "true data samples" the EB-GFN
//! experiment learns J from.

use crate::util::linalg::Mat;
use crate::util::rng::Rng;

/// Neighbour lists of the N×N torus (each site: 4 distinct neighbours for
/// N ≥ 3).
pub fn torus_neighbors(n: usize) -> Vec<Vec<usize>> {
    let idx = |r: usize, c: usize| (r % n) * n + (c % n);
    let mut nb = vec![Vec::new(); n * n];
    for r in 0..n {
        for c in 0..n {
            let i = idx(r, c);
            for j in [idx(r + 1, c), idx(r + n - 1, c), idx(r, c + 1), idx(r, c + n - 1)] {
                if j != i && !nb[i].contains(&j) {
                    nb[i].push(j);
                }
            }
        }
    }
    nb
}

/// One Wolff cluster update for a uniform-coupling lattice Ising model with
/// P(x) ∝ exp(x' (σA) x) (i.e. bond strength 2σ between neighbours —
/// the quadratic form counts each edge twice). Requires σ > 0; use
/// [`gauge_flip`] to map antiferromagnetic torus models onto this case.
pub fn wolff_step(spins: &mut [i8], neighbors: &[Vec<usize>], sigma: f64, rng: &mut Rng) {
    debug_assert!(sigma > 0.0);
    let p_add = 1.0 - (-4.0 * sigma).exp(); // bond activation probability
    let seed = rng.below(spins.len());
    let s0 = spins[seed];
    let mut stack = vec![seed];
    let mut in_cluster = vec![false; spins.len()];
    in_cluster[seed] = true;
    while let Some(u) = stack.pop() {
        for &v in &neighbors[u] {
            if !in_cluster[v] && spins[v] == s0 && rng.bernoulli(p_add) {
                in_cluster[v] = true;
                stack.push(v);
            }
        }
    }
    for (i, inc) in in_cluster.iter().enumerate() {
        if *inc {
            spins[i] = -spins[i];
        }
    }
}

/// Checkerboard gauge transform: flips spins on odd sublattice sites. Maps
/// an antiferromagnetic torus model (σ < 0, even N) onto the ferromagnetic
/// one with |σ|. Self-inverse.
pub fn gauge_flip(spins: &mut [i8], n: usize) {
    for r in 0..n {
        for c in 0..n {
            if (r + c) % 2 == 1 {
                spins[r * n + c] = -spins[r * n + c];
            }
        }
    }
}

/// One heat-bath sweep for a general symmetric coupling matrix J with
/// target P(x) ∝ exp(xᵀJx / temp). Visits all sites in order.
pub fn heat_bath_sweep(spins: &mut [i8], j: &Mat, temp: f64, rng: &mut Rng) {
    let d = spins.len();
    for site in 0..d {
        // Local field: ΔlogP between +1 and -1 at this site = 4·h/temp
        // with h = Σ_c J[site][c]·x_c (J symmetric, diagonal zero).
        let mut h = 0.0;
        let row = j.row(site);
        for c in 0..d {
            if c != site {
                h += row[c] * spins[c] as f64;
            }
        }
        let p_up = 1.0 / (1.0 + (-4.0 * h / temp).exp());
        spins[site] = if rng.bernoulli(p_up) { 1 } else { -1 };
    }
}

/// Parallel-tempering sampler over a temperature ladder (T = 1 is the
/// target chain). Returns `n_samples` configurations from the T = 1 chain.
pub struct ParallelTempering {
    pub j: Mat,
    pub temps: Vec<f64>,
    chains: Vec<Vec<i8>>,
}

impl ParallelTempering {
    pub fn new(j: Mat, temps: Vec<f64>, rng: &mut Rng) -> Self {
        assert!((temps[0] - 1.0).abs() < 1e-12, "first ladder rung must be T=1");
        let d = j.rows;
        let chains = temps
            .iter()
            .map(|_| (0..d).map(|_| if rng.bernoulli(0.5) { 1i8 } else { -1 }).collect())
            .collect();
        ParallelTempering { j, temps, chains }
    }

    fn log_weight(&self, chain: usize) -> f64 {
        // log P_T(x) ∝ xᵀJx / T.
        let x = &self.chains[chain];
        let mut s = 0.0;
        for r in 0..self.j.rows {
            let row = self.j.row(r);
            let mut acc = 0.0;
            for c in 0..self.j.cols {
                acc += row[c] * x[c] as f64;
            }
            s += x[r] as f64 * acc;
        }
        s / self.temps[chain]
    }

    /// One PT round: a heat-bath sweep per chain + adjacent swap proposals.
    pub fn round(&mut self, rng: &mut Rng) {
        for (k, temp) in self.temps.clone().iter().enumerate() {
            heat_bath_sweep(&mut self.chains[k], &self.j, *temp, rng);
        }
        for k in 0..self.temps.len() - 1 {
            // Swap acceptance: exp((1/T_k − 1/T_{k+1})(E_{k+1} − E_k)) with
            // E = −xᵀJx; expressed via the cached log-weights.
            let lw_kk = self.log_weight(k);
            let lw_k1k1 = self.log_weight(k + 1);
            self.chains.swap(k, k + 1);
            let lw_kk_sw = self.log_weight(k);
            let lw_k1k1_sw = self.log_weight(k + 1);
            let log_acc = (lw_kk_sw + lw_k1k1_sw) - (lw_kk + lw_k1k1);
            if !(log_acc >= 0.0 || rng.uniform().ln() < log_acc) {
                self.chains.swap(k, k + 1); // reject: swap back
            }
        }
    }

    /// Draw samples from the target (T=1) chain with `thin` rounds between
    /// draws after `burn_in` rounds.
    pub fn sample(
        &mut self,
        n_samples: usize,
        burn_in: usize,
        thin: usize,
        rng: &mut Rng,
    ) -> Vec<Vec<i8>> {
        for _ in 0..burn_in {
            self.round(rng);
        }
        let mut out = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            for _ in 0..thin {
                self.round(rng);
            }
            out.push(self.chains[0].clone());
        }
        out
    }
}

/// Generate the paper's Ising dataset: N×N torus, J = σ·A_N, using Wolff
/// for σ > 0 (with gauge transform for σ < 0 on even N; PT fallback for odd
/// N antiferromagnets).
pub fn generate_ising_dataset(
    n: usize,
    sigma: f64,
    n_samples: usize,
    rng: &mut Rng,
) -> Vec<Vec<i8>> {
    let d = n * n;
    if sigma > 0.0 || n % 2 == 0 {
        let neighbors = torus_neighbors(n);
        let s = sigma.abs();
        let mut spins: Vec<i8> =
            (0..d).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect();
        let burn = 200;
        let thin = 5;
        for _ in 0..burn {
            wolff_step(&mut spins, &neighbors, s, rng);
        }
        let mut out = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            for _ in 0..thin {
                wolff_step(&mut spins, &neighbors, s, rng);
            }
            let mut x = spins.clone();
            if sigma < 0.0 {
                gauge_flip(&mut x, n); // map back to the AF model
            }
            out.push(x);
        }
        out
    } else {
        // Odd-N antiferromagnet (frustrated): general PT sampler.
        let mut j = crate::reward::ising::torus_adjacency(n);
        j.scale(sigma);
        let temps = vec![1.0, 1.5, 2.25, 3.4, 5.0];
        let mut pt = ParallelTempering::new(j, temps, rng);
        pt.sample(n_samples, 100, 3, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reward::ising::{ising_energy, torus_adjacency};
    use std::collections::HashMap;

    /// Exact distribution over all 2^D configurations (tiny lattices only).
    fn exact_distribution(j: &Mat) -> HashMap<Vec<i8>, f64> {
        let d = j.rows;
        let mut logs = Vec::new();
        let mut configs = Vec::new();
        for mask in 0u64..(1 << d) {
            let x: Vec<i8> =
                (0..d).map(|i| if mask >> i & 1 == 1 { 1i8 } else { -1 }).collect();
            logs.push(-ising_energy(j, &x));
            configs.push(x);
        }
        let probs = crate::util::stats::softmax_from_logs(&logs);
        configs.into_iter().zip(probs).collect()
    }

    fn empirical_tv(samples: &[Vec<i8>], exact: &HashMap<Vec<i8>, f64>) -> f64 {
        let mut counts: HashMap<&Vec<i8>, f64> = HashMap::new();
        for s in samples {
            *counts.entry(s).or_default() += 1.0 / samples.len() as f64;
        }
        let mut tv = 0.0;
        for (x, p) in exact {
            tv += (p - counts.get(x).copied().unwrap_or(0.0)).abs();
        }
        0.5 * tv
    }

    #[test]
    fn torus_neighbors_degree() {
        let nb = torus_neighbors(3);
        assert!(nb.iter().all(|v| v.len() == 4));
        let nb2 = torus_neighbors(2); // parallel edges collapse
        assert!(nb2.iter().all(|v| v.len() == 2));
    }

    #[test]
    fn heat_bath_matches_exact_2x2() {
        let mut rng = Rng::new(0);
        let mut j = torus_adjacency(2);
        j.scale(0.3);
        let mut spins = vec![1i8, 1, 1, 1];
        // Burn.
        for _ in 0..200 {
            heat_bath_sweep(&mut spins, &j, 1.0, &mut rng);
        }
        let mut samples = Vec::new();
        for _ in 0..20_000 {
            heat_bath_sweep(&mut spins, &j, 1.0, &mut rng);
            samples.push(spins.clone());
        }
        let exact = exact_distribution(&j);
        let tv = empirical_tv(&samples, &exact);
        assert!(tv < 0.03, "heat-bath TV = {tv}");
    }

    #[test]
    fn wolff_matches_exact_3x3() {
        let mut rng = Rng::new(1);
        let sigma = 0.15;
        let mut j = torus_adjacency(3);
        j.scale(sigma);
        let exact = exact_distribution(&j);
        let neighbors = torus_neighbors(3);
        let mut spins = vec![1i8; 9];
        for _ in 0..200 {
            wolff_step(&mut spins, &neighbors, sigma, &mut rng);
        }
        let mut samples = Vec::new();
        for _ in 0..40_000 {
            wolff_step(&mut spins, &neighbors, sigma, &mut rng);
            samples.push(spins.clone());
        }
        let tv = empirical_tv(&samples, &exact);
        assert!(tv < 0.05, "wolff TV = {tv}");
    }

    #[test]
    fn parallel_tempering_matches_exact_2x2() {
        let mut rng = Rng::new(2);
        let mut j = torus_adjacency(2);
        j.scale(-0.4); // antiferromagnetic
        let exact = exact_distribution(&j);
        let mut pt =
            ParallelTempering::new(j.clone(), vec![1.0, 2.0, 4.0], &mut rng);
        let samples = pt.sample(20_000, 100, 1, &mut rng);
        let tv = empirical_tv(&samples, &exact);
        assert!(tv < 0.04, "PT TV = {tv}");
    }

    #[test]
    fn gauge_flip_is_involution_and_maps_energy() {
        let n = 4;
        let mut rng = Rng::new(3);
        let mut x: Vec<i8> =
            (0..16).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect();
        let orig = x.clone();
        // Energy under +σ of flipped == energy under −σ of original.
        let mut jp = torus_adjacency(n);
        jp.scale(0.3);
        let mut jm = torus_adjacency(n);
        jm.scale(-0.3);
        let e_m = ising_energy(&jm, &x);
        gauge_flip(&mut x, n);
        let e_p = ising_energy(&jp, &x);
        assert!((e_m - e_p).abs() < 1e-12);
        gauge_flip(&mut x, n);
        assert_eq!(x, orig);
    }

    #[test]
    fn dataset_generator_shapes() {
        let mut rng = Rng::new(4);
        let ds = generate_ising_dataset(3, 0.2, 20, &mut rng);
        assert_eq!(ds.len(), 20);
        assert!(ds.iter().all(|x| x.len() == 9));
        assert!(ds.iter().all(|x| x.iter().all(|&s| s == 1 || s == -1)));
        // Antiferro odd-N path.
        let ds2 = generate_ising_dataset(3, -0.1, 5, &mut rng);
        assert_eq!(ds2.len(), 5);
    }
}
