//! Synthetic phylogenetic alignments (DESIGN.md §3: stand-in for the DS1–DS8
//! datasets of Zhou et al. 2024): evolve sequences down a random binary tree
//! with per-site mutation probability μ per edge, then return the leaf
//! alignment. This preserves the property that parsimony-optimal trees are
//! informative about the generating topology.

use crate::reward::parsimony::Alignment;
use crate::util::rng::Rng;

/// Generate an alignment of `n_species` × `n_sites` nucleotides.
pub fn synthetic_alignment(n_species: usize, n_sites: usize, mu: f64, rng: &mut Rng) -> Alignment {
    assert!(n_species >= 2);
    // Random root sequence.
    let root: Vec<u8> = (0..n_sites).map(|_| rng.below(4) as u8).collect();
    // Evolve down a random topology built by splitting a pool of lineages.
    let mut pool: Vec<Vec<u8>> = vec![root];
    while pool.len() < n_species {
        // Pick a random lineage, replace by two mutated children.
        let idx = rng.below(pool.len());
        let parent = pool.swap_remove(idx);
        pool.push(mutate(&parent, mu, rng));
        pool.push(mutate(&parent, mu, rng));
    }
    Alignment::new(pool)
}

fn mutate(seq: &[u8], mu: f64, rng: &mut Rng) -> Vec<u8> {
    seq.iter()
        .map(|&c| {
            if rng.bernoulli(mu) {
                // Substitute with a different nucleotide.
                let mut nc = rng.below(3) as u8;
                if nc >= c {
                    nc += 1;
                }
                nc
            } else {
                c
            }
        })
        .collect()
}

/// The eight scaled dataset configurations standing in for DS1–DS8.
/// (paper datasets have 27–64 species; we scale to CPU budget while keeping
/// the size *ordering* so the throughput table shows the same trend).
pub fn ds_config(ds: usize) -> (usize, usize) {
    // (n_species, n_sites)
    match ds {
        1 => (8, 32),
        2 => (10, 32),
        3 => (12, 40),
        4 => (12, 48),
        5 => (14, 48),
        6 => (16, 48),
        7 => (18, 64),
        8 => (20, 64),
        _ => panic!("DS index must be 1..=8"),
    }
}

/// Reward constant C per dataset (scaled analogue of the paper's table 6).
pub fn ds_reward_c(ds: usize) -> f64 {
    let (_, m) = ds_config(ds);
    // Roughly 2 mutations/site upper bound, mirroring C ≳ max parsimony.
    2.0 * m as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_shape() {
        let mut rng = Rng::new(0);
        let a = synthetic_alignment(8, 32, 0.15, &mut rng);
        assert_eq!(a.n_species(), 8);
        assert_eq!(a.n_sites, 32);
    }

    #[test]
    fn mutation_rate_reasonable() {
        let mut rng = Rng::new(1);
        let seq = vec![0u8; 10_000];
        let m = mutate(&seq, 0.2, &mut rng);
        let diff = m.iter().filter(|&&c| c != 0).count();
        let rate = diff as f64 / 10_000.0;
        assert!((rate - 0.2).abs() < 0.02, "{rate}");
        assert!(m.iter().all(|&c| c < 4));
    }

    #[test]
    fn related_species_are_similar() {
        // With low mutation rate the alignment should have high column
        // agreement (not uniform noise).
        let mut rng = Rng::new(2);
        let a = synthetic_alignment(6, 200, 0.05, &mut rng);
        let mut agree = 0usize;
        for site in 0..200 {
            let c0 = a.seqs[0][site];
            if a.seqs.iter().filter(|s| s[site] == c0).count() >= 4 {
                agree += 1;
            }
        }
        assert!(agree > 120, "only {agree} / 200 conserved-ish sites");
    }

    #[test]
    fn ds_configs_are_increasing() {
        let mut last = 0;
        for ds in 1..=8 {
            let (n, m) = ds_config(ds);
            assert!(n * m >= last);
            last = n * m;
            assert!(ds_reward_c(ds) > 0.0);
        }
    }
}
