//! HTTP/1.1 connection plumbing for the serve front end: a hardened
//! request reader, a response writer, and a small keep-alive client used
//! by the integration tests and the `serve_http_qps` bench.
//!
//! Std-only by necessity (the image carries no hyper/tokio): requests are
//! parsed off a blocking `TcpStream` with a short OS read timeout, so the
//! reader can poll a shutdown flag between reads instead of blocking in
//! the kernel forever. The subset of HTTP/1.1 implemented is exactly what
//! the front end needs — request line, headers, `Content-Length` bodies,
//! keep-alive — with hard caps on header and body size so a hostile peer
//! cannot buffer us into OOM (the connection-level twin of the sampler's
//! bounded admission queue).

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Cap on the request head (request line + headers). Generous for any
/// legitimate client of this API.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// `false` once the client sent `Connection: close`.
    pub keep_alive: bool,
}

/// Why [`read_request`] returned without a request.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete request was parsed.
    Request(Request),
    /// The peer closed (or half-closed) the connection cleanly.
    Eof,
    /// The server is shutting down (`stop` was raised mid-read).
    Stopped,
    /// The peer sent nothing for `idle_timeout` — close the connection.
    IdleTimeout,
    /// Malformed or over-limit request; the caller should answer 400 and
    /// close.
    Bad(String),
}

/// Read one request off `stream`, polling `stop` between reads.
///
/// `idle_timeout` bounds how long we wait for the *start* of a request on
/// a keep-alive connection; once bytes arrive the same budget bounds the
/// remainder (a trickling peer cannot hold the handler hostage).
pub fn read_request(
    stream: &mut TcpStream,
    max_body: usize,
    idle_timeout: Duration,
    stop: &AtomicBool,
) -> ReadOutcome {
    // Short OS timeout so the loop can notice `stop` promptly; the real
    // deadline accounting happens here, not in the kernel.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(25)));
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let started = Instant::now();
    // Phase 1: the head, terminated by CRLFCRLF.
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return ReadOutcome::Bad(format!(
                "request head exceeds {MAX_HEAD_BYTES} bytes"
            ));
        }
        if stop.load(Ordering::Relaxed) {
            return ReadOutcome::Stopped;
        }
        if started.elapsed() > idle_timeout {
            return ReadOutcome::IdleTimeout;
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                return if buf.is_empty() {
                    ReadOutcome::Eof
                } else {
                    ReadOutcome::Bad("connection closed mid-request".to_string())
                };
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return ReadOutcome::Bad(format!("read error: {e}")),
        }
    };

    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return ReadOutcome::Bad("request head is not UTF-8".to_string()),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/1.") => {
            (m.to_string(), p.to_string())
        }
        _ => return ReadOutcome::Bad(format!("malformed request line {request_line:?}")),
    };
    let mut content_length = 0usize;
    let mut keep_alive = true;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = match value.parse() {
                Ok(n) => n,
                Err(_) => {
                    return ReadOutcome::Bad(format!("bad content-length {value:?}"))
                }
            };
        } else if name.eq_ignore_ascii_case("connection")
            && value.eq_ignore_ascii_case("close")
        {
            keep_alive = false;
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            // Chunked bodies are out of scope for this API; refuse rather
            // than misparse.
            return ReadOutcome::Bad("transfer-encoding is not supported".to_string());
        }
    }
    if content_length > max_body {
        return ReadOutcome::Bad(format!(
            "body of {content_length} bytes exceeds the {max_body}-byte limit"
        ));
    }

    // Phase 2: the body — whatever followed the head in the buffer, plus
    // reads up to content-length.
    let mut body = buf.split_off(head_end + 4);
    while body.len() < content_length {
        if stop.load(Ordering::Relaxed) {
            return ReadOutcome::Stopped;
        }
        if started.elapsed() > idle_timeout {
            return ReadOutcome::IdleTimeout;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return ReadOutcome::Bad("connection closed mid-body".to_string()),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return ReadOutcome::Bad(format!("read error: {e}")),
        }
    }
    body.truncate(content_length);
    ReadOutcome::Request(Request { method, path, body, keep_alive })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Media type for the JSON routes.
pub const CONTENT_TYPE_JSON: &str = "application/json";

/// Media type for Prometheus text exposition (`GET /metrics`).
pub const CONTENT_TYPE_PROMETHEUS: &str = "text/plain; version=0.0.4";

/// Write one JSON response. `extra_headers` are preformatted `Name: value`
/// lines (no CRLF).
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &[u8],
    extra_headers: &[&str],
) -> std::io::Result<()> {
    write_response_typed(stream, status, body, CONTENT_TYPE_JSON, extra_headers)
}

/// Write one response with an explicit media type (the `/metrics` route
/// serves Prometheus text, everything else JSON).
pub fn write_response_typed(
    stream: &mut TcpStream,
    status: u16,
    body: &[u8],
    content_type: &str,
    extra_headers: &[&str],
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Response",
    };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\n",
        body.len()
    );
    for h in extra_headers {
        head.push_str(h);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// A minimal blocking keep-alive HTTP/1.1 client, enough for the
/// integration tests and the QPS bench (the image has no curl-equivalent
/// crate). One connection per client; requests are serial.
pub struct HttpClient {
    stream: TcpStream,
}

impl HttpClient {
    pub fn connect(addr: &str) -> anyhow::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(HttpClient { stream })
    }

    /// Issue one request, block for the full response, return
    /// `(status, body)`. The connection stays open for the next call.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> anyhow::Result<(u16, Vec<u8>)> {
        let (status, _headers, body) = self.request_full(method, path, body)?;
        Ok((status, body))
    }

    /// Like [`HttpClient::request`] but also returns the response headers
    /// as lowercased `(name, value)` pairs, so tests can assert media
    /// types and backpressure hints.
    pub fn request_full(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> anyhow::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: gfnx\r\ncontent-length: {}\r\n\r\n",
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()?;

        let mut buf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(pos) = find_head_end(&buf) {
                break pos;
            }
            let n = self.stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed before a full response head");
            buf.extend_from_slice(&chunk[..n]);
        };
        let head = std::str::from_utf8(&buf[..head_end])?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("malformed status line {status_line:?}"))?;
        let mut content_length = 0usize;
        let mut headers = Vec::new();
        for line in lines {
            if let Some((name, value)) = line.split_once(':') {
                let value = value.trim();
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.parse()?;
                }
                headers.push((name.to_ascii_lowercase(), value.to_string()));
            }
        }
        let mut body = buf.split_off(head_end + 4);
        while body.len() < content_length {
            let n = self.stream.read(&mut chunk)?;
            anyhow::ensure!(n > 0, "server closed mid-body");
            body.extend_from_slice(&chunk[..n]);
        }
        body.truncate(content_length);
        Ok((status, headers, body))
    }

    /// POST a JSON body.
    pub fn post_json(&mut self, path: &str, json: &str) -> anyhow::Result<(u16, Vec<u8>)> {
        self.request("POST", path, json.as_bytes())
    }

    /// GET a path.
    pub fn get(&mut self, path: &str) -> anyhow::Result<(u16, Vec<u8>)> {
        self.request("GET", path, &[])
    }

    /// GET a path, returning status, headers, and body.
    pub fn get_full(
        &mut self,
        path: &str,
    ) -> anyhow::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
        self.request_full("GET", path, &[])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::sync::Arc;

    fn serve_once<F>(handler: F) -> String
    where
        F: FnOnce(TcpStream) + Send + 'static,
    {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            if let Ok((stream, _)) = listener.accept() {
                handler(stream);
            }
        });
        addr
    }

    #[test]
    fn parses_request_with_body_and_answers() {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let addr = serve_once(move |mut s| {
            match read_request(&mut s, 1024, Duration::from_secs(5), &stop2) {
                ReadOutcome::Request(req) => {
                    assert_eq!(req.method, "POST");
                    assert_eq!(req.path, "/sample");
                    assert_eq!(req.body, b"{\"n\":3}");
                    assert!(req.keep_alive);
                    write_response(&mut s, 200, b"{\"ok\":true}", &[]).unwrap();
                }
                other => panic!("expected a request, got {other:?}"),
            }
        });
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, body) = c.post_json("/sample", "{\"n\":3}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
    }

    #[test]
    fn responses_carry_an_explicit_content_type() {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let addr = serve_once(move |mut s| {
            for _ in 0..2 {
                match read_request(&mut s, 1024, Duration::from_secs(5), &stop2) {
                    ReadOutcome::Request(req) if req.path == "/json" => {
                        write_response(&mut s, 200, b"{}", &[]).unwrap();
                    }
                    ReadOutcome::Request(_) => {
                        write_response_typed(
                            &mut s,
                            200,
                            b"# TYPE x counter\nx 1\n",
                            CONTENT_TYPE_PROMETHEUS,
                            &[],
                        )
                        .unwrap();
                    }
                    other => panic!("expected a request, got {other:?}"),
                }
            }
        });
        let mut c = HttpClient::connect(&addr).unwrap();
        let ctype = |headers: &[(String, String)]| {
            headers
                .iter()
                .find(|(n, _)| n == "content-type")
                .map(|(_, v)| v.clone())
                .expect("content-type header present")
        };
        let (status, headers, _) = c.get_full("/json").unwrap();
        assert_eq!(status, 200);
        assert_eq!(ctype(&headers), CONTENT_TYPE_JSON);
        let (status, headers, body) = c.get_full("/metrics").unwrap();
        assert_eq!(status, 200);
        assert_eq!(ctype(&headers), CONTENT_TYPE_PROMETHEUS);
        assert!(body.starts_with(b"# TYPE"));
    }

    #[test]
    fn oversized_bodies_and_heads_are_refused() {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let addr = serve_once(move |mut s| {
            match read_request(&mut s, 16, Duration::from_secs(5), &stop2) {
                ReadOutcome::Bad(msg) => {
                    assert!(msg.contains("exceeds"), "{msg}");
                    write_response(&mut s, 400, b"{}", &[]).unwrap();
                }
                other => panic!("expected Bad, got {other:?}"),
            }
        });
        let mut c = HttpClient::connect(&addr).unwrap();
        let big = "x".repeat(64);
        let (status, _) = c.post_json("/sample", &big).unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn clean_eof_and_keep_alive_sequences() {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let addr = serve_once(move |mut s| {
            // Two requests on one connection, then EOF.
            for i in 0..2 {
                match read_request(&mut s, 1024, Duration::from_secs(5), &stop2) {
                    ReadOutcome::Request(req) => {
                        assert_eq!(req.path, format!("/r{i}"));
                        write_response(&mut s, 200, b"[]", &[]).unwrap();
                    }
                    other => panic!("request {i}: got {other:?}"),
                }
            }
            assert!(matches!(
                read_request(&mut s, 1024, Duration::from_secs(5), &stop2),
                ReadOutcome::Eof
            ));
        });
        let mut c = HttpClient::connect(&addr).unwrap();
        assert_eq!(c.get("/r0").unwrap().0, 200);
        assert_eq!(c.get("/r1").unwrap().0, 200);
        drop(c);
        // Give the server thread a beat to observe EOF (assertions panic
        // inside it if this fails; nothing to join here).
        std::thread::sleep(Duration::from_millis(50));
    }

    #[test]
    fn stop_flag_interrupts_an_idle_read() {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let t0 = Instant::now();
            let out = read_request(&mut s, 1024, Duration::from_secs(30), &stop2);
            (t0.elapsed(), matches!(out, ReadOutcome::Stopped))
        });
        let _c = HttpClient::connect(&addr).unwrap(); // connect, send nothing
        std::thread::sleep(Duration::from_millis(60));
        stop.store(true, Ordering::Relaxed);
        let (elapsed, stopped) = h.join().unwrap();
        assert!(stopped, "reader must notice the stop flag");
        assert!(elapsed < Duration::from_secs(5), "promptly: {elapsed:?}");
    }
}
