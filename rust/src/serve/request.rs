//! Request/ticket types: how callers talk to the sampling service.
//!
//! A [`SampleRequest`] asks for `n_samples` terminal objects; the service
//! answers immediately with a [`SampleTicket`], a waitable handle fulfilled
//! by the worker thread once every trajectory of the request has finished.
//! Tickets are plain `Mutex` + `Condvar` (no async runtime in the image).

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Marker carried by every deadline/timeout error in the serve stack (the
/// worker's in-queue and mid-drain cancels, and [`SampleTicket::wait_timeout`]
/// giving up client-side). The HTTP layer maps errors containing this to 504;
/// everything else is a 500.
pub const TIMEOUT_ERROR: &str = "deadline exceeded";

/// Is this a serve-stack timeout (vs a policy/env/shutdown failure)?
pub fn is_timeout(err: &anyhow::Error) -> bool {
    err.to_string().contains(TIMEOUT_ERROR)
}

/// A sampling request.
#[derive(Clone, Copy, Debug)]
pub struct SampleRequest {
    /// Number of terminal objects to sample (0 is answered immediately).
    pub n_samples: usize,
    /// Base seed. Trajectory `i` uses the stream
    /// [`traj_seed(seed, i)`](crate::serve::traj_seed), making results
    /// independent of slot assignment and batch composition.
    pub seed: u64,
}

/// One sampled trajectory, as returned to the requester.
#[derive(Clone, Debug)]
pub struct SampleOutput<Obj> {
    /// The terminal object.
    pub obj: Obj,
    /// Σ_t log P_F of the sampled actions under the serving policy.
    pub log_pf: f64,
    /// Terminal log-reward.
    pub log_reward: f64,
    /// Trajectory length (number of forward transitions).
    pub length: usize,
    /// Index of this trajectory within its request (outputs are returned
    /// sorted by this index).
    pub traj_index: usize,
}

/// Internal ticket cell state.
pub(crate) enum TicketCell<Obj> {
    Pending,
    Ready(anyhow::Result<Vec<SampleOutput<Obj>>>),
    Taken,
    /// The waiter gave up ([`SampleTicket::wait_timeout`]); a later
    /// [`TicketShared::fulfill`] is a no-op (the result has no reader).
    TimedOut,
}

pub(crate) struct TicketShared<Obj> {
    pub(crate) cell: Mutex<TicketCell<Obj>>,
    pub(crate) cv: Condvar,
}

impl<Obj> TicketShared<Obj> {
    pub(crate) fn new() -> Arc<TicketShared<Obj>> {
        Arc::new(TicketShared { cell: Mutex::new(TicketCell::Pending), cv: Condvar::new() })
    }

    /// Complete the ticket (first fulfillment wins; later calls are no-ops).
    pub(crate) fn fulfill(&self, result: anyhow::Result<Vec<SampleOutput<Obj>>>) {
        let mut g = self.cell.lock().unwrap();
        if matches!(*g, TicketCell::Pending) {
            *g = TicketCell::Ready(result);
            self.cv.notify_all();
        }
    }
}

/// A waitable handle for one [`SampleRequest`].
pub struct SampleTicket<Obj> {
    pub(crate) shared: Arc<TicketShared<Obj>>,
}

impl<Obj> SampleTicket<Obj> {
    /// Block until the service answers, consuming the ticket. Outputs are
    /// sorted by `traj_index`.
    pub fn wait(self) -> anyhow::Result<Vec<SampleOutput<Obj>>> {
        let mut g = self.shared.cell.lock().unwrap();
        loop {
            match std::mem::replace(&mut *g, TicketCell::Taken) {
                TicketCell::Ready(r) => return r,
                TicketCell::Pending => {
                    *g = TicketCell::Pending;
                    g = self.shared.cv.wait(g).unwrap();
                }
                TicketCell::Taken | TicketCell::TimedOut => {
                    unreachable!("ticket consumed twice")
                }
            }
        }
    }

    /// Like [`SampleTicket::wait`], but give up after `timeout`: the cell
    /// moves to a timed-out terminal state (a late worker fulfillment
    /// becomes a no-op) and a [`TIMEOUT_ERROR`] error is returned. This is
    /// the client-side half of the deadline story — a stalled or wedged
    /// worker can no longer strand a caller forever.
    pub fn wait_timeout(self, timeout: Duration) -> anyhow::Result<Vec<SampleOutput<Obj>>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.shared.cell.lock().unwrap();
        loop {
            match std::mem::replace(&mut *g, TicketCell::Taken) {
                TicketCell::Ready(r) => return r,
                TicketCell::Pending => {
                    let now = Instant::now();
                    if now >= deadline {
                        *g = TicketCell::TimedOut;
                        return Err(anyhow::anyhow!(
                            "{TIMEOUT_ERROR}: no result within {timeout:?}"
                        ));
                    }
                    *g = TicketCell::Pending;
                    g = self.shared.cv.wait_timeout(g, deadline - now).unwrap().0;
                }
                TicketCell::Taken | TicketCell::TimedOut => {
                    unreachable!("ticket consumed twice")
                }
            }
        }
    }

    /// Has the service answered yet? (Non-blocking.)
    pub fn is_ready(&self) -> bool {
        matches!(*self.shared.cell.lock().unwrap(), TicketCell::Ready(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_wait_sees_fulfillment_across_threads() {
        let shared = TicketShared::<u32>::new();
        let ticket = SampleTicket { shared: shared.clone() };
        assert!(!ticket.is_ready());
        let t = std::thread::spawn(move || {
            shared.fulfill(Ok(vec![SampleOutput {
                obj: 7,
                log_pf: -1.0,
                log_reward: 0.5,
                length: 3,
                traj_index: 0,
            }]));
        });
        let out = ticket.wait().unwrap();
        t.join().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].obj, 7);
    }

    #[test]
    fn first_fulfillment_wins() {
        let shared = TicketShared::<u32>::new();
        shared.fulfill(Err(anyhow::anyhow!("first")));
        shared.fulfill(Ok(vec![]));
        let ticket = SampleTicket { shared };
        assert_eq!(ticket.wait().unwrap_err().to_string(), "first");
    }

    /// `wait_timeout` returns a recognizable timeout error when nobody
    /// fulfills, and a late fulfillment against the timed-out cell is a
    /// silent no-op (no panic, no resurrected reader).
    #[test]
    fn wait_timeout_expires_and_late_fulfill_is_noop() {
        let shared = TicketShared::<u32>::new();
        let ticket = SampleTicket { shared: shared.clone() };
        let t0 = Instant::now();
        let err = ticket.wait_timeout(Duration::from_millis(30)).unwrap_err();
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert!(is_timeout(&err), "timeout errors must carry the marker: {err}");
        shared.fulfill(Ok(vec![])); // must not panic or flip the state
        assert!(matches!(*shared.cell.lock().unwrap(), TicketCell::TimedOut));
    }

    /// A fulfillment racing in before the timeout wins: the waiter gets the
    /// result, not the timeout.
    #[test]
    fn wait_timeout_returns_result_when_fulfilled_in_time() {
        let shared = TicketShared::<u32>::new();
        let ticket = SampleTicket { shared: shared.clone() };
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            shared.fulfill(Ok(vec![]));
        });
        let out = ticket.wait_timeout(Duration::from_secs(5)).unwrap();
        t.join().unwrap();
        assert!(out.is_empty());
    }
}
