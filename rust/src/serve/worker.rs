//! The service layer: a dedicated worker thread wrapping the slot engine.
//!
//! One worker owns the environment and the policy. Requests arrive through
//! the MPSC [`Queue`]; the worker admits them into an in-flight table and
//! feeds their trajectories to [`sample_stream`], which merges trajectories
//! from *all* admitted requests into the same slot table — a late request
//! starts filling slots the moment one frees, without waiting for earlier
//! requests to drain. Tickets complete per request as soon as that
//! request's last trajectory finishes.
//!
//! The policy is built *on* the worker thread by a `Send` factory closure:
//! PJRT clients are `Rc`-based thread-locals, so an `OwnedArtifactPolicy`
//! must be constructed where it will run.

use super::queue::Queue;
use super::request::{SampleOutput, SampleRequest, SampleTicket, TicketShared};
use super::sampler::{sample_stream, TrajJob, TrajResult};
use super::stats::{ServeSnapshot, ServeStats};
use super::traj_seed;
use crate::envs::{EnvSpec, VecEnv};
use crate::runtime::policy::{check_env_token_shape, BatchPolicy, PolicyShape};
use crate::telemetry::Registry;
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// The hot-swap mailbox: latest-wins slot holding the next policy to serve
/// (see [`SamplerService::hot_swap`]).
type SwapSlot = Arc<Mutex<Option<Box<dyn BatchPolicy + Send>>>>;

/// The worker's serving policy: the current policy plus the swap mailbox.
/// Each [`BatchPolicy::eval`] first applies a pending swap (via `try_lock`,
/// so a contended mailbox never stalls the dispatch hot path — the swap
/// just lands on the next dispatch), which is what makes swaps **live**:
/// they take effect mid-drain, between two dispatches of the same running
/// batch, without disturbing in-flight trajectories (their remaining
/// actions simply come from the newer policy).
struct SwappablePolicy {
    current: Box<dyn BatchPolicy>,
    slot: SwapSlot,
    stats: Arc<ServeStats>,
    /// Spec of the env this worker serves — the fixed side of the swap
    /// compatibility check.
    spec: EnvSpec,
}

impl SwappablePolicy {
    fn apply_pending(&mut self) {
        let Ok(mut slot) = self.slot.try_lock() else { return };
        let Some(next) = slot.take() else { return };
        drop(slot);
        if next.shape() == self.current.shape()
            && check_env_token_shape(&self.spec, &next.shape(), next.token_shape()).is_ok()
        {
            self.current = next;
            self.stats.policy_swaps.inc();
        } else {
            // A mis-shaped policy would corrupt the running slot table, and
            // one that factorizes observations into the wrong token grid
            // (transformer trained for a different env) would silently
            // misread every row; drop it and count the rejection instead of
            // poisoning the service.
            self.stats.swaps_rejected.inc();
        }
    }
}

impl BatchPolicy for SwappablePolicy {
    fn shape(&self) -> PolicyShape {
        self.current.shape()
    }

    fn token_shape(&self) -> Option<(usize, usize)> {
        self.current.token_shape()
    }

    fn eval(
        &mut self,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.apply_pending();
        self.current.eval(obs, fwd_mask, bwd_mask)
    }
}

struct WorkItem<Obj> {
    req: SampleRequest,
    ticket: Arc<TicketShared<Obj>>,
    /// Enqueue time, for the `serve.request_latency` and
    /// `serve.first_dispatch_latency` histograms.
    submitted: Instant,
}

/// An in-flight request inside one worker drain.
struct InFlight<Obj> {
    ticket: Arc<TicketShared<Obj>>,
    seed: u64,
    n: usize,
    issued: usize,
    done: usize,
    outputs: Vec<Option<SampleOutput<Obj>>>,
    submitted: Instant,
}

/// Bookkeeping of one worker drain. A drain can run indefinitely under
/// sustained traffic, so this must not grow with the number of requests
/// served: completed requests are pruned from `inflight`, and the job
/// source only ever looks at the front of `pending` (requests that still
/// have unissued trajectories) instead of scanning history.
struct DrainState<Obj> {
    next_id: u64,
    inflight: HashMap<u64, InFlight<Obj>>,
    /// FIFO of request ids with `issued < n`.
    pending: VecDeque<u64>,
}

impl<Obj> DrainState<Obj> {
    fn new() -> DrainState<Obj> {
        DrainState { next_id: 0, inflight: HashMap::new(), pending: VecDeque::new() }
    }
}

/// A continuous-batching sampling service over one environment + policy.
pub struct SamplerService<Obj> {
    queue: Queue<WorkItem<Obj>>,
    stats: Arc<ServeStats>,
    swap: SwapSlot,
    handle: Option<JoinHandle<()>>,
}

impl<Obj: Send + 'static> SamplerService<Obj> {
    /// Stand up the service. `policy_factory` runs once on the worker
    /// thread and builds the policy (e.g. `OwnedArtifactPolicy::load` for
    /// the AOT graphs, or a `UniformPolicy` for artifact-free serving).
    pub fn spawn<E, F>(env: E, policy_factory: F) -> SamplerService<Obj>
    where
        E: VecEnv<Obj = Obj> + Send + 'static,
        F: FnOnce() -> anyhow::Result<Box<dyn BatchPolicy>> + Send + 'static,
    {
        Self::spawn_in(env, policy_factory, Arc::new(Registry::new()))
    }

    /// Like [`SamplerService::spawn`], but register the service's `serve.*`
    /// metrics in `registry` instead of a fresh scoped one — pass
    /// [`crate::telemetry::global`] to fold serve stats into the process
    /// telemetry export (`train --serve --telemetry-file …`).
    pub fn spawn_in<E, F>(
        env: E,
        policy_factory: F,
        registry: Arc<Registry>,
    ) -> SamplerService<Obj>
    where
        E: VecEnv<Obj = Obj> + Send + 'static,
        F: FnOnce() -> anyhow::Result<Box<dyn BatchPolicy>> + Send + 'static,
    {
        let queue: Queue<WorkItem<Obj>> = Queue::new();
        let stats = Arc::new(ServeStats::in_registry(registry));
        let swap: SwapSlot = Arc::new(Mutex::new(None));
        let worker_queue = queue.clone();
        let worker_stats = Arc::clone(&stats);
        let worker_swap = Arc::clone(&swap);
        let handle = std::thread::Builder::new()
            .name("gfnx-serve-worker".to_string())
            .spawn(move || {
                worker_loop(env, policy_factory, worker_queue, worker_stats, worker_swap)
            })
            .expect("failed to spawn serve worker thread");
        SamplerService { queue, stats, swap, handle: Some(handle) }
    }

    /// Install a new serving policy **live**: the worker picks it up at its
    /// next policy dispatch — mid-drain included — so a training loop can
    /// publish improving snapshots while requests stream (the engine's
    /// `train --serve` path calls this from its publish hook). Latest wins:
    /// an unapplied pending swap is replaced, not queued. The incoming
    /// policy must match the serving dispatch shape; mismatches are dropped
    /// and counted in [`ServeSnapshot::swaps_rejected`].
    pub fn hot_swap(&self, policy: Box<dyn BatchPolicy + Send>) {
        *self.swap.lock().unwrap() = Some(policy);
    }

    /// Enqueue a request; returns immediately with a waitable ticket.
    pub fn submit(&self, req: SampleRequest) -> SampleTicket<Obj> {
        let shared = TicketShared::new();
        self.stats.requests_submitted.inc();
        let item = WorkItem { req, ticket: Arc::clone(&shared), submitted: Instant::now() };
        if !self.queue.push(item) {
            shared.fulfill(Err(anyhow::anyhow!(
                "sampler service is shut down (queue closed)"
            )));
            self.stats.requests_failed.inc();
        }
        SampleTicket { shared }
    }

    /// Convenience: submit and block for the result.
    pub fn sample(&self, n_samples: usize, seed: u64) -> anyhow::Result<Vec<SampleOutput<Obj>>> {
        self.submit(SampleRequest { n_samples, seed }).wait()
    }

    /// Current request backlog (excluding in-flight work).
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Point-in-time service counters.
    pub fn stats(&self) -> ServeSnapshot {
        self.stats.snapshot()
    }

    /// The telemetry registry backing this service's `serve.*` metrics
    /// (scoped by default; shared if spawned via [`SamplerService::spawn_in`]).
    pub fn registry(&self) -> &Arc<Registry> {
        self.stats.registry()
    }

    /// Stop accepting requests, finish queued + in-flight work, join the
    /// worker.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<Obj> Drop for SamplerService<Obj> {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Admit a work item: zero-sample requests complete immediately; others
/// enter the in-flight table under a fresh stable id.
fn admit<Obj>(
    drain: &RefCell<DrainState<Obj>>,
    item: WorkItem<Obj>,
    stats: &ServeStats,
) {
    if item.req.n_samples == 0 {
        // Count before fulfilling: a waiter that wakes on fulfill() must
        // already see the completion in a stats snapshot.
        stats.requests_completed.inc();
        stats.request_latency.record(item.submitted.elapsed().as_nanos() as u64);
        item.ticket.fulfill(Ok(Vec::new()));
        return;
    }
    let n = item.req.n_samples;
    let mut s = drain.borrow_mut();
    let id = s.next_id;
    s.next_id += 1;
    s.inflight.insert(
        id,
        InFlight {
            ticket: item.ticket,
            seed: item.req.seed,
            n,
            issued: 0,
            done: 0,
            outputs: (0..n).map(|_| None).collect(),
            submitted: item.submitted,
        },
    );
    s.pending.push_back(id);
}

fn worker_loop<E, F>(
    env: E,
    policy_factory: F,
    queue: Queue<WorkItem<E::Obj>>,
    stats: Arc<ServeStats>,
    swap: SwapSlot,
) where
    E: VecEnv,
    F: FnOnce() -> anyhow::Result<Box<dyn BatchPolicy>>,
{
    let spec = env.spec();
    let mut policy = match policy_factory() {
        Ok(p) => SwappablePolicy { current: p, slot: swap, stats: Arc::clone(&stats), spec },
        Err(e) => {
            // Refuse service: fail the backlog and all future submissions.
            queue.close();
            while let Some(item) = queue.try_pop() {
                item.ticket.fulfill(Err(anyhow::anyhow!("policy init failed: {e}")));
                stats.requests_failed.inc();
            }
            return;
        }
    };

    loop {
        // Block for work (or shutdown once the queue is closed and drained).
        let first = match queue.pop_blocking() {
            Some(item) => item,
            None => return,
        };
        let drain: RefCell<DrainState<E::Obj>> = RefCell::new(DrainState::new());
        admit(&drain, first, &stats);

        // Drain: the engine pulls trajectories lazily; the job source keeps
        // admitting newly queued requests so they join the running batch.
        let result = sample_stream(
            &env,
            &mut policy,
            || loop {
                {
                    let mut guard = drain.borrow_mut();
                    let s = &mut *guard;
                    while let Some(&id) = s.pending.front() {
                        let f = s
                            .inflight
                            .get_mut(&id)
                            .expect("pending id without in-flight entry");
                        if f.issued < f.n {
                            let i = f.issued;
                            if i == 0 {
                                // First trajectory of this request enters
                                // the slot table: queueing delay is over.
                                stats
                                    .first_dispatch_latency
                                    .record(f.submitted.elapsed().as_nanos() as u64);
                            }
                            f.issued += 1;
                            let seed = traj_seed(f.seed, i as u64);
                            if f.issued == f.n {
                                s.pending.pop_front();
                            }
                            return Some(TrajJob { request: id, traj_index: i, seed });
                        }
                        s.pending.pop_front();
                    }
                }
                match queue.try_pop() {
                    Some(item) => admit(&drain, item, &stats),
                    None => return None,
                }
            },
            |r: TrajResult<E::Obj>| {
                stats.trajectories_completed.inc();
                let mut guard = drain.borrow_mut();
                let f = guard
                    .inflight
                    .get_mut(&r.request)
                    .expect("trajectory for unknown request");
                debug_assert!(f.outputs[r.traj_index].is_none(), "duplicate trajectory");
                f.outputs[r.traj_index] = Some(SampleOutput {
                    obj: r.obj,
                    log_pf: r.log_pf,
                    log_reward: r.log_reward,
                    length: r.length,
                    traj_index: r.traj_index,
                });
                f.done += 1;
                if f.done == f.n {
                    // Prune the completed request so a long-lived drain does
                    // not accumulate history.
                    let f = guard.inflight.remove(&r.request).unwrap();
                    let outs: Vec<SampleOutput<E::Obj>> = f
                        .outputs
                        .into_iter()
                        .map(|o| o.expect("missing trajectory"))
                        .collect();
                    // Count before fulfilling (see admit()): waiters woken
                    // by fulfill() read a consistent stats snapshot.
                    stats.requests_completed.inc();
                    stats.request_latency.record(f.submitted.elapsed().as_nanos() as u64);
                    f.ticket.fulfill(Ok(outs));
                }
            },
        );

        match result {
            Ok(s) => {
                stats.policy_dispatches.add(s.dispatches);
                stats.active_row_steps.add(s.active_row_steps);
                stats.total_row_steps.add(s.total_row_steps);
                let total = stats.total_row_steps.get();
                if total > 0 {
                    stats
                        .occupancy
                        .set(stats.active_row_steps.get() as f64 / total as f64);
                }
            }
            Err(e) => {
                // The engine is wedged (policy failure or env invariant
                // breach): fail everything in flight and queued, then stop
                // serving — later submissions error immediately.
                let msg = format!("serve worker failed: {e}");
                for f in drain.borrow_mut().inflight.values() {
                    f.ticket.fulfill(Err(anyhow::anyhow!("{msg}")));
                    stats.requests_failed.inc();
                }
                queue.close();
                while let Some(item) = queue.try_pop() {
                    item.ticket.fulfill(Err(anyhow::anyhow!("{msg}")));
                    stats.requests_failed.inc();
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::hypergrid::HypergridEnv;
    use crate::reward::hypergrid::HypergridReward;
    use crate::runtime::policy::{PolicyShape, UniformPolicy};

    fn service(b: usize) -> SamplerService<Vec<i32>> {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, b);
        SamplerService::spawn(env, move || {
            Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
        })
    }

    /// End-to-end worker drain: a request returns exactly `n` outputs whose
    /// objects decode to in-range coordinates with matching rewards, and
    /// the counters account for every trajectory.
    #[test]
    fn worker_serves_requests_end_to_end() {
        let svc = service(4);
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let outs = svc.sample(10, 7).unwrap();
        assert_eq!(outs.len(), 10);
        for o in &outs {
            assert!(o.obj.iter().all(|&c| (0..6).contains(&c)));
            use crate::envs::VecEnv;
            let want = env.log_reward_obj(&o.obj);
            assert!((o.log_reward - want).abs() < 1e-5);
            assert!(o.length >= 1);
        }
        let snap = svc.stats();
        assert_eq!(snap.requests_submitted, 1);
        assert_eq!(snap.requests_completed, 1);
        assert!(snap.trajectories_completed >= 10);
        svc.shutdown();
    }

    /// Per-trajectory determinism through the worker: the same request
    /// seed yields the same multiset of objects regardless of slot-table
    /// width.
    #[test]
    fn worker_results_are_deterministic_in_seed_across_widths() {
        let run = |b: usize| {
            let svc = service(b);
            let mut objs: Vec<Vec<i32>> =
                svc.sample(12, 99).unwrap().into_iter().map(|o| o.obj).collect();
            svc.shutdown();
            objs.sort();
            objs
        };
        assert_eq!(run(3), run(8));
    }

    /// Zero-sample requests complete immediately (the admit fast path).
    #[test]
    fn worker_completes_empty_requests() {
        let svc = service(2);
        let outs = svc.sample(0, 1).unwrap();
        assert!(outs.is_empty());
        assert_eq!(svc.stats().requests_completed, 1);
        svc.shutdown();
    }

    /// Live hot-swap: after swapping a trained `NativePolicy` over the
    /// uniform one, the service's samples are exactly what a service
    /// spawned with that policy directly would produce (the per-trajectory
    /// seed streams make this a bitwise statement, not a distributional
    /// one), and the swap is counted.
    #[test]
    fn hot_swap_switches_the_serving_policy() {
        use crate::runtime::{NativeBackend, NativeConfig};
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let native = NativeBackend::new(NativeConfig::for_env(&env, 4, "tb").with_hidden(16), 21)
            .unwrap()
            .to_policy();

        // Reference: a service born with the native policy.
        let reference = {
            let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
            let p = native.clone();
            let svc = SamplerService::spawn(env, move || {
                Ok(Box::new(p) as Box<dyn BatchPolicy>)
            });
            let mut objs: Vec<Vec<i32>> =
                svc.sample(15, 33).unwrap().into_iter().map(|o| o.obj).collect();
            svc.shutdown();
            objs.sort();
            objs
        };

        // A uniform-policy service, swapped live.
        let svc = service(4);
        let _ = svc.sample(5, 1).unwrap(); // pre-swap traffic
        svc.hot_swap(Box::new(native));
        let mut objs: Vec<Vec<i32>> =
            svc.sample(15, 33).unwrap().into_iter().map(|o| o.obj).collect();
        objs.sort();
        assert_eq!(objs, reference, "post-swap samples must come from the new policy");
        let snap = svc.stats();
        assert_eq!(snap.policy_swaps, 1);
        assert_eq!(snap.swaps_rejected, 0);
        svc.shutdown();
    }

    /// A mis-shaped swap is dropped (counted, service unharmed) instead of
    /// corrupting the slot table.
    #[test]
    fn hot_swap_rejects_shape_mismatch() {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let svc = service(4);
        // Wrong batch width.
        let bad = UniformPolicy::new(PolicyShape::of_env(&env, 9));
        svc.hot_swap(Box::new(bad));
        let outs = svc.sample(8, 3).unwrap();
        assert_eq!(outs.len(), 8, "service keeps serving after a rejected swap");
        let snap = svc.stats();
        assert_eq!(snap.swaps_rejected, 1);
        assert_eq!(snap.policy_swaps, 0);
        svc.shutdown();
    }

    /// Model-aware swap gate: a transformer policy whose token grid
    /// factorizes the right `obs_dim` the wrong way (3×4 over hypergrid's
    /// 2×6) passes the plain shape check but is rejected by the token-shape
    /// check; one that matches the env's grid swaps in and serves.
    #[test]
    fn hot_swap_rejects_token_grid_mismatch_and_accepts_match() {
        use crate::runtime::{ModelSpec, NativeBackend, NativeConfig, TransformerArch};
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let svc = service(4);

        // Same PolicyShape (obs_dim 12), wrong factorization: 3×4 ≠ 2×6.
        let arch = |seq_len, token_dim| TransformerArch {
            seq_len,
            token_dim,
            embed: 8,
            n_heads: 2,
            ff_hidden: 16,
            causal: false,
        };
        let bad = NativeBackend::new(
            NativeConfig::for_env(&env, 4, "tb")
                .with_model(ModelSpec::Transformer(arch(3, 4))),
            5,
        )
        .unwrap()
        .to_policy();
        svc.hot_swap(Box::new(bad));
        let outs = svc.sample(6, 11).unwrap();
        assert_eq!(outs.len(), 6, "service keeps serving after a rejected swap");
        assert_eq!(svc.stats().swaps_rejected, 1);
        assert_eq!(svc.stats().policy_swaps, 0);

        // Matching grid (2×6): the swap applies and the service serves from
        // the transformer.
        let good = NativeBackend::new(
            NativeConfig::for_env(&env, 4, "tb")
                .with_model(ModelSpec::Transformer(arch(2, 6))),
            5,
        )
        .unwrap()
        .to_policy();
        svc.hot_swap(Box::new(good));
        let outs = svc.sample(6, 12).unwrap();
        assert_eq!(outs.len(), 6);
        assert_eq!(svc.stats().policy_swaps, 1);
        assert_eq!(svc.stats().swaps_rejected, 1);
        svc.shutdown();
    }

    /// Latest-wins mailbox: two swaps before any dispatch apply only the
    /// second.
    #[test]
    fn hot_swap_latest_wins() {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, 4);
        let svc = service(4);
        svc.hot_swap(Box::new(UniformPolicy::new(shape)));
        svc.hot_swap(Box::new(UniformPolicy::new(shape)));
        let _ = svc.sample(4, 0).unwrap();
        assert_eq!(svc.stats().policy_swaps, 1, "only the latest pending swap applies");
        svc.shutdown();
    }

    /// Satellite: failure accounting. With a policy that fails mid-serve,
    /// every submitted request is answered exactly once — completed (the
    /// zero-sample fast path) or failed (in-flight, queued, and
    /// post-shutdown submissions) — so
    /// `submitted == completed + failed + pending` holds with `pending = 0`
    /// once all tickets resolve.
    #[test]
    fn failure_accounting_balances_under_worker_shutdown() {
        struct FailingPolicy {
            shape: PolicyShape,
        }
        impl BatchPolicy for FailingPolicy {
            fn shape(&self) -> PolicyShape {
                self.shape
            }
            fn eval(
                &mut self,
                _obs: &[f32],
                _fwd: &[f32],
                _bwd: &[f32],
            ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
                anyhow::bail!("injected policy failure")
            }
        }
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, 4);
        let svc: SamplerService<Vec<i32>> = SamplerService::spawn(env, move || {
            Ok(Box::new(FailingPolicy { shape }) as Box<dyn BatchPolicy>)
        });
        let t0 = svc.submit(SampleRequest { n_samples: 0, seed: 1 });
        let t1 = svc.submit(SampleRequest { n_samples: 5, seed: 2 });
        let t2 = svc.submit(SampleRequest { n_samples: 3, seed: 3 });
        assert!(t0.wait().is_ok(), "empty request completes before any dispatch");
        assert!(t1.wait().is_err(), "in-flight request fails with the worker");
        assert!(t2.wait().is_err(), "queued request fails on worker shutdown");
        // The worker has stopped serving: a late submission fails too,
        // either immediately (queue closed) or via the drain loop.
        let t3 = svc.submit(SampleRequest { n_samples: 2, seed: 4 });
        assert!(t3.wait().is_err());
        let snap = svc.stats();
        assert_eq!(snap.requests_submitted, 4);
        assert_eq!(snap.requests_completed, 1);
        assert_eq!(snap.requests_failed, 3);
        assert_eq!(
            snap.requests_submitted,
            snap.requests_completed + snap.requests_failed,
            "no request lost or double-counted"
        );
        svc.shutdown();
    }

    /// The service's latency histograms and occupancy gauge live in its
    /// registry and populate per request.
    #[test]
    fn latency_histograms_and_occupancy_populate() {
        let svc = service(4);
        let reg = Arc::clone(svc.registry());
        let outs = svc.sample(8, 5).unwrap();
        assert_eq!(outs.len(), 8);
        svc.shutdown(); // drain accounting (occupancy gauge) lands by join
        let lat = reg.histogram("serve.request_latency").snapshot();
        assert_eq!(lat.count, 1, "one completed request, one latency sample");
        assert!(lat.sum > 0);
        assert!(lat.percentile(0.5) <= lat.percentile(0.99));
        assert_eq!(reg.histogram("serve.first_dispatch_latency").count(), 1);
        let occ = reg.gauge("serve.occupancy").get();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy gauge set after drain: {occ}");
    }
}
