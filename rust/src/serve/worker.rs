//! The service layer: a dedicated worker thread wrapping the slot engine.
//!
//! One worker owns the environment and the policy. Requests arrive through
//! the MPSC [`Queue`]; the worker admits them into an in-flight table and
//! feeds their trajectories to [`sample_stream`], which merges trajectories
//! from *all* admitted requests into the same slot table — a late request
//! starts filling slots the moment one frees, without waiting for earlier
//! requests to drain. Tickets complete per request as soon as that
//! request's last trajectory finishes.
//!
//! On top of the batching engine sits the production envelope:
//!
//! - **Admission control**: the queue may be depth-bounded
//!   ([`SamplerService::spawn_with`]); over-capacity submissions are *shed*
//!   ([`SubmitOutcome::Shed`], counted as `serve.shed`) instead of growing
//!   an unbounded backlog until OOM.
//! - **Deadlines**: a request may carry an absolute deadline
//!   ([`SubmitOptions::deadline`]). Expired requests are cancelled at
//!   admission (in-queue expiry) or mid-drain (a deadline min-heap swept on
//!   every job-source poll); their tickets resolve with a
//!   [`TIMEOUT_ERROR`] error and already-running trajectories finish into
//!   a discard list, so a cancelled request never corrupts the slot table.
//! - **Per-client fairness**: trajectories are issued round-robin across
//!   clients ([`SubmitOptions::client`]), one trajectory per turn, so a
//!   client with one huge request cannot starve small requests from other
//!   clients — their trajectories interleave in the same slot table.
//!
//! The policy is built *on* the worker thread by a `Send` factory closure:
//! PJRT clients are `Rc`-based thread-locals, so an `OwnedArtifactPolicy`
//! must be constructed where it will run.

use super::queue::{PushError, Queue};
use super::request::{SampleOutput, SampleRequest, SampleTicket, TicketShared, TIMEOUT_ERROR};
use super::sampler::{sample_stream, TrajJob, TrajResult};
use super::stats::{ServeSnapshot, ServeStats};
use super::traj_seed;
use crate::envs::{EnvSpec, VecEnv};
use crate::runtime::policy::{check_env_token_shape, BatchPolicy, PolicyShape};
use crate::telemetry::trace::{self, ActiveTrace};
use crate::telemetry::Registry;
use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// The hot-swap mailbox: latest-wins slot holding the next policy to serve
/// (see [`SamplerService::hot_swap`]).
type SwapSlot = Arc<Mutex<Option<Box<dyn BatchPolicy + Send>>>>;

/// Traced requests currently draining (request id → trace handle): the
/// policy wrapper appends one `dispatch` slice per eval to each. Worker-
/// thread-only state (`Rc`), shared between the drain closures and the
/// policy; empty whenever no sampled request is in flight, so untraced
/// serving never takes the slow path.
type ActiveTraces = Rc<RefCell<Vec<(u64, Arc<ActiveTrace>)>>>;

/// The worker's serving policy: the current policy plus the swap mailbox.
/// Each [`BatchPolicy::eval`] first applies a pending swap (via `try_lock`,
/// so a contended mailbox never stalls the dispatch hot path — the swap
/// just lands on the next dispatch), which is what makes swaps **live**:
/// they take effect mid-drain, between two dispatches of the same running
/// batch, without disturbing in-flight trajectories (their remaining
/// actions simply come from the newer policy).
struct SwappablePolicy {
    current: Box<dyn BatchPolicy>,
    slot: SwapSlot,
    stats: Arc<ServeStats>,
    /// Spec of the env this worker serves — the fixed side of the swap
    /// compatibility check.
    spec: EnvSpec,
    /// Traced in-flight requests; each eval while this is non-empty gets
    /// timed as a `dispatch` waterfall slice on every listed trace.
    active_traces: ActiveTraces,
}

impl SwappablePolicy {
    fn apply_pending(&mut self) {
        let Ok(mut slot) = self.slot.try_lock() else { return };
        let Some(next) = slot.take() else { return };
        drop(slot);
        if next.shape() == self.current.shape()
            && check_env_token_shape(&self.spec, &next.shape(), next.token_shape()).is_ok()
        {
            self.current = next;
            self.stats.policy_swaps.inc();
        } else {
            // A mis-shaped policy would corrupt the running slot table, and
            // one that factorizes observations into the wrong token grid
            // (transformer trained for a different env) would silently
            // misread every row; drop it and count the rejection instead of
            // poisoning the service.
            self.stats.swaps_rejected.inc();
        }
    }
}

impl BatchPolicy for SwappablePolicy {
    fn shape(&self) -> PolicyShape {
        self.current.shape()
    }

    fn token_shape(&self) -> Option<(usize, usize)> {
        self.current.token_shape()
    }

    fn eval(
        &mut self,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.apply_pending();
        // One relaxed load when tracing is off; the slice-timing path only
        // runs while a sampled request is actually draining.
        if trace::trace_enabled() && !self.active_traces.borrow().is_empty() {
            let t0 = Instant::now();
            let out = self.current.eval(obs, fwd_mask, bwd_mask);
            let t1 = Instant::now();
            for (_, tr) in self.active_traces.borrow().iter() {
                tr.segment("dispatch", t0, t1);
            }
            out
        } else {
            self.current.eval(obs, fwd_mask, bwd_mask)
        }
    }
}

/// Per-request submission options beyond the [`SampleRequest`] itself.
#[derive(Clone, Copy, Debug)]
pub struct SubmitOptions {
    /// Absolute deadline. Past it the worker cancels the request — whether
    /// it is still queued or already mid-drain — and resolves its ticket
    /// with a [`TIMEOUT_ERROR`] error (counted as `serve.requests_timedout`).
    pub deadline: Option<Instant>,
    /// Sampling temperature (`1.0` = the policy's training distribution;
    /// see [`TrajJob::temperature`]). Must be finite and positive.
    pub temperature: f64,
    /// Client identity for round-robin fairness. Requests sharing a client
    /// id share one issuance lane; distinct ids interleave one trajectory
    /// per turn. `0` (the default) is the anonymous shared lane.
    pub client: u64,
}

impl Default for SubmitOptions {
    fn default() -> Self {
        SubmitOptions { deadline: None, temperature: 1.0, client: 0 }
    }
}

/// What [`SamplerService::try_submit`] did with a request.
pub enum SubmitOutcome<Obj> {
    /// Admitted; wait on the ticket.
    Ticket(SampleTicket<Obj>),
    /// Refused — the bounded queue is at capacity (load shed; the HTTP
    /// layer answers 503). Counted as `serve.shed` *and* `serve.requests_failed`.
    Shed,
    /// Refused — the service is shut down.
    Closed,
}

impl<Obj> std::fmt::Debug for SubmitOutcome<Obj> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitOutcome::Ticket(_) => "Ticket(..)",
            SubmitOutcome::Shed => "Shed",
            SubmitOutcome::Closed => "Closed",
        })
    }
}

struct WorkItem<Obj> {
    req: SampleRequest,
    opts: SubmitOptions,
    ticket: Arc<TicketShared<Obj>>,
    /// Enqueue time, for the `serve.request_latency` and
    /// `serve.first_dispatch_latency` histograms.
    submitted: Instant,
    /// Sampled-request trace handle (see [`SamplerService::try_submit_traced`]).
    trace: Option<Arc<ActiveTrace>>,
}

/// An in-flight request inside one worker drain.
struct InFlight<Obj> {
    ticket: Arc<TicketShared<Obj>>,
    seed: u64,
    n: usize,
    issued: usize,
    done: usize,
    outputs: Vec<Option<SampleOutput<Obj>>>,
    submitted: Instant,
    temperature: f64,
    trace: Option<Arc<ActiveTrace>>,
    /// When the request's first trajectory entered the slot table — the
    /// shared instant that makes `queue_wait + drain` reconcile *exactly*
    /// with the `serve.request_latency` sample for this request.
    issued_at: Option<Instant>,
}

/// Bookkeeping of one worker drain. A drain can run indefinitely under
/// sustained traffic, so this must not grow with the number of requests
/// served: completed requests are pruned from `inflight`, per-client lanes
/// are dropped when they empty, heap entries and lane ids for departed
/// requests are skipped lazily, and `cancelled` entries die with their last
/// in-slot trajectory.
struct DrainState<Obj> {
    next_id: u64,
    inflight: HashMap<u64, InFlight<Obj>>,
    /// Round-robin rotation of client ids that have unissued work.
    rotation: VecDeque<u64>,
    /// Client id → FIFO of request ids with `issued < n`.
    per_client: HashMap<u64, VecDeque<u64>>,
    /// Deadline min-heap over admitted requests (lazy deletion: entries
    /// whose id has left `inflight` are skipped on pop).
    deadlines: BinaryHeap<Reverse<(Instant, u64)>>,
    /// Cancelled request id → trajectories still occupying slots
    /// (`issued − done` at cancel time). The sink discards their late
    /// results and removes the entry at zero, keeping this map bounded by
    /// the slot-table width.
    cancelled: HashMap<u64, usize>,
}

impl<Obj> DrainState<Obj> {
    fn new() -> DrainState<Obj> {
        DrainState {
            next_id: 0,
            inflight: HashMap::new(),
            rotation: VecDeque::new(),
            per_client: HashMap::new(),
            deadlines: BinaryHeap::new(),
            cancelled: HashMap::new(),
        }
    }
}

/// A continuous-batching sampling service over one environment + policy.
pub struct SamplerService<Obj> {
    queue: Queue<WorkItem<Obj>>,
    stats: Arc<ServeStats>,
    swap: SwapSlot,
    handle: Option<JoinHandle<()>>,
}

impl<Obj: Send + 'static> SamplerService<Obj> {
    /// Stand up the service. `policy_factory` runs once on the worker
    /// thread and builds the policy (e.g. `OwnedArtifactPolicy::load` for
    /// the AOT graphs, or a `UniformPolicy` for artifact-free serving).
    pub fn spawn<E, F>(env: E, policy_factory: F) -> SamplerService<Obj>
    where
        E: VecEnv<Obj = Obj> + Send + 'static,
        F: FnOnce() -> anyhow::Result<Box<dyn BatchPolicy>> + Send + 'static,
    {
        Self::spawn_with(env, policy_factory, Arc::new(Registry::new()), None)
    }

    /// Like [`SamplerService::spawn`], but register the service's `serve.*`
    /// metrics in `registry` instead of a fresh scoped one — pass
    /// [`crate::telemetry::global`] to fold serve stats into the process
    /// telemetry export (`train --serve --telemetry-file …`).
    pub fn spawn_in<E, F>(
        env: E,
        policy_factory: F,
        registry: Arc<Registry>,
    ) -> SamplerService<Obj>
    where
        E: VecEnv<Obj = Obj> + Send + 'static,
        F: FnOnce() -> anyhow::Result<Box<dyn BatchPolicy>> + Send + 'static,
    {
        Self::spawn_with(env, policy_factory, registry, None)
    }

    /// The fully general constructor: `queue_capacity` bounds the request
    /// backlog (`None` = unbounded). Over-capacity submissions are shed
    /// non-blockingly — the backpressure the network front end needs to
    /// answer 503 instead of buffering until OOM.
    ///
    /// The capacity also bounds *admission depth*: the worker stops pulling
    /// queued requests into the drain while `queue_capacity` requests are
    /// already in flight, so the backlog genuinely accumulates in the
    /// bounded queue instead of being swallowed into unbounded in-flight
    /// state. Total accepted-but-unresolved requests are therefore capped
    /// at `2 * queue_capacity` (in flight + queued); everything beyond that
    /// sheds.
    pub fn spawn_with<E, F>(
        env: E,
        policy_factory: F,
        registry: Arc<Registry>,
        queue_capacity: Option<usize>,
    ) -> SamplerService<Obj>
    where
        E: VecEnv<Obj = Obj> + Send + 'static,
        F: FnOnce() -> anyhow::Result<Box<dyn BatchPolicy>> + Send + 'static,
    {
        let queue: Queue<WorkItem<Obj>> = match queue_capacity {
            Some(cap) => Queue::with_capacity(cap),
            None => Queue::new(),
        };
        let stats = Arc::new(ServeStats::in_registry(registry));
        let swap: SwapSlot = Arc::new(Mutex::new(None));
        let worker_queue = queue.clone();
        let worker_stats = Arc::clone(&stats);
        let worker_swap = Arc::clone(&swap);
        let handle = std::thread::Builder::new()
            .name("gfnx-serve-worker".to_string())
            .spawn(move || {
                worker_loop(
                    env,
                    policy_factory,
                    worker_queue,
                    worker_stats,
                    worker_swap,
                    queue_capacity,
                )
            })
            .expect("failed to spawn serve worker thread");
        SamplerService { queue, stats, swap, handle: Some(handle) }
    }

    /// Install a new serving policy **live**: the worker picks it up at its
    /// next policy dispatch — mid-drain included — so a training loop can
    /// publish improving snapshots while requests stream (the engine's
    /// `train --serve` path calls this from its publish hook). Latest wins:
    /// an unapplied pending swap is replaced, not queued. The incoming
    /// policy must match the serving dispatch shape; mismatches are dropped
    /// and counted in [`ServeSnapshot::swaps_rejected`].
    pub fn hot_swap(&self, policy: Box<dyn BatchPolicy + Send>) {
        *self.swap.lock().unwrap() = Some(policy);
    }

    /// Enqueue a request; returns immediately with a waitable ticket
    /// (pre-failed if the service is shut down or shedding — use
    /// [`SamplerService::try_submit`] to distinguish those without paying
    /// for an error allocation).
    pub fn submit(&self, req: SampleRequest) -> SampleTicket<Obj> {
        self.submit_opts(req, SubmitOptions::default())
    }

    /// [`SamplerService::submit`] with explicit per-request options.
    pub fn submit_opts(&self, req: SampleRequest, opts: SubmitOptions) -> SampleTicket<Obj> {
        match self.try_submit(req, opts) {
            SubmitOutcome::Ticket(t) => t,
            SubmitOutcome::Shed => {
                let shared = TicketShared::new();
                shared.fulfill(Err(anyhow::anyhow!(
                    "sampler service overloaded: request shed (queue full)"
                )));
                SampleTicket { shared }
            }
            SubmitOutcome::Closed => {
                let shared = TicketShared::new();
                shared.fulfill(Err(anyhow::anyhow!(
                    "sampler service is shut down (queue closed)"
                )));
                SampleTicket { shared }
            }
        }
    }

    /// Admission-controlled submit: returns [`SubmitOutcome::Shed`] when
    /// the bounded queue is at capacity and [`SubmitOutcome::Closed`] after
    /// shutdown, instead of a pre-failed ticket. Every outcome is counted —
    /// `submitted == completed + failed` still balances once all tickets
    /// resolve, with shed/closed requests resolving (and recording their
    /// ~zero latency) at the submission site itself.
    pub fn try_submit(&self, req: SampleRequest, opts: SubmitOptions) -> SubmitOutcome<Obj> {
        self.try_submit_traced(req, opts, None)
    }

    /// [`SamplerService::try_submit`] carrying an optional trace handle
    /// (minted by the HTTP front end for sampled requests): the worker adds
    /// `queue_wait`, per-eval `dispatch` slices, and `drain` segments to it
    /// as the request moves through the drain. The caller keeps its own
    /// `Arc` and finishes the trace once the ticket resolves.
    pub fn try_submit_traced(
        &self,
        req: SampleRequest,
        opts: SubmitOptions,
        request_trace: Option<Arc<ActiveTrace>>,
    ) -> SubmitOutcome<Obj> {
        let shared = TicketShared::new();
        self.stats.requests_submitted.inc();
        let submitted = Instant::now();
        let item = WorkItem {
            req,
            opts,
            ticket: Arc::clone(&shared),
            submitted,
            trace: request_trace,
        };
        match self.queue.push(item) {
            Ok(()) => {
                self.stats.queue_high_water.set(self.queue.high_water() as f64);
                SubmitOutcome::Ticket(SampleTicket { shared })
            }
            Err(e) => {
                // Failures record latency too (satellite fix): the
                // histogram accounts for every resolved request, not only
                // the happy path.
                self.stats.requests_failed.inc();
                self.stats.request_latency.record(submitted.elapsed().as_nanos() as u64);
                if e.is_full() {
                    self.stats.shed.inc();
                    SubmitOutcome::Shed
                } else {
                    SubmitOutcome::Closed
                }
            }
        }
    }

    /// Convenience: submit and block for the result.
    pub fn sample(&self, n_samples: usize, seed: u64) -> anyhow::Result<Vec<SampleOutput<Obj>>> {
        self.submit(SampleRequest { n_samples, seed }).wait()
    }

    /// Current request backlog (excluding in-flight work).
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Whether the service has stopped accepting requests (shutdown begun,
    /// or the worker died and closed the queue behind it). `/healthz`
    /// reports this as a `service_closed` degradation.
    pub fn is_closed(&self) -> bool {
        self.queue.is_closed()
    }

    /// Deepest admission-queue backlog seen so far.
    pub fn queue_high_water(&self) -> usize {
        self.queue.high_water()
    }

    /// The shared stats handles (heartbeat age, in-flight gauge) for the
    /// health watchdog.
    pub fn stats_handles(&self) -> &Arc<ServeStats> {
        &self.stats
    }

    /// Point-in-time service counters.
    pub fn stats(&self) -> ServeSnapshot {
        self.stats.snapshot()
    }

    /// The telemetry registry backing this service's `serve.*` metrics
    /// (scoped by default; shared if spawned via [`SamplerService::spawn_in`]).
    pub fn registry(&self) -> &Arc<Registry> {
        self.stats.registry()
    }

    /// Stop accepting requests, finish queued + in-flight work, join the
    /// worker. (Dropping the service — or its last `Arc` — does the same.)
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<Obj> Drop for SamplerService<Obj> {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Resolve a work item with a timeout error and account for it.
fn fail_timeout<Obj>(
    ticket: &TicketShared<Obj>,
    submitted: Instant,
    detail: &str,
    stats: &ServeStats,
) {
    stats.requests_timedout.inc();
    stats.requests_failed.inc();
    stats.request_latency.record(submitted.elapsed().as_nanos() as u64);
    ticket.fulfill(Err(anyhow::anyhow!("{TIMEOUT_ERROR}: {detail}")));
}

/// Admit a work item: expired requests fail immediately (in-queue deadline
/// enforcement), zero-sample requests complete immediately; others enter
/// the in-flight table under a fresh stable id and join their client's
/// issuance lane.
fn admit<Obj>(
    drain: &RefCell<DrainState<Obj>>,
    item: WorkItem<Obj>,
    stats: &ServeStats,
) {
    if let Some(d) = item.opts.deadline {
        if Instant::now() >= d {
            fail_timeout(
                &item.ticket,
                item.submitted,
                &format!("expired in queue after {:?}", item.submitted.elapsed()),
                stats,
            );
            return;
        }
    }
    if !(item.opts.temperature.is_finite() && item.opts.temperature > 0.0) {
        // Reject here rather than letting the sampler's refill invariant
        // fire mid-drain, which would fail *every* in-flight request over
        // one bad parameter.
        stats.requests_failed.inc();
        stats.request_latency.record(item.submitted.elapsed().as_nanos() as u64);
        item.ticket.fulfill(Err(anyhow::anyhow!(
            "invalid temperature {}: must be finite and > 0",
            item.opts.temperature
        )));
        return;
    }
    if item.req.n_samples == 0 {
        // Count before fulfilling: a waiter that wakes on fulfill() must
        // already see the completion in a stats snapshot.
        stats.requests_completed.inc();
        stats.request_latency.record(item.submitted.elapsed().as_nanos() as u64);
        item.ticket.fulfill(Ok(Vec::new()));
        return;
    }
    let n = item.req.n_samples;
    let mut s = drain.borrow_mut();
    let id = s.next_id;
    s.next_id += 1;
    s.inflight.insert(
        id,
        InFlight {
            ticket: item.ticket,
            seed: item.req.seed,
            n,
            issued: 0,
            done: 0,
            outputs: (0..n).map(|_| None).collect(),
            submitted: item.submitted,
            temperature: item.opts.temperature,
            trace: item.trace,
            issued_at: None,
        },
    );
    stats.inflight.set(s.inflight.len() as f64);
    if let Some(d) = item.opts.deadline {
        s.deadlines.push(Reverse((d, id)));
    }
    let client = item.opts.client;
    match s.per_client.entry(client) {
        std::collections::hash_map::Entry::Occupied(e) => e.into_mut().push_back(id),
        std::collections::hash_map::Entry::Vacant(e) => {
            e.insert(VecDeque::from([id]));
            s.rotation.push_back(client);
        }
    }
}

/// Mid-drain deadline sweep: cancel every admitted request whose deadline
/// has passed. Trajectories already in the slot table keep running (the
/// engine has no preemption) but their results are diverted to the
/// `cancelled` discard ledger, so the ticket resolves *now*, not when the
/// stragglers finish.
fn expire_due<Obj>(
    s: &mut DrainState<Obj>,
    now: Instant,
    stats: &ServeStats,
    active: &ActiveTraces,
) {
    while let Some(&Reverse((d, id))) = s.deadlines.peek() {
        if d > now {
            break;
        }
        s.deadlines.pop();
        let Some(f) = s.inflight.remove(&id) else {
            continue; // completed before its deadline; stale heap entry
        };
        stats.inflight.set(s.inflight.len() as f64);
        if f.trace.is_some() {
            // Stop attributing dispatch slices to a cancelled request; the
            // front end finishes its trace when the ticket's timeout error
            // comes back.
            active.borrow_mut().retain(|(tid, _)| *tid != id);
        }
        let outstanding = f.issued - f.done;
        if outstanding > 0 {
            s.cancelled.insert(id, outstanding);
        }
        fail_timeout(
            &f.ticket,
            f.submitted,
            &format!("cancelled mid-drain with {}/{} trajectories done", f.done, f.n),
            stats,
        );
    }
}

fn worker_loop<E, F>(
    env: E,
    policy_factory: F,
    queue: Queue<WorkItem<E::Obj>>,
    stats: Arc<ServeStats>,
    swap: SwapSlot,
    max_inflight: Option<usize>,
) where
    E: VecEnv,
    F: FnOnce() -> anyhow::Result<Box<dyn BatchPolicy>>,
{
    let spec = env.spec();
    let active: ActiveTraces = Rc::new(RefCell::new(Vec::new()));
    let mut policy = match policy_factory() {
        Ok(p) => SwappablePolicy {
            current: p,
            slot: swap,
            stats: Arc::clone(&stats),
            spec,
            active_traces: Rc::clone(&active),
        },
        Err(e) => {
            // Refuse service: fail the backlog and all future submissions.
            queue.close();
            while let Some(item) = queue.try_pop() {
                stats.requests_failed.inc();
                stats.request_latency.record(item.submitted.elapsed().as_nanos() as u64);
                item.ticket.fulfill(Err(anyhow::anyhow!("policy init failed: {e}")));
            }
            return;
        }
    };

    stats.beat(); // ready to serve: the watchdog's liveness baseline

    loop {
        // Block for work (or shutdown once the queue is closed and drained).
        let first = match queue.pop_blocking() {
            Some(item) => item,
            None => return,
        };
        stats.beat();
        let drain: RefCell<DrainState<E::Obj>> = RefCell::new(DrainState::new());
        admit(&drain, first, &stats);

        // Drain: the engine pulls trajectories lazily; the job source keeps
        // admitting newly queued requests so they join the running batch.
        let result = sample_stream(
            &env,
            &mut policy,
            || {
                // Admit everything waiting (up to the in-flight bound)
                // before deciding what to issue: fairness requires
                // late-arriving clients to be in the rotation while an
                // earlier client's backlog is still being issued (the
                // pre-fairness code only polled the queue once the admitted
                // work was fully issued, which let one huge request starve
                // admission itself). The bound keeps admission from
                // swallowing the bounded queue into unbounded in-flight
                // state — with it, a flood genuinely backs up in the queue
                // and overflow sheds.
                loop {
                    if let Some(cap) = max_inflight {
                        if drain.borrow().inflight.len() >= cap {
                            break;
                        }
                    }
                    match queue.try_pop() {
                        Some(item) => admit(&drain, item, &stats),
                        None => break,
                    }
                }
                // Every job-source poll is dispatch progress: touch the
                // watchdog heartbeat so stall detection only fires when the
                // worker is genuinely stuck (e.g. parked inside an eval),
                // not merely busy.
                stats.beat();
                let mut guard = drain.borrow_mut();
                let s = &mut *guard;
                if s.deadlines.peek().is_some() {
                    expire_due(s, Instant::now(), &stats, &active);
                }
                // Round-robin across clients: issue ONE trajectory from the
                // front client's oldest request, then rotate, so no client's
                // backlog monopolizes slot refills.
                while let Some(&client) = s.rotation.front() {
                    let fifo = s
                        .per_client
                        .get_mut(&client)
                        .expect("rotation entry without per-client lane");
                    let mut job = None;
                    while let Some(&id) = fifo.front() {
                        // Lazy cleanup: ids whose request completed at issue
                        // time or was cancelled have left `inflight`.
                        let Some(f) = s.inflight.get_mut(&id) else {
                            fifo.pop_front();
                            continue;
                        };
                        debug_assert!(f.issued < f.n, "fully issued id still in lane");
                        let i = f.issued;
                        if i == 0 {
                            // First trajectory of this request enters the
                            // slot table: queueing delay is over. One shared
                            // instant ends `queue_wait` and starts `drain`,
                            // so the two segments tile the request's latency
                            // with no gap or overlap.
                            let issue = Instant::now();
                            stats.first_dispatch_latency.record(
                                issue.saturating_duration_since(f.submitted).as_nanos()
                                    as u64,
                            );
                            f.issued_at = Some(issue);
                            if let Some(tr) = &f.trace {
                                tr.segment("queue_wait", f.submitted, issue);
                                active.borrow_mut().push((id, Arc::clone(tr)));
                            }
                        }
                        f.issued += 1;
                        if f.issued == f.n {
                            fifo.pop_front();
                        }
                        job = Some(TrajJob {
                            request: id,
                            traj_index: i,
                            seed: traj_seed(f.seed, i as u64),
                            temperature: f.temperature,
                        });
                        break;
                    }
                    match job {
                        Some(job) => {
                            let c = s.rotation.pop_front().unwrap();
                            if s.per_client.get(&c).is_some_and(|f| !f.is_empty()) {
                                s.rotation.push_back(c);
                            } else {
                                s.per_client.remove(&c);
                            }
                            return Some(job);
                        }
                        None => {
                            // Lane drained: drop it (re-created on the
                            // client's next admission).
                            s.per_client.remove(&client);
                            s.rotation.pop_front();
                        }
                    }
                }
                None
            },
            |r: TrajResult<E::Obj>| {
                stats.trajectories_completed.inc();
                let mut guard = drain.borrow_mut();
                let s = &mut *guard;
                if let Some(left) = s.cancelled.get_mut(&r.request) {
                    // Straggler of a deadline-cancelled request: its ticket
                    // already resolved; discard the result and forget the
                    // request once its last slot drains.
                    *left -= 1;
                    if *left == 0 {
                        s.cancelled.remove(&r.request);
                    }
                    return;
                }
                let f = s
                    .inflight
                    .get_mut(&r.request)
                    .expect("trajectory for unknown request");
                debug_assert!(f.outputs[r.traj_index].is_none(), "duplicate trajectory");
                f.outputs[r.traj_index] = Some(SampleOutput {
                    obj: r.obj,
                    log_pf: r.log_pf,
                    log_reward: r.log_reward,
                    length: r.length,
                    traj_index: r.traj_index,
                });
                f.done += 1;
                if f.done == f.n {
                    // Prune the completed request so a long-lived drain does
                    // not accumulate history.
                    let f = s.inflight.remove(&r.request).unwrap();
                    stats.inflight.set(s.inflight.len() as f64);
                    let outs: Vec<SampleOutput<E::Obj>> = f
                        .outputs
                        .into_iter()
                        .map(|o| o.expect("missing trajectory"))
                        .collect();
                    // Count before fulfilling (see admit()): waiters woken
                    // by fulfill() read a consistent stats snapshot. The
                    // single `done` instant both closes the trace's `drain`
                    // segment and stamps the latency histogram, so
                    // queue_wait + drain equals the recorded latency exactly.
                    let done = Instant::now();
                    stats.requests_completed.inc();
                    stats.request_latency.record(
                        done.saturating_duration_since(f.submitted).as_nanos() as u64,
                    );
                    if let Some(tr) = &f.trace {
                        tr.segment("drain", f.issued_at.unwrap_or(f.submitted), done);
                        active.borrow_mut().retain(|(id, _)| *id != r.request);
                    }
                    f.ticket.fulfill(Ok(outs));
                }
            },
        );

        match result {
            Ok(s) => {
                stats.beat();
                stats.inflight.set(drain.borrow().inflight.len() as f64);
                stats.policy_dispatches.add(s.dispatches);
                stats.active_row_steps.add(s.active_row_steps);
                stats.total_row_steps.add(s.total_row_steps);
                let total = stats.total_row_steps.get();
                if total > 0 {
                    stats
                        .occupancy
                        .set(stats.active_row_steps.get() as f64 / total as f64);
                }
            }
            Err(e) => {
                // The engine is wedged (policy failure or env invariant
                // breach): fail everything in flight and queued, then stop
                // serving — later submissions error immediately.
                let msg = format!("serve worker failed: {e}");
                active.borrow_mut().clear();
                for f in drain.borrow_mut().inflight.values() {
                    stats.requests_failed.inc();
                    stats.request_latency.record(f.submitted.elapsed().as_nanos() as u64);
                    f.ticket.fulfill(Err(anyhow::anyhow!("{msg}")));
                }
                stats.inflight.set(0.0);
                queue.close();
                while let Some(item) = queue.try_pop() {
                    stats.requests_failed.inc();
                    stats.request_latency.record(item.submitted.elapsed().as_nanos() as u64);
                    item.ticket.fulfill(Err(anyhow::anyhow!("{msg}")));
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::hypergrid::HypergridEnv;
    use crate::reward::hypergrid::HypergridReward;
    use crate::runtime::policy::{PolicyShape, UniformPolicy};
    use crate::serve::request::is_timeout;
    use std::sync::Condvar;
    use std::time::Duration;

    fn service(b: usize) -> SamplerService<Vec<i32>> {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, b);
        SamplerService::spawn(env, move || {
            Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
        })
    }

    /// End-to-end worker drain: a request returns exactly `n` outputs whose
    /// objects decode to in-range coordinates with matching rewards, and
    /// the counters account for every trajectory.
    #[test]
    fn worker_serves_requests_end_to_end() {
        let svc = service(4);
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let outs = svc.sample(10, 7).unwrap();
        assert_eq!(outs.len(), 10);
        for o in &outs {
            assert!(o.obj.iter().all(|&c| (0..6).contains(&c)));
            use crate::envs::VecEnv;
            let want = env.log_reward_obj(&o.obj);
            assert!((o.log_reward - want).abs() < 1e-5);
            assert!(o.length >= 1);
        }
        let snap = svc.stats();
        assert_eq!(snap.requests_submitted, 1);
        assert_eq!(snap.requests_completed, 1);
        assert!(snap.trajectories_completed >= 10);
        svc.shutdown();
    }

    /// Per-trajectory determinism through the worker: the same request
    /// seed yields the same multiset of objects regardless of slot-table
    /// width.
    #[test]
    fn worker_results_are_deterministic_in_seed_across_widths() {
        let run = |b: usize| {
            let svc = service(b);
            let mut objs: Vec<Vec<i32>> =
                svc.sample(12, 99).unwrap().into_iter().map(|o| o.obj).collect();
            svc.shutdown();
            objs.sort();
            objs
        };
        assert_eq!(run(3), run(8));
    }

    /// Zero-sample requests complete immediately (the admit fast path).
    #[test]
    fn worker_completes_empty_requests() {
        let svc = service(2);
        let outs = svc.sample(0, 1).unwrap();
        assert!(outs.is_empty());
        assert_eq!(svc.stats().requests_completed, 1);
        svc.shutdown();
    }

    /// Live hot-swap: after swapping a trained `NativePolicy` over the
    /// uniform one, the service's samples are exactly what a service
    /// spawned with that policy directly would produce (the per-trajectory
    /// seed streams make this a bitwise statement, not a distributional
    /// one), and the swap is counted.
    #[test]
    fn hot_swap_switches_the_serving_policy() {
        use crate::runtime::{NativeBackend, NativeConfig};
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let native = NativeBackend::new(NativeConfig::for_env(&env, 4, "tb").with_hidden(16), 21)
            .unwrap()
            .to_policy();

        // Reference: a service born with the native policy.
        let reference = {
            let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
            let p = native.clone();
            let svc = SamplerService::spawn(env, move || {
                Ok(Box::new(p) as Box<dyn BatchPolicy>)
            });
            let mut objs: Vec<Vec<i32>> =
                svc.sample(15, 33).unwrap().into_iter().map(|o| o.obj).collect();
            svc.shutdown();
            objs.sort();
            objs
        };

        // A uniform-policy service, swapped live.
        let svc = service(4);
        let _ = svc.sample(5, 1).unwrap(); // pre-swap traffic
        svc.hot_swap(Box::new(native));
        let mut objs: Vec<Vec<i32>> =
            svc.sample(15, 33).unwrap().into_iter().map(|o| o.obj).collect();
        objs.sort();
        assert_eq!(objs, reference, "post-swap samples must come from the new policy");
        let snap = svc.stats();
        assert_eq!(snap.policy_swaps, 1);
        assert_eq!(snap.swaps_rejected, 0);
        svc.shutdown();
    }

    /// A mis-shaped swap is dropped (counted, service unharmed) instead of
    /// corrupting the slot table.
    #[test]
    fn hot_swap_rejects_shape_mismatch() {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let svc = service(4);
        // Wrong batch width.
        let bad = UniformPolicy::new(PolicyShape::of_env(&env, 9));
        svc.hot_swap(Box::new(bad));
        let outs = svc.sample(8, 3).unwrap();
        assert_eq!(outs.len(), 8, "service keeps serving after a rejected swap");
        let snap = svc.stats();
        assert_eq!(snap.swaps_rejected, 1);
        assert_eq!(snap.policy_swaps, 0);
        svc.shutdown();
    }

    /// Model-aware swap gate: a transformer policy whose token grid
    /// factorizes the right `obs_dim` the wrong way (3×4 over hypergrid's
    /// 2×6) passes the plain shape check but is rejected by the token-shape
    /// check; one that matches the env's grid swaps in and serves.
    #[test]
    fn hot_swap_rejects_token_grid_mismatch_and_accepts_match() {
        use crate::runtime::{ModelSpec, NativeBackend, NativeConfig, TransformerArch};
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let svc = service(4);

        // Same PolicyShape (obs_dim 12), wrong factorization: 3×4 ≠ 2×6.
        let arch = |seq_len, token_dim| TransformerArch {
            seq_len,
            token_dim,
            embed: 8,
            n_heads: 2,
            ff_hidden: 16,
            causal: false,
        };
        let bad = NativeBackend::new(
            NativeConfig::for_env(&env, 4, "tb")
                .with_model(ModelSpec::Transformer(arch(3, 4))),
            5,
        )
        .unwrap()
        .to_policy();
        svc.hot_swap(Box::new(bad));
        let outs = svc.sample(6, 11).unwrap();
        assert_eq!(outs.len(), 6, "service keeps serving after a rejected swap");
        assert_eq!(svc.stats().swaps_rejected, 1);
        assert_eq!(svc.stats().policy_swaps, 0);

        // Matching grid (2×6): the swap applies and the service serves from
        // the transformer.
        let good = NativeBackend::new(
            NativeConfig::for_env(&env, 4, "tb")
                .with_model(ModelSpec::Transformer(arch(2, 6))),
            5,
        )
        .unwrap()
        .to_policy();
        svc.hot_swap(Box::new(good));
        let outs = svc.sample(6, 12).unwrap();
        assert_eq!(outs.len(), 6);
        assert_eq!(svc.stats().policy_swaps, 1);
        assert_eq!(svc.stats().swaps_rejected, 1);
        svc.shutdown();
    }

    /// Latest-wins mailbox: two swaps before any dispatch apply only the
    /// second.
    #[test]
    fn hot_swap_latest_wins() {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, 4);
        let svc = service(4);
        svc.hot_swap(Box::new(UniformPolicy::new(shape)));
        svc.hot_swap(Box::new(UniformPolicy::new(shape)));
        let _ = svc.sample(4, 0).unwrap();
        assert_eq!(svc.stats().policy_swaps, 1, "only the latest pending swap applies");
        svc.shutdown();
    }

    /// Satellite: failure accounting. With a policy that fails mid-serve,
    /// every submitted request is answered exactly once — completed (the
    /// zero-sample fast path) or failed (in-flight, queued, and
    /// post-shutdown submissions) — so
    /// `submitted == completed + failed + pending` holds with `pending = 0`
    /// once all tickets resolve.
    #[test]
    fn failure_accounting_balances_under_worker_shutdown() {
        struct FailingPolicy {
            shape: PolicyShape,
        }
        impl BatchPolicy for FailingPolicy {
            fn shape(&self) -> PolicyShape {
                self.shape
            }
            fn eval(
                &mut self,
                _obs: &[f32],
                _fwd: &[f32],
                _bwd: &[f32],
            ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
                anyhow::bail!("injected policy failure")
            }
        }
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, 4);
        let svc: SamplerService<Vec<i32>> = SamplerService::spawn(env, move || {
            Ok(Box::new(FailingPolicy { shape }) as Box<dyn BatchPolicy>)
        });
        let t0 = svc.submit(SampleRequest { n_samples: 0, seed: 1 });
        let t1 = svc.submit(SampleRequest { n_samples: 5, seed: 2 });
        let t2 = svc.submit(SampleRequest { n_samples: 3, seed: 3 });
        assert!(t0.wait().is_ok(), "empty request completes before any dispatch");
        assert!(t1.wait().is_err(), "in-flight request fails with the worker");
        assert!(t2.wait().is_err(), "queued request fails on worker shutdown");
        // The worker has stopped serving: a late submission fails too,
        // either immediately (queue closed) or via the drain loop.
        let t3 = svc.submit(SampleRequest { n_samples: 2, seed: 4 });
        assert!(t3.wait().is_err());
        let snap = svc.stats();
        assert_eq!(snap.requests_submitted, 4);
        assert_eq!(snap.requests_completed, 1);
        assert_eq!(snap.requests_failed, 3);
        assert_eq!(
            snap.requests_submitted,
            snap.requests_completed + snap.requests_failed,
            "no request lost or double-counted"
        );
        svc.shutdown();
    }

    /// The service's latency histograms and occupancy gauge live in its
    /// registry and populate per request.
    #[test]
    fn latency_histograms_and_occupancy_populate() {
        let svc = service(4);
        let reg = Arc::clone(svc.registry());
        let outs = svc.sample(8, 5).unwrap();
        assert_eq!(outs.len(), 8);
        svc.shutdown(); // drain accounting (occupancy gauge) lands by join
        let lat = reg.histogram("serve.request_latency").snapshot();
        assert_eq!(lat.count, 1, "one completed request, one latency sample");
        assert!(lat.sum > 0);
        assert!(lat.percentile(0.5) <= lat.percentile(0.99));
        assert_eq!(reg.histogram("serve.first_dispatch_latency").count(), 1);
        let occ = reg.gauge("serve.occupancy").get();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy gauge set after drain: {occ}");
    }

    /// Tentpole: a traced request's waterfall reconciles *exactly* with the
    /// `serve.request_latency` histogram — `queue_wait + drain` equals the
    /// recorded latency to the nanosecond (shared instants at both segment
    /// boundaries), the two segments tile without gap, and every `dispatch`
    /// slice nests inside `drain`.
    #[test]
    fn traced_request_reconciles_with_latency_histogram() {
        let _g = crate::telemetry::flag_test_lock();
        trace::set_trace_rate(1.0);
        trace::reset_sampler();
        let svc = service(4);
        let tr = trace::try_start("http_request").expect("rate 1.0 samples everything");
        let ticket = match svc.try_submit_traced(
            SampleRequest { n_samples: 6, seed: 3 },
            SubmitOptions::default(),
            Some(Arc::clone(&tr)),
        ) {
            SubmitOutcome::Ticket(t) => t,
            other => panic!("expected admission, got {other:?}"),
        };
        assert_eq!(ticket.wait().unwrap().len(), 6);
        tr.finish(true);
        trace::set_trace_rate(0.0);

        let rec = trace::tracer()
            .recent(trace::TRACE_RING)
            .into_iter()
            .find(|r| r.id == tr.id())
            .expect("finished trace in the ring");
        assert!(rec.ok);
        let segs = |name: &str| -> Vec<_> {
            rec.segments.iter().filter(|s| s.name == name).collect()
        };
        let qw = segs("queue_wait");
        let dr = segs("drain");
        let dispatch = segs("dispatch");
        assert_eq!(qw.len(), 1, "exactly one queue_wait: {:?}", rec.segments);
        assert_eq!(dr.len(), 1, "exactly one drain: {:?}", rec.segments);
        assert!(!dispatch.is_empty(), "at least one dispatch slice");
        // Exact reconciliation with the histogram's single sample.
        let lat = svc.registry().histogram("serve.request_latency").sum();
        assert_eq!(qw[0].dur_ns + dr[0].dur_ns, lat);
        // queue_wait and drain tile the request with no gap or overlap.
        assert_eq!(qw[0].start_ns + qw[0].dur_ns, dr[0].start_ns);
        // Dispatch slices nest inside the drain window.
        let drain_end = dr[0].start_ns + dr[0].dur_ns;
        for s in &dispatch {
            assert!(s.start_ns >= dr[0].start_ns && s.start_ns + s.dur_ns <= drain_end);
        }
        assert!(dispatch.iter().map(|s| s.dur_ns).sum::<u64>() <= dr[0].dur_ns);
        svc.shutdown();
    }

    /// Untraced requests leave no segments behind and the dispatch-slice
    /// log stays empty (the disabled fast path).
    #[test]
    fn untraced_requests_record_no_waterfall() {
        let _g = crate::telemetry::flag_test_lock();
        trace::set_trace_rate(0.0);
        let before = trace::tracer().recent(trace::TRACE_RING).len();
        let svc = service(4);
        assert_eq!(svc.sample(5, 2).unwrap().len(), 5);
        svc.shutdown();
        assert_eq!(
            trace::tracer().recent(trace::TRACE_RING).len(),
            before,
            "tracing off: no new records"
        );
    }

    // ---- production-envelope tests (bounded queue, deadlines, fairness) ----

    #[derive(Default)]
    struct GateState {
        arrived: bool,
        open: bool,
    }
    type Gate = Arc<(Mutex<GateState>, Condvar)>;

    /// A policy that parks every `eval` until the gate opens, and flags
    /// when the worker first arrives — lets tests line up queue states
    /// deterministically instead of racing on sleeps.
    struct GatedPolicy {
        inner: UniformPolicy,
        gate: Gate,
    }

    impl BatchPolicy for GatedPolicy {
        fn shape(&self) -> PolicyShape {
            self.inner.shape()
        }
        fn eval(
            &mut self,
            obs: &[f32],
            fwd: &[f32],
            bwd: &[f32],
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            let (m, cv) = &*self.gate;
            let mut st = m.lock().unwrap();
            st.arrived = true;
            cv.notify_all();
            while !st.open {
                st = cv.wait(st).unwrap();
            }
            drop(st);
            self.inner.eval(obs, fwd, bwd)
        }
    }

    fn gated_service(b: usize, cap: Option<usize>) -> (SamplerService<Vec<i32>>, Gate) {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, b);
        let gate: Gate = Arc::new((Mutex::new(GateState::default()), Condvar::new()));
        let g = Arc::clone(&gate);
        let svc = SamplerService::spawn_with(
            env,
            move || {
                Ok(Box::new(GatedPolicy { inner: UniformPolicy::new(shape), gate: g })
                    as Box<dyn BatchPolicy>)
            },
            Arc::new(Registry::new()),
            cap,
        );
        (svc, gate)
    }

    fn wait_arrived(gate: &Gate) {
        let (m, cv) = &**gate;
        let mut st = m.lock().unwrap();
        while !st.arrived {
            st = cv.wait(st).unwrap();
        }
    }

    fn open_gate(gate: &Gate) {
        let (m, cv) = &**gate;
        m.lock().unwrap().open = true;
        cv.notify_all();
    }

    /// Satellite: bounded-queue admission. With the worker parked mid-eval
    /// and a capacity-1 queue, the first extra submission queues, the
    /// second is shed (`SubmitOutcome::Shed`, `serve.shed`), and after the
    /// gate opens the admitted requests complete — the accounting and the
    /// latency histogram cover all three resolutions.
    #[test]
    fn bounded_queue_sheds_and_counts() {
        let (svc, gate) = gated_service(2, Some(1));
        let t_a = svc.submit(SampleRequest { n_samples: 2, seed: 1 });
        wait_arrived(&gate); // worker parked in eval; backlog empty
        let t_b = match svc.try_submit(SampleRequest { n_samples: 2, seed: 2 }, SubmitOptions::default()) {
            SubmitOutcome::Ticket(t) => t,
            other => panic!("expected admission, got {other:?}"),
        };
        assert!(
            matches!(
                svc.try_submit(SampleRequest { n_samples: 2, seed: 3 }, SubmitOptions::default()),
                SubmitOutcome::Shed
            ),
            "capacity-1 queue must shed the second extra request"
        );
        assert_eq!(svc.stats().shed, 1);
        // submit() over a full queue resolves the same way, via a
        // pre-failed ticket.
        let t_d = svc.submit(SampleRequest { n_samples: 2, seed: 4 });
        assert!(t_d.wait().is_err());
        open_gate(&gate);
        assert_eq!(t_a.wait().unwrap().len(), 2);
        assert_eq!(t_b.wait().unwrap().len(), 2);
        let snap = svc.stats();
        assert_eq!(snap.requests_submitted, 4);
        assert_eq!(snap.requests_completed, 2);
        assert_eq!(snap.requests_failed, 2);
        assert_eq!(snap.shed, 2);
        assert_eq!(
            svc.registry().histogram("serve.request_latency").count(),
            4,
            "failed (shed) requests record latency too"
        );
        svc.shutdown();
    }

    /// Satellite: in-queue deadline expiry. A request whose deadline passes
    /// while it waits behind a parked worker is failed at admission with a
    /// recognizable timeout error; the service keeps serving.
    #[test]
    fn deadline_expires_in_queue() {
        let (svc, gate) = gated_service(2, None);
        let t_a = svc.submit(SampleRequest { n_samples: 1, seed: 1 });
        wait_arrived(&gate);
        let t_b = svc.submit_opts(
            SampleRequest { n_samples: 1, seed: 2 },
            SubmitOptions {
                deadline: Some(Instant::now() + Duration::from_millis(20)),
                ..SubmitOptions::default()
            },
        );
        std::thread::sleep(Duration::from_millis(50)); // let it expire in queue
        open_gate(&gate);
        let err = t_b.wait().unwrap_err();
        assert!(is_timeout(&err), "expected a timeout error, got: {err}");
        assert_eq!(t_a.wait().unwrap().len(), 1);
        let snap = svc.stats();
        assert_eq!(snap.requests_timedout, 1);
        assert_eq!(snap.requests_failed, 1);
        assert_eq!(snap.requests_completed, 1);
        svc.shutdown();
    }

    /// A policy that sleeps per eval — slow enough that deadlines and
    /// fairness observations are deterministic at test timescales.
    struct SlowPolicy {
        inner: UniformPolicy,
        delay: Duration,
    }

    impl BatchPolicy for SlowPolicy {
        fn shape(&self) -> PolicyShape {
            self.inner.shape()
        }
        fn eval(
            &mut self,
            obs: &[f32],
            fwd: &[f32],
            bwd: &[f32],
        ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
            std::thread::sleep(self.delay);
            self.inner.eval(obs, fwd, bwd)
        }
    }

    fn slow_service(b: usize, delay: Duration) -> SamplerService<Vec<i32>> {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, b);
        SamplerService::spawn(env, move || {
            Ok(Box::new(SlowPolicy { inner: UniformPolicy::new(shape), delay })
                as Box<dyn BatchPolicy>)
        })
    }

    /// Satellite: mid-drain deadline expiry. A request far too large to
    /// finish by its deadline is cancelled *while draining* — the ticket
    /// resolves with a timeout well within 2× the deadline (not after all
    /// n trajectories), already-running slot work is discarded harmlessly,
    /// and the service keeps serving afterwards.
    #[test]
    fn deadline_expires_mid_drain() {
        let svc = slow_service(2, Duration::from_millis(5));
        let deadline = Duration::from_millis(300);
        let t0 = Instant::now();
        let t_big = svc.submit_opts(
            SampleRequest { n_samples: 500, seed: 7 },
            SubmitOptions {
                deadline: Some(t0 + deadline),
                ..SubmitOptions::default()
            },
        );
        let err = t_big.wait().unwrap_err();
        let elapsed = t0.elapsed();
        assert!(is_timeout(&err), "expected a timeout error, got: {err}");
        assert!(
            elapsed < 2 * deadline,
            "cancel must land promptly after the deadline, took {elapsed:?}"
        );
        // The drain survived the cancellation: stragglers were discarded,
        // and fresh requests are served.
        let outs = svc.sample(3, 9).unwrap();
        assert_eq!(outs.len(), 3);
        let snap = svc.stats();
        assert_eq!(snap.requests_timedout, 1);
        assert_eq!(snap.requests_completed, 1);
        assert_eq!(
            snap.requests_submitted,
            snap.requests_completed + snap.requests_failed
        );
        svc.shutdown();
    }

    /// Satellite: per-client round-robin fairness. A small request from
    /// client 2 submitted behind a huge request from client 1 interleaves
    /// into the slot table and resolves while the big one is still
    /// draining — no starvation.
    #[test]
    fn concurrent_clients_do_not_starve() {
        let svc = slow_service(2, Duration::from_millis(2));
        let t_big = svc.submit_opts(
            SampleRequest { n_samples: 300, seed: 1 },
            SubmitOptions { client: 1, ..SubmitOptions::default() },
        );
        let t_small = svc.submit_opts(
            SampleRequest { n_samples: 4, seed: 2 },
            SubmitOptions { client: 2, ..SubmitOptions::default() },
        );
        let outs = t_small.wait().unwrap();
        assert_eq!(outs.len(), 4);
        assert!(
            !t_big.is_ready(),
            "the huge request must still be draining when the small one resolves"
        );
        assert_eq!(t_big.wait().unwrap().len(), 300);
        let snap = svc.stats();
        assert_eq!(snap.requests_completed, 2);
        svc.shutdown();
    }

    /// Temperature rides `SubmitOptions` end-to-end: T = 1 is bitwise
    /// identical to a plain submit; an invalid temperature fails the
    /// request (and the whole worker refuses it before corrupting state).
    #[test]
    fn submit_opts_temperature_end_to_end() {
        let svc = service(4);
        let a: Vec<Vec<i32>> = svc
            .submit_opts(
                SampleRequest { n_samples: 10, seed: 5 },
                SubmitOptions { temperature: 1.0, ..SubmitOptions::default() },
            )
            .wait()
            .unwrap()
            .into_iter()
            .map(|o| o.obj)
            .collect();
        let b: Vec<Vec<i32>> =
            svc.sample(10, 5).unwrap().into_iter().map(|o| o.obj).collect();
        assert_eq!(a, b, "T = 1.0 must be bitwise identical to the default path");
        svc.shutdown();

        // Hot sampling still returns valid objects (distribution checks
        // live in the rng/sampler tests; here we prove the plumbing).
        let svc = service(4);
        let outs = svc
            .submit_opts(
                SampleRequest { n_samples: 6, seed: 8 },
                SubmitOptions { temperature: 3.0, ..SubmitOptions::default() },
            )
            .wait()
            .unwrap();
        assert_eq!(outs.len(), 6);
        for o in &outs {
            assert!(o.obj.iter().all(|&c| (0..6).contains(&c)));
        }

        // An invalid temperature fails only its own request — the service
        // keeps serving everyone else.
        let err = svc
            .submit_opts(
                SampleRequest { n_samples: 2, seed: 1 },
                SubmitOptions { temperature: 0.0, ..SubmitOptions::default() },
            )
            .wait()
            .unwrap_err();
        assert!(err.to_string().contains("invalid temperature"), "{err}");
        assert_eq!(svc.sample(2, 2).unwrap().len(), 2);
        svc.shutdown();
    }
}
