//! A std-only MPSC queue with close semantics and an optional depth bound.
//!
//! `std::sync::mpsc` lacks the three things the serve worker needs — a
//! non-blocking `try_pop` usable alongside blocking pops from the same
//! consumer, an observable close state that immediately wakes blocked
//! consumers, and a non-blocking bounded `push` whose "full" outcome is
//! distinguishable from "closed" (the HTTP admission layer sheds on the
//! former and errors on the latter) — so, in the spirit of
//! `util::threadpool` (no rayon/tokio in the image), this is a small
//! `Mutex` + `Condvar` queue.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// Why a [`Queue::push`] was refused. The rejected item is handed back so
/// the caller can resolve its ticket (nothing is silently dropped).
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at its capacity bound (backpressure — shed the item).
    Full(T),
    /// The queue is closed (service shut down — fail the item).
    Closed(T),
}

impl<T> PushError<T> {
    /// Recover the rejected item.
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(t) | PushError::Closed(t) => t,
        }
    }

    pub fn is_full(&self) -> bool {
        matches!(self, PushError::Full(_))
    }
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
    /// Deepest backlog ever observed (set at push, under the same lock).
    high_water: usize,
}

struct Inner<T> {
    state: Mutex<QueueState<T>>,
    cv: Condvar,
    /// Depth bound; `usize::MAX` = unbounded.
    capacity: usize,
}

/// A multi-producer queue; clones share the same underlying channel.
pub struct Queue<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Queue<T> {
    fn clone(&self) -> Self {
        Queue { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Default for Queue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Queue<T> {
    /// An unbounded queue (pushes only fail once closed).
    pub fn new() -> Queue<T> {
        Self::with_capacity(usize::MAX)
    }

    /// A queue that refuses pushes beyond `capacity` queued items with
    /// [`PushError::Full`] — non-blocking backpressure, not a blocking
    /// bound: the producer (an HTTP connection thread) must be able to
    /// answer 503 immediately instead of stalling on a slow worker.
    pub fn with_capacity(capacity: usize) -> Queue<T> {
        Queue {
            inner: Arc::new(Inner {
                state: Mutex::new(QueueState {
                    items: VecDeque::new(),
                    closed: false,
                    high_water: 0,
                }),
                cv: Condvar::new(),
                capacity,
            }),
        }
    }

    /// Enqueue an item. Refuses with [`PushError::Closed`] after
    /// [`Queue::close`] and with [`PushError::Full`] at the capacity bound,
    /// handing the item back either way.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.state.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.inner.capacity {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        g.high_water = g.high_water.max(g.items.len());
        self.inner.cv.notify_one();
        Ok(())
    }

    /// Dequeue, blocking until an item arrives or the queue is closed *and*
    /// drained (`None`).
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.state.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.inner.cv.wait(g).unwrap();
        }
    }

    /// Dequeue without blocking.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.state.lock().unwrap().items.pop_front()
    }

    /// Close the queue: future pushes fail, blocked consumers drain the
    /// backlog and then observe `None`.
    pub fn close(&self) {
        let mut g = self.inner.state.lock().unwrap();
        g.closed = true;
        self.inner.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.state.lock().unwrap().closed
    }

    /// Current backlog depth.
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deepest backlog this queue has ever held — the watchdog's
    /// "how close did admission come to shedding" signal. Monotone;
    /// unaffected by pops.
    pub fn high_water(&self) -> usize {
        self.inner.state.lock().unwrap().high_water
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_len() {
        let q = Queue::new();
        assert!(q.is_empty());
        for i in 0..5 {
            assert!(q.push(i).is_ok());
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = Queue::new();
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(
            matches!(q.push(3), Err(PushError::Closed(3))),
            "push after close must fail Closed and hand the item back"
        );
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: Queue<u32> = Queue::new();
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn multi_producer_single_consumer() {
        let q: Queue<usize> = Queue::new();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        assert!(q.push(p * 100 + i).is_ok());
                    }
                })
            })
            .collect();
        let mut got = Vec::new();
        while got.len() < 400 {
            if let Some(v) = q.pop_blocking() {
                got.push(v);
            }
        }
        for t in producers {
            t.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, (0..400).collect::<Vec<_>>());
    }

    /// The capacity bound sheds with `Full` (distinct from `Closed`), and
    /// popping reopens exactly that much headroom.
    #[test]
    fn bounded_queue_sheds_with_full_not_closed() {
        let q = Queue::with_capacity(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        match q.push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(q.len(), 2, "shed items are not enqueued");
        assert_eq!(q.try_pop(), Some(1));
        q.push(4).unwrap(); // headroom back after a pop
        assert!(matches!(q.push(5), Err(PushError::Full(5))));
        let e = q.push(6).unwrap_err();
        assert!(e.is_full());
        assert_eq!(e.into_inner(), 6);
    }

    /// High-water marks the deepest backlog ever held, surviving pops.
    #[test]
    fn high_water_tracks_peak_depth() {
        let q = Queue::new();
        assert_eq!(q.high_water(), 0);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.high_water(), 3);
        q.try_pop();
        q.try_pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.high_water(), 3, "high-water is monotone");
        q.push(4).unwrap();
        assert_eq!(q.high_water(), 3, "depth 2 does not move a peak of 3");
        q.push(5).unwrap();
        q.push(6).unwrap();
        assert_eq!(q.high_water(), 4);
    }

    /// Regression (satellite): closing a *full* bounded queue must drain
    /// cleanly — consumers see the whole backlog then `None`, producers see
    /// `Closed` (not `Full`), and nothing deadlocks.
    #[test]
    fn close_while_full_drains_without_deadlock() {
        let q = Queue::with_capacity(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.push(3).unwrap_err().is_full());
        q.close();
        // Closed wins over Full: a producer must learn the queue is gone,
        // not be told to retry a shed.
        assert!(matches!(q.push(4), Err(PushError::Closed(4))));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = q2.pop_blocking() {
                got.push(v);
            }
            got
        });
        assert_eq!(consumer.join().unwrap(), vec![1, 2]);
        assert_eq!(q.pop_blocking(), None);
    }
}
