//! A std-only MPSC queue with close semantics.
//!
//! `std::sync::mpsc` lacks the two things the serve worker needs — a
//! non-blocking `try_pop` usable alongside blocking pops from the same
//! consumer, and an observable close state that immediately wakes blocked
//! consumers — so, in the spirit of `util::threadpool` (no rayon/tokio in
//! the image), this is a small `Mutex` + `Condvar` queue.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

struct Inner<T> {
    state: Mutex<QueueState<T>>,
    cv: Condvar,
}

/// A multi-producer queue; clones share the same underlying channel.
pub struct Queue<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Queue<T> {
    fn clone(&self) -> Self {
        Queue { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Default for Queue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Queue<T> {
    pub fn new() -> Queue<T> {
        Queue {
            inner: Arc::new(Inner {
                state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Enqueue an item. Returns `false` (dropping the item) if the queue is
    /// closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.state.lock().unwrap();
        if g.closed {
            return false;
        }
        g.items.push_back(item);
        self.inner.cv.notify_one();
        true
    }

    /// Dequeue, blocking until an item arrives or the queue is closed *and*
    /// drained (`None`).
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.state.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.inner.cv.wait(g).unwrap();
        }
    }

    /// Dequeue without blocking.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.state.lock().unwrap().items.pop_front()
    }

    /// Close the queue: future pushes fail, blocked consumers drain the
    /// backlog and then observe `None`.
    pub fn close(&self) {
        let mut g = self.inner.state.lock().unwrap();
        g.closed = true;
        self.inner.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.state.lock().unwrap().closed
    }

    /// Current backlog depth.
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_len() {
        let q = Queue::new();
        assert!(q.is_empty());
        for i in 0..5 {
            assert!(q.push(i));
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = Queue::new();
        q.push(1);
        q.push(2);
        q.close();
        assert!(!q.push(3), "push after close must fail");
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: Queue<u32> = Queue::new();
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn multi_producer_single_consumer() {
        let q: Queue<usize> = Queue::new();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100 {
                        assert!(q.push(p * 100 + i));
                    }
                })
            })
            .collect();
        let mut got = Vec::new();
        while got.len() < 400 {
            if let Some(v) = q.pop_blocking() {
                got.push(v);
            }
        }
        for t in producers {
            t.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, (0..400).collect::<Vec<_>>());
    }
}
