//! The continuous-batching slot engine.
//!
//! A fixed-`B` slot table rides on one fixed-shape policy dispatch per env
//! step. Idle slots are refilled from a lazy job source *before every
//! dispatch*, so a slot is empty for at most zero dispatches while work is
//! available — the defining property of continuous batching. Idle slots are
//! staged as zeroed-obs / action-0-legal sentinels (the same convention as
//! `RolloutCtx::stage`) so the masked softmax stays finite.
//!
//! The engine is synchronous and thread-free; the service layer
//! ([`crate::serve::worker`]) runs it on a dedicated thread, and
//! `Trainer::sample_objs_served` runs it inline.

use crate::coordinator::rollout::RolloutCtx;
use crate::envs::{VecEnv, NOOP};
use crate::runtime::policy::BatchPolicy;
use crate::util::rng::Rng;

/// One trajectory of work for the slot engine.
#[derive(Clone, Copy, Debug)]
pub struct TrajJob {
    /// Caller-side request tag (opaque to the engine; echoed in results).
    pub request: u64,
    /// Trajectory index within the request.
    pub traj_index: usize,
    /// Seed of this trajectory's dedicated RNG stream.
    pub seed: u64,
    /// Sampling temperature: actions are drawn from softmax(logits / T)
    /// over the legal set. `1.0` (the training distribution) is bitwise
    /// identical to the pre-temperature engine — see
    /// [`Rng::categorical_masked_scaled`]. The reported `log_pf` is always
    /// Σ log P_F under the *untempered* policy, so downstream importance
    /// corrections stay well-defined.
    pub temperature: f64,
}

/// One finished trajectory.
#[derive(Clone, Debug)]
pub struct TrajResult<Obj> {
    pub request: u64,
    pub traj_index: usize,
    pub obj: Obj,
    /// Σ_t log P_F of the sampled actions under the serving policy.
    pub log_pf: f64,
    /// Terminal log-reward (from the env's terminal transition).
    pub log_reward: f64,
    /// Number of forward transitions.
    pub length: usize,
}

/// Aggregate statistics of one engine run.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Fixed-shape policy dispatches executed.
    pub dispatches: u64,
    /// Slot-steps that carried a live trajectory.
    pub active_row_steps: u64,
    /// Total slot-steps (`dispatches × B`).
    pub total_row_steps: u64,
    /// Trajectories completed.
    pub completed: u64,
}

impl StreamStats {
    /// Fraction of slot-steps that did useful work (1.0 = perfectly packed).
    pub fn occupancy(&self) -> f64 {
        if self.total_row_steps == 0 {
            1.0
        } else {
            self.active_row_steps as f64 / self.total_row_steps as f64
        }
    }

    pub fn merge(&mut self, other: &StreamStats) {
        self.dispatches += other.dispatches;
        self.active_row_steps += other.active_row_steps;
        self.total_row_steps += other.total_row_steps;
        self.completed += other.completed;
    }
}

/// Per-slot bookkeeping for an in-flight trajectory.
struct SlotJob {
    request: u64,
    traj_index: usize,
    rng: Rng,
    /// Inverse sampling temperature (`1.0 / TrajJob::temperature`).
    inv_t: f64,
    log_pf: f64,
    steps: usize,
}

/// Drive trajectories through the slot table until the job source is dry
/// and every in-flight trajectory has finished.
///
/// `next_job` is polled once per idle slot per step; it may return `None`
/// now and `Some` on a later poll (the service layer uses this to merge
/// late-arriving requests into the running batch). `sink` is invoked once
/// per finished trajectory, in completion order.
///
/// Determinism: each trajectory's actions are drawn from its own
/// `Rng::new(job.seed)` stream, so for row-wise policies the result of a
/// trajectory does not depend on slot assignment, on `B`, or on what else
/// shared its dispatches.
pub fn sample_stream<E, P, F, S>(
    env: &E,
    policy: &mut P,
    mut next_job: F,
    mut sink: S,
) -> anyhow::Result<StreamStats>
where
    E: VecEnv,
    P: BatchPolicy + ?Sized,
    F: FnMut() -> Option<TrajJob>,
    S: FnMut(TrajResult<E::Obj>),
{
    let spec = env.spec();
    let shape = policy.shape();
    anyhow::ensure!(
        shape.obs_dim == spec.obs_dim
            && shape.n_actions == spec.n_actions
            && shape.n_bwd_actions == spec.n_bwd_actions,
        "env spec {:?} does not match policy shape {:?}",
        spec,
        shape
    );
    let b = shape.batch;
    anyhow::ensure!(b > 0, "policy batch must be positive");
    let mut state = env.reset(b);
    let mut slots: Vec<Option<SlotJob>> = (0..b).map(|_| None).collect();
    let mut stats = StreamStats::default();

    let mut ctx = RolloutCtx::for_shape(&shape);
    let mut skip = vec![true; b];
    let mut mask_scratch = vec![false; spec.n_actions];
    let mut actions = vec![NOOP; b];

    loop {
        // Refill idle slots from the job source (the "continuous" part:
        // this happens before every dispatch, not per batch drain).
        for i in 0..b {
            if slots[i].is_none() {
                if let Some(job) = next_job() {
                    anyhow::ensure!(
                        job.temperature.is_finite() && job.temperature > 0.0,
                        "trajectory temperature must be finite and positive, got {}",
                        job.temperature
                    );
                    env.reset_row(&mut state, i);
                    slots[i] = Some(SlotJob {
                        request: job.request,
                        traj_index: job.traj_index,
                        rng: Rng::new(job.seed),
                        inv_t: 1.0 / job.temperature,
                        log_pf: 0.0,
                        steps: 0,
                    });
                }
            }
        }
        if slots.iter().all(|s| s.is_none()) {
            break; // source dry and table drained
        }

        // Stage the dispatch: live rows get real obs/masks; idle slots get
        // the shared dead-row sentinel convention (RolloutCtx::stage).
        for i in 0..b {
            skip[i] = slots[i].is_none();
        }
        ctx.stage(env, &state, &skip);

        // One fixed-shape dispatch for the whole table.
        let (fwd_logp, _bwd_logp, _flow) = policy.eval(&ctx.obs, &ctx.fwd_mask, &ctx.bwd_mask)?;
        stats.dispatches += 1;
        stats.total_row_steps += b as u64;

        // Sample actions for live slots from their private RNG streams.
        for i in 0..b {
            actions[i] = NOOP;
            if let Some(job) = slots[i].as_mut() {
                env.fwd_mask_into(&state, i, &mut mask_scratch);
                let row = &fwd_logp[i * spec.n_actions..(i + 1) * spec.n_actions];
                let a = job.rng.categorical_masked_scaled(row, &mask_scratch, job.inv_t) as i32;
                actions[i] = a;
                job.log_pf += row[a as usize] as f64;
                job.steps += 1;
                stats.active_row_steps += 1;
            }
        }

        let out = env.step(&mut state, &actions);

        // Emit finished trajectories; their slots refill on the next pass.
        for i in 0..b {
            if slots[i].is_some() && out.done[i] {
                let job = slots[i].take().unwrap();
                let obj = env.extract(&state, i);
                stats.completed += 1;
                sink(TrajResult {
                    request: job.request,
                    traj_index: job.traj_index,
                    obj,
                    log_pf: job.log_pf,
                    log_reward: out.log_reward[i],
                    length: job.steps,
                });
            } else if let Some(job) = slots[i].as_ref() {
                anyhow::ensure!(
                    job.steps < spec.t_max,
                    "slot {i}: trajectory exceeded t_max={} without terminating",
                    spec.t_max
                );
            }
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::hypergrid::HypergridEnv;
    use crate::reward::hypergrid::HypergridReward;
    use crate::runtime::policy::{PolicyShape, UniformPolicy};
    use crate::serve::traj_seed;

    fn env(h: usize) -> HypergridEnv<HypergridReward> {
        HypergridEnv::new(2, h, HypergridReward::standard(h))
    }

    fn run_n(
        e: &HypergridEnv<HypergridReward>,
        b: usize,
        n: usize,
        seed: u64,
    ) -> (Vec<TrajResult<Vec<i32>>>, StreamStats) {
        let shape = PolicyShape::of_env(e, b);
        let mut policy = UniformPolicy::new(shape);
        let mut next = 0usize;
        let mut results = Vec::new();
        let stats = sample_stream(
            e,
            &mut policy,
            || {
                if next < n {
                    let j = TrajJob {
                        request: 0,
                        traj_index: next,
                        seed: traj_seed(seed, next as u64),
                        temperature: 1.0,
                    };
                    next += 1;
                    Some(j)
                } else {
                    None
                }
            },
            |r| results.push(r),
        )
        .unwrap();
        results.sort_by_key(|r| r.traj_index);
        (results, stats)
    }

    #[test]
    fn produces_exactly_n_trajectories() {
        let e = env(8);
        let (results, stats) = run_n(&e, 4, 37, 5);
        assert_eq!(results.len(), 37);
        assert_eq!(stats.completed, 37);
        for (k, r) in results.iter().enumerate() {
            assert_eq!(r.traj_index, k);
            assert!(r.length >= 1 && r.length <= e.spec().t_max);
            assert!(r.log_pf < 0.0);
            assert!(r.log_reward.is_finite());
            assert_eq!(
                r.log_reward,
                e.log_reward_obj(&r.obj),
                "terminal reward must match the extracted object"
            );
        }
    }

    #[test]
    fn results_are_invariant_to_slot_table_width() {
        // The per-trajectory RNG streams + a row-wise policy make results
        // independent of B (and therefore of batch composition).
        let e = env(8);
        let (r4, _) = run_n(&e, 4, 25, 11);
        let (r16, _) = run_n(&e, 16, 25, 11);
        let (r1, _) = run_n(&e, 1, 25, 11);
        for ((a, b), c) in r4.iter().zip(&r16).zip(&r1) {
            assert_eq!(a.obj, b.obj);
            assert_eq!(a.obj, c.obj);
            assert_eq!(a.log_pf.to_bits(), b.log_pf.to_bits(), "bitwise log_pf");
            assert_eq!(a.log_reward.to_bits(), b.log_reward.to_bits());
            assert_eq!(a.length, b.length);
            assert_eq!(a.length, c.length);
        }
    }

    #[test]
    fn refill_keeps_dispatches_near_optimal() {
        // With heterogeneous lengths the padded rollout would run every
        // batch until its slowest row; slot refill keeps occupancy high.
        let e = env(32); // t_max = 63, typical uniform-policy length ~3
        let (results, stats) = run_n(&e, 8, 200, 3);
        let total_steps: usize = results.iter().map(|r| r.length).sum();
        assert_eq!(stats.active_row_steps as usize, total_steps);
        assert!(
            stats.occupancy() > 0.8,
            "slot refill should keep occupancy high, got {}",
            stats.occupancy()
        );
        // Dispatch count is within a small factor of the information-
        // theoretic floor ⌈total_steps / B⌉ (the drain tail costs a little).
        let floor = ((total_steps + 7) / 8) as u64;
        assert!(
            stats.dispatches <= floor + e.spec().t_max as u64,
            "dispatches {} vs floor {floor}",
            stats.dispatches
        );
    }

    #[test]
    fn late_arriving_jobs_join_the_running_batch() {
        // The source returns None for a while, then yields more work; the
        // engine must pick it up as long as any slot is still live.
        let e = env(6);
        let shape = PolicyShape::of_env(&e, 4);
        let mut policy = UniformPolicy::new(shape);
        let mut polls = 0usize;
        let mut issued = 0usize;
        let mut results = Vec::new();
        let stats = sample_stream(
            &e,
            &mut policy,
            || {
                polls += 1;
                // Job 0 immediately; job 1 only after a few polls (while job
                // 0 may still be running); nothing after that.
                if issued == 0 {
                    issued = 1;
                    return Some(TrajJob {
                        request: 0,
                        traj_index: 0,
                        seed: traj_seed(9, 0),
                        temperature: 1.0,
                    });
                }
                if issued == 1 && polls > 6 {
                    issued = 2;
                    return Some(TrajJob {
                        request: 0,
                        traj_index: 1,
                        seed: traj_seed(9, 1),
                        temperature: 1.0,
                    });
                }
                None
            },
            |r: TrajResult<Vec<i32>>| results.push(r),
        )
        .unwrap();
        // Both jobs completed in one engine run iff job 0 was still in
        // flight when job 1 appeared; otherwise only job 0 completes.
        assert!(!results.is_empty());
        assert_eq!(stats.completed as usize, results.len());
        assert!(results.iter().any(|r| r.traj_index == 0));
    }

    /// Temperature plumbing: T = 1 jobs are bitwise identical to the
    /// pre-temperature engine (covered transitively by the width-invariance
    /// test above running at 1.0); here, a near-zero temperature makes every
    /// step greedy, so two greedy runs agree with each other and a T = 5 run
    /// explores (differs from greedy for at least one trajectory).
    #[test]
    fn temperature_changes_sampling_but_not_rng_contract() {
        let e = env(8);
        let run_t = |temperature: f64, seed: u64| {
            let shape = PolicyShape::of_env(&e, 4);
            // Strictly ordered logits (gap 2.0 between any two actions), so
            // every legal subset has a unique argmax and the greedy limit is
            // fully deterministic.
            struct Biased(PolicyShape);
            impl crate::runtime::policy::BatchPolicy for Biased {
                fn shape(&self) -> PolicyShape {
                    self.0
                }
                fn eval(
                    &mut self,
                    _o: &[f32],
                    _f: &[f32],
                    _b: &[f32],
                ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
                    let b = self.0.batch;
                    let n = self.0.n_actions;
                    let mut fwd = vec![0.0f32; b * n];
                    for r in 0..b {
                        for a in 0..n {
                            // Negative and strictly decreasing: behaves like
                            // (unnormalized) log-probs so Σ row[a] < 0.
                            fwd[r * n + a] = -1.0 - 2.0 * a as f32;
                        }
                    }
                    Ok((fwd, vec![0.0; b * self.0.n_bwd_actions], vec![0.0; b]))
                }
            }
            let mut policy = Biased(shape);
            let mut next = 0usize;
            let mut objs = Vec::new();
            sample_stream(
                &e,
                &mut policy,
                || {
                    if next < 12 {
                        let j = TrajJob {
                            request: 0,
                            traj_index: next,
                            seed: traj_seed(seed, next as u64),
                            temperature,
                        };
                        next += 1;
                        Some(j)
                    } else {
                        None
                    }
                },
                |r: TrajResult<Vec<i32>>| objs.push((r.traj_index, r.obj, r.log_pf)),
            )
            .unwrap();
            objs.sort();
            objs
        };
        assert_eq!(run_t(1e-6, 3), run_t(1e-6, 77), "greedy runs are seed-independent");
        assert_ne!(run_t(1e-6, 3), run_t(5.0, 3), "hot sampling must explore");
        // log_pf is reported under the untempered policy: greedy trajectories
        // still carry finite, strictly negative log-probabilities.
        for (_, _, lp) in run_t(1e-6, 3) {
            assert!(lp.is_finite() && lp < 0.0);
        }
    }

    #[test]
    fn zero_jobs_returns_empty_stats() {
        let e = env(4);
        let shape = PolicyShape::of_env(&e, 4);
        let mut policy = UniformPolicy::new(shape);
        let stats = sample_stream(&e, &mut policy, || None, |_r: TrajResult<Vec<i32>>| {
            panic!("no results expected")
        })
        .unwrap();
        assert_eq!(stats.dispatches, 0);
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.occupancy(), 1.0);
    }
}
