//! The network front end: an HTTP/1.1 server multiplexing many concurrent
//! clients onto one [`SamplerService`].
//!
//! ## Routes
//!
//! - `POST /sample` — body `{"n": 64, "seed": 7}` plus optional
//!   `"temperature"` (softmax temperature, default 1.0), `"deadline_ms"`
//!   (clamped to the server's max), and `"config"`/`"model"` (validated
//!   against what this server actually serves — a client asking for a
//!   different checkpoint gets 400, not silently wrong samples). Answers
//!   `200` with `{"outputs": [{"obj", "log_pf", "log_reward", "length"}…]}`,
//!   `503` when the admission queue sheds (`Retry-After: 1`), `504` when
//!   the request's deadline expires (in queue or mid-drain), `400` on
//!   malformed or mismatched requests.
//! - `GET /stats` — the service's telemetry [`Registry`] as JSON (the
//!   `serve.*` counters/histograms/gauges), wrapped with the served
//!   family/config/model identity.
//! - `GET /metrics` — the same registry in Prometheus text exposition
//!   (`text/plain; version=0.0.4`): counters, gauges, and histograms with
//!   cumulative `le` buckets, `_sum`, `_count`.
//! - `GET /trace?n=K` — the most recent `K` (default 16) sampled request
//!   waterfalls from the in-process trace ring (see
//!   [`telemetry::trace`](crate::telemetry::trace)); empty unless tracing
//!   is enabled (`GFNX_TRACE` / `--trace`).
//! - `GET /healthz` — watchdog-backed readiness. Healthy answers `200`
//!   `{"ok": true, "reasons": []}`; a degraded service answers `503` with
//!   machine-readable reasons: `worker_stalled` when work is pending
//!   (backlog or in-flight requests) but the worker heartbeat is older
//!   than [`HttpServerConfig::stall_window`], and `service_closed` once
//!   the admission queue has shut. The body always carries
//!   `worker_heartbeat_age_s`, `queue_depth`, `inflight`, and
//!   `queue_high_water` so a probe can alert on trends, not just the flip.
//!
//! Every JSON route answers `content-type: application/json` (error bodies
//! included); `/metrics` answers the Prometheus media type.
//!
//! ## Request tracing
//!
//! When tracing is on, a sampled `POST /sample` mints a trace id at accept
//! and records a waterfall — `parse`, `queue_wait` (stamped by the worker
//! at first dispatch), per-dispatch `dispatch` slices, `drain`, and the
//! final `write` — whose `queue_wait + drain` interval reconciles exactly
//! with the `serve.request_latency` histogram sample for that request.
//!
//! ## Concurrency shape
//!
//! One accept thread (non-blocking listener polled against a stop flag)
//! spawns a handler thread per connection, capped at
//! [`HttpServerConfig::max_connections`] — beyond the cap a connection is
//! answered `503` immediately and closed, the connection-level twin of
//! queue shedding. Each connection gets a distinct fairness lane
//! ([`SubmitOptions::client`]), so the worker round-robins trajectories
//! across connections and a greedy client cannot starve the rest. Every
//! request carries a deadline (client-supplied or the server default),
//! enforced by the worker in-queue and mid-drain, and the handler waits
//! with [`SampleTicket::wait_timeout`] at 2× the deadline so even a wedged
//! worker cannot strand a connection.
//!
//! [`SampleTicket::wait_timeout`]: super::request::SampleTicket::wait_timeout

use super::conn::{
    read_request, write_response, write_response_typed, ReadOutcome, Request,
    CONTENT_TYPE_JSON, CONTENT_TYPE_PROMETHEUS,
};
use super::request::{is_timeout, SampleRequest};
use super::worker::{SamplerService, SubmitOptions, SubmitOutcome};
use crate::reward::parsimony::PhyloTree;
use crate::telemetry::trace::{self, ActiveTrace};
use crate::util::json::Json;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Terminal objects a server can put on the wire. Implemented by every
/// registered env family's `Obj` type (the registry's `EnvDriver` bound),
/// so `serve --env <any-of-9>` type-checks.
pub trait ObjJson {
    fn to_json(&self) -> Json;
}

impl ObjJson for Vec<i32> {
    fn to_json(&self) -> Json {
        Json::Arr(self.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl ObjJson for Vec<i16> {
    fn to_json(&self) -> Json {
        Json::Arr(self.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl ObjJson for Vec<i8> {
    fn to_json(&self) -> Json {
        Json::Arr(self.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl ObjJson for u64 {
    /// Bayesnet adjacency masks can exceed 2^53, so a JSON number (f64)
    /// would silently round; serialize as a decimal string.
    fn to_json(&self) -> Json {
        Json::Str(self.to_string())
    }
}

impl ObjJson for PhyloTree {
    /// Leaves as numbers, internal nodes as `[left, right]`.
    fn to_json(&self) -> Json {
        match self {
            PhyloTree::Leaf(i) => Json::Num(*i as f64),
            PhyloTree::Node(l, r) => Json::Arr(vec![l.to_json(), r.to_json()]),
        }
    }
}

/// What this server serves, echoed on `/stats` and validated against the
/// optional `"config"`/`"model"` fields of sample requests.
#[derive(Clone, Debug)]
pub struct ServeIdentity {
    pub family: String,
    pub config: String,
    /// `"mlp"` or `"transformer"` — whatever checkpoint/backend is live.
    pub model: String,
}

/// Tunables of the HTTP front end.
#[derive(Clone, Debug)]
pub struct HttpServerConfig {
    /// Concurrent-connection cap; excess connections get an immediate 503.
    pub max_connections: usize,
    /// Deadline applied when a request names none.
    pub default_deadline: Duration,
    /// Upper clamp on client-supplied `deadline_ms`.
    pub max_deadline: Duration,
    /// Request body cap in bytes.
    pub max_body: usize,
    /// Keep-alive idle window before a silent connection is closed.
    pub idle_timeout: Duration,
    /// Per-request sample-count cap (`n`).
    pub max_n: usize,
    /// Watchdog window for `/healthz`: with work pending (backlog or
    /// in-flight requests), a worker heartbeat older than this flips the
    /// probe to `503 worker_stalled`. An *idle* worker is allowed an
    /// arbitrarily old heartbeat. Defaults to 10 s, overridable via the
    /// `GFNX_STALL_WINDOW_MS` env var (or `serve --stall-window-ms`).
    pub stall_window: Duration,
}

impl Default for HttpServerConfig {
    fn default() -> Self {
        let stall_window = std::env::var("GFNX_STALL_WINDOW_MS")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(Duration::from_secs(10));
        HttpServerConfig {
            max_connections: 256,
            default_deadline: Duration::from_secs(30),
            max_deadline: Duration::from_secs(120),
            max_body: 64 * 1024,
            idle_timeout: Duration::from_secs(60),
            max_n: 100_000,
            stall_window,
        }
    }
}

/// A running HTTP front end. Dropping (or [`HttpServer::shutdown`]) stops
/// the accept loop and joins every connection handler.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `listen` (e.g. `127.0.0.1:8080`, or port `0` for an ephemeral
    /// port — read it back via [`HttpServer::local_addr`]) and serve `svc`.
    pub fn serve<Obj>(
        listen: &str,
        svc: Arc<SamplerService<Obj>>,
        identity: ServeIdentity,
        cfg: HttpServerConfig,
    ) -> anyhow::Result<HttpServer>
    where
        Obj: ObjJson + Send + 'static,
    {
        let listener = TcpListener::bind(listen)
            .map_err(|e| anyhow::anyhow!("cannot bind {listen}: {e}"))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("gfnx-http-accept".to_string())
            .spawn(move || accept_loop(listener, svc, identity, cfg, accept_stop))
            .expect("failed to spawn http accept thread");
        Ok(HttpServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain connection handlers, join.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop<Obj>(
    listener: TcpListener,
    svc: Arc<SamplerService<Obj>>,
    identity: ServeIdentity,
    cfg: HttpServerConfig,
    stop: Arc<AtomicBool>,
) where
    Obj: ObjJson + Send + 'static,
{
    let identity = Arc::new(identity);
    let cfg = Arc::new(cfg);
    let next_client = Arc::new(AtomicU64::new(1)); // 0 is the anonymous lane
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    let conn_refused = svc.registry().counter("serve.http.conn_refused");
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                handlers.retain(|h| !h.is_finished());
                if handlers.len() >= cfg.max_connections {
                    // Connection-level shedding: answer 503 and close
                    // instead of queueing unbounded handler threads.
                    conn_refused.inc();
                    let _ = write_response(
                        &mut stream,
                        503,
                        br#"{"error":"connection limit reached"}"#,
                        &["retry-after: 1"],
                    );
                    continue;
                }
                let svc = Arc::clone(&svc);
                let identity = Arc::clone(&identity);
                let cfg = Arc::clone(&cfg);
                let stop = Arc::clone(&stop);
                let client = next_client.fetch_add(1, Ordering::Relaxed);
                if let Ok(h) = std::thread::Builder::new()
                    .name(format!("gfnx-http-conn-{client}"))
                    .spawn(move || handle_connection(stream, svc, identity, cfg, client, stop))
                {
                    handlers.push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_connection<Obj>(
    mut stream: TcpStream,
    svc: Arc<SamplerService<Obj>>,
    identity: Arc<ServeIdentity>,
    cfg: Arc<HttpServerConfig>,
    client: u64,
    stop: Arc<AtomicBool>,
) where
    Obj: ObjJson + Send + 'static,
{
    let requests = svc.registry().counter("serve.http.requests");
    loop {
        let req = match read_request(&mut stream, cfg.max_body, cfg.idle_timeout, &stop) {
            ReadOutcome::Request(r) => r,
            ReadOutcome::Eof | ReadOutcome::Stopped | ReadOutcome::IdleTimeout => return,
            ReadOutcome::Bad(msg) => {
                let _ = write_response(&mut stream, 400, &error_body(&msg), &[]);
                return;
            }
        };
        requests.inc();
        let keep_alive = req.keep_alive;
        // Routes may carry a query string (`/trace?n=4`); match on the bare
        // path and hand the query to the handler.
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (req.path.as_str(), None),
        };
        // Sampled tracing: mint the trace at accept so the waterfall covers
        // the whole request, parse included. One relaxed atomic load when
        // tracing is off.
        let req_trace = if req.method == "POST" && path == "/sample" {
            trace::try_start("http_request")
        } else {
            None
        };
        let (status, body, content_type, extra): (u16, String, &str, &[&str]) =
            match (req.method.as_str(), path) {
                ("POST", "/sample") => {
                    match handle_sample(&req, &svc, &identity, &cfg, client, req_trace.as_ref()) {
                        Ok(body) => (200, body, CONTENT_TYPE_JSON, &[]),
                        Err(SampleError::Shed) => (
                            503,
                            r#"{"error":"overloaded: request shed (queue full)"}"#.to_string(),
                            CONTENT_TYPE_JSON,
                            &["retry-after: 1"],
                        ),
                        Err(SampleError::Closed) => (
                            503,
                            r#"{"error":"service is shutting down"}"#.to_string(),
                            CONTENT_TYPE_JSON,
                            &[],
                        ),
                        Err(SampleError::Timeout(msg)) => {
                            (504, error_body_str(&msg), CONTENT_TYPE_JSON, &[])
                        }
                        Err(SampleError::Bad(msg)) => {
                            (400, error_body_str(&msg), CONTENT_TYPE_JSON, &[])
                        }
                        Err(SampleError::Internal(msg)) => {
                            (500, error_body_str(&msg), CONTENT_TYPE_JSON, &[])
                        }
                    }
                }
                ("GET", "/stats") => (200, stats_body(&svc, &identity), CONTENT_TYPE_JSON, &[]),
                ("GET", "/metrics") => (
                    200,
                    svc.registry().render_prometheus(),
                    CONTENT_TYPE_PROMETHEUS,
                    &[],
                ),
                ("GET", "/trace") => {
                    let n = query_param(query, "n")
                        .and_then(|v| v.parse::<usize>().ok())
                        .unwrap_or(16);
                    (200, trace::tracer().recent_json(n).to_string(), CONTENT_TYPE_JSON, &[])
                }
                ("GET", "/healthz") => {
                    let (status, body) = healthz_body(&svc, cfg.stall_window);
                    (status, body, CONTENT_TYPE_JSON, &[])
                }
                ("GET", "/sample")
                | ("POST", "/stats")
                | ("POST", "/metrics")
                | ("POST", "/trace")
                | ("POST", "/healthz") => (
                    405,
                    r#"{"error":"method not allowed"}"#.to_string(),
                    CONTENT_TYPE_JSON,
                    &[],
                ),
                (_, path) => {
                    (404, error_body_str(&format!("no route {path}")), CONTENT_TYPE_JSON, &[])
                }
            };
        let write_start = Instant::now();
        let write_ok =
            write_response_typed(&mut stream, status, body.as_bytes(), content_type, extra)
                .is_ok();
        if let Some(tr) = &req_trace {
            tr.segment("write", write_start, Instant::now());
            tr.meta("status", status as f64);
            tr.meta("body_bytes", body.len() as f64);
            tr.finish(status == 200);
        }
        if !write_ok || !keep_alive {
            return;
        }
    }
}

/// Pull one `key=value` pair out of a raw query string.
fn query_param<'a>(query: Option<&'a str>, key: &str) -> Option<&'a str> {
    query?
        .split('&')
        .find_map(|kv| kv.split_once('=').filter(|(k, _)| *k == key).map(|(_, v)| v))
}

/// The watchdog verdict behind `GET /healthz`: status code plus a JSON body
/// with machine-readable degradation reasons and the raw gauges they were
/// judged from.
fn healthz_body<Obj: Send + 'static>(
    svc: &SamplerService<Obj>,
    stall_window: Duration,
) -> (u16, String) {
    let stats = svc.stats_handles();
    let backlog = svc.backlog();
    let inflight = stats.inflight.get();
    let age = stats.heartbeat_age_s();
    let window_s = stall_window.as_secs_f64();
    let mut reasons: Vec<Json> = Vec::new();
    if svc.is_closed() {
        reasons.push(Json::Str("service_closed".to_string()));
    }
    // A stall is only a stall if there is work the worker should be moving:
    // an idle worker parked in pop_blocking legitimately stops beating.
    if (backlog > 0 || inflight > 0.0) && age > window_s {
        reasons.push(Json::Str(format!(
            "worker_stalled: serve.worker_heartbeat_s is {age:.3}s old \
             (stall window {window_s:.3}s) with work pending"
        )));
    }
    let ok = reasons.is_empty();
    let body = Json::obj(vec![
        ("ok", Json::Bool(ok)),
        ("reasons", Json::Arr(reasons)),
        ("worker_heartbeat_age_s", Json::Num(age)),
        ("stall_window_s", Json::Num(window_s)),
        ("queue_depth", Json::Num(backlog as f64)),
        ("inflight", Json::Num(inflight)),
        ("queue_high_water", Json::Num(svc.queue_high_water() as f64)),
    ])
    .to_string();
    (if ok { 200 } else { 503 }, body)
}

enum SampleError {
    Shed,
    Closed,
    Timeout(String),
    Bad(String),
    Internal(String),
}

fn handle_sample<Obj>(
    req: &Request,
    svc: &SamplerService<Obj>,
    identity: &ServeIdentity,
    cfg: &HttpServerConfig,
    client: u64,
    req_trace: Option<&Arc<ActiveTrace>>,
) -> Result<String, SampleError>
where
    Obj: ObjJson + Send + 'static,
{
    let parse_start = Instant::now();
    let body = std::str::from_utf8(&req.body)
        .map_err(|_| SampleError::Bad("body is not UTF-8".to_string()))?;
    let json = Json::parse(body).map_err(|e| SampleError::Bad(e.to_string()))?;
    if let Some(tr) = req_trace {
        tr.segment("parse", parse_start, Instant::now());
    }

    let n = json
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| SampleError::Bad("missing or non-numeric field 'n'".to_string()))?;
    if n > cfg.max_n {
        return Err(SampleError::Bad(format!(
            "n = {n} exceeds this server's limit of {}",
            cfg.max_n
        )));
    }
    let seed = parse_seed(&json)?;

    // A client may pin the config/model it expects; serving something else
    // silently would hand it samples from the wrong distribution.
    for (field, served) in [("config", &identity.config), ("model", &identity.model)] {
        if let Some(want) = json.get(field).and_then(Json::as_str) {
            if want != served {
                return Err(SampleError::Bad(format!(
                    "this server serves {field} {served:?}, not {want:?}"
                )));
            }
        }
    }

    let temperature = match json.get("temperature") {
        None => 1.0,
        Some(t) => t.as_f64().filter(|t| t.is_finite() && *t > 0.0).ok_or_else(|| {
            SampleError::Bad("'temperature' must be a finite number > 0".to_string())
        })?,
    };

    let deadline = match json.get("deadline_ms") {
        None => cfg.default_deadline,
        Some(d) => {
            let ms = d.as_f64().filter(|m| m.is_finite() && *m > 0.0).ok_or_else(|| {
                SampleError::Bad("'deadline_ms' must be a number > 0".to_string())
            })?;
            Duration::from_millis(ms as u64).min(cfg.max_deadline)
        }
    };

    let now = Instant::now();
    let opts = SubmitOptions {
        deadline: Some(now + deadline),
        temperature,
        client,
    };
    let ticket = match svc.try_submit_traced(
        SampleRequest { n_samples: n, seed },
        opts,
        req_trace.cloned(),
    ) {
        SubmitOutcome::Ticket(t) => t,
        SubmitOutcome::Shed => return Err(SampleError::Shed),
        SubmitOutcome::Closed => return Err(SampleError::Closed),
    };
    // The worker resolves expiries itself (in-queue and mid-drain); the 2×
    // client-side bound only exists so a wedged worker cannot strand the
    // connection — and it keeps the "resolve within 2× deadline" guarantee
    // unconditional.
    let outputs = match ticket.wait_timeout(2 * deadline) {
        Ok(outs) => outs,
        Err(e) if is_timeout(&e) => return Err(SampleError::Timeout(e.to_string())),
        Err(e) => return Err(SampleError::Internal(e.to_string())),
    };

    let rows: Vec<Json> = outputs
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("obj", o.obj.to_json()),
                ("log_pf", Json::Num(o.log_pf)),
                ("log_reward", Json::Num(o.log_reward)),
                ("length", Json::Num(o.length as f64)),
                ("traj_index", Json::Num(o.traj_index as f64)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("n", Json::Num(n as f64)),
        ("seed", Json::Str(seed.to_string())),
        ("temperature", Json::Num(temperature)),
        ("outputs", Json::Arr(rows)),
    ])
    .to_string())
}

/// Seeds are u64; JSON numbers are f64 and lose precision past 2^53, so a
/// string form is accepted (and echoed back) for full-range seeds.
fn parse_seed(json: &Json) -> Result<u64, SampleError> {
    match json.get("seed") {
        None => Err(SampleError::Bad("missing field 'seed'".to_string())),
        Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 && *x <= 2f64.powi(53) => {
            Ok(*x as u64)
        }
        Some(Json::Str(s)) => s.parse::<u64>().map_err(|_| {
            SampleError::Bad(format!("'seed' string {s:?} is not a u64"))
        }),
        Some(_) => Err(SampleError::Bad(
            "'seed' must be a non-negative integer (use a string beyond 2^53)".to_string(),
        )),
    }
}

fn stats_body<Obj: Send + 'static>(
    svc: &SamplerService<Obj>,
    identity: &ServeIdentity,
) -> String {
    Json::obj(vec![
        ("family", Json::Str(identity.family.clone())),
        ("config", Json::Str(identity.config.clone())),
        ("model", Json::Str(identity.model.clone())),
        ("registry", svc.registry().to_json()),
    ])
    .to_string()
}

fn error_body(msg: &str) -> Vec<u8> {
    error_body_str(msg).into_bytes()
}

fn error_body_str(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::hypergrid::HypergridEnv;
    use crate::reward::hypergrid::HypergridReward;
    use crate::runtime::policy::{BatchPolicy, PolicyShape, UniformPolicy};
    use crate::serve::conn::HttpClient;

    fn http_service() -> (Arc<SamplerService<Vec<i32>>>, HttpServer, String) {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, 4);
        let svc = Arc::new(SamplerService::spawn(env, move || {
            Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
        }));
        let server = HttpServer::serve(
            "127.0.0.1:0",
            Arc::clone(&svc),
            ServeIdentity {
                family: "hypergrid".to_string(),
                config: "hypergrid_small".to_string(),
                model: "mlp".to_string(),
            },
            HttpServerConfig::default(),
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        (svc, server, addr)
    }

    #[test]
    fn sample_roundtrip_is_deterministic_and_complete() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, body) = c.post_json("/sample", r#"{"n":5,"seed":3}"#).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let outs = j.req_arr("outputs").unwrap();
        assert_eq!(outs.len(), 5);
        for o in outs {
            let obj = o.req_arr("obj").unwrap();
            assert!(obj.iter().all(|c| (0.0..6.0).contains(&c.as_f64().unwrap())));
            assert!(o.get("log_pf").unwrap().as_f64().unwrap() < 0.0);
            assert!(o.get("log_reward").unwrap().as_f64().is_some());
            assert!(o.req_usize("length").unwrap() >= 1);
        }
        // Same request, same bytes: the seed pins the trajectory streams.
        let (_, body2) = c.post_json("/sample", r#"{"n":5,"seed":3}"#).unwrap();
        assert_eq!(body, body2, "repeat requests must be bit-identical");
        server.shutdown();
    }

    #[test]
    fn stats_route_serves_registry_json_with_identity() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, _) = c.post_json("/sample", r#"{"n":2,"seed":1}"#).unwrap();
        assert_eq!(status, 200);
        let (status, body) = c.get("/stats").unwrap();
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.req_str("family").unwrap(), "hypergrid");
        assert_eq!(j.req_str("config").unwrap(), "hypergrid_small");
        assert_eq!(j.req_str("model").unwrap(), "mlp");
        let reg = j.req("registry").unwrap();
        // Registry::to_json schema: counters/gauges/histograms objects.
        let counters = reg.get("counters").expect("registry.counters");
        assert_eq!(
            counters.get("serve.requests_completed").and_then(Json::as_usize),
            Some(1)
        );
        assert!(counters.get("serve.http.requests").is_some());
        assert!(reg
            .get("histograms")
            .and_then(|h| h.get("serve.request_latency"))
            .is_some());
        server.shutdown();
    }

    #[test]
    fn malformed_and_mismatched_requests_get_400() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let cases: &[(&str, &str)] = &[
            ("{not json", "parse"),
            (r#"{"seed":1}"#, "'n'"),
            (r#"{"n":3}"#, "'seed'"),
            (r#"{"n":3,"seed":-2}"#, "'seed'"),
            (r#"{"n":3,"seed":1,"temperature":0}"#, "'temperature'"),
            (r#"{"n":3,"seed":1,"deadline_ms":"soon"}"#, "'deadline_ms'"),
            (r#"{"n":3,"seed":1,"config":"hypergrid_8d_10"}"#, "hypergrid_small"),
            (r#"{"n":3,"seed":1,"model":"transformer"}"#, "mlp"),
        ];
        for (body, needle) in cases {
            let (status, resp) = c.post_json("/sample", body).unwrap();
            let resp = String::from_utf8_lossy(&resp).to_string();
            assert_eq!(status, 400, "{body} -> {resp}");
            assert!(
                resp.to_lowercase().contains(&needle.to_lowercase()),
                "{body}: error {resp:?} should mention {needle:?}"
            );
        }
        // Still serving after a pile of bad requests.
        let (status, _) = c.post_json("/sample", r#"{"n":1,"seed":9}"#).unwrap();
        assert_eq!(status, 200);
        server.shutdown();
    }

    #[test]
    fn routing_unknown_paths_and_methods() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        assert_eq!(c.get("/healthz").unwrap().0, 200);
        assert_eq!(c.get("/nope").unwrap().0, 404);
        assert_eq!(c.get("/sample").unwrap().0, 405);
        assert_eq!(c.post_json("/stats", "{}").unwrap().0, 405);
        server.shutdown();
    }

    #[test]
    fn seed_accepts_full_range_strings() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let big = u64::MAX.to_string();
        let (status, body) = c
            .post_json("/sample", &format!(r#"{{"n":2,"seed":"{big}"}}"#))
            .unwrap();
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.req_str("seed").unwrap(), big, "seed echoed losslessly");
        server.shutdown();
    }

    fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
        headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Every JSON route — success and error bodies alike — declares
    /// `application/json`; `/metrics` declares the Prometheus media type.
    #[test]
    fn responses_declare_content_types() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        for path in ["/stats", "/healthz", "/trace"] {
            let (status, headers, _) = c.get_full(path).unwrap();
            assert_eq!(status, 200, "{path}");
            assert_eq!(header(&headers, "content-type"), Some(CONTENT_TYPE_JSON), "{path}");
        }
        let (status, headers, _) =
            c.request_full("POST", "/sample", br#"{"n":2,"seed":1}"#).unwrap();
        assert_eq!(status, 200);
        assert_eq!(header(&headers, "content-type"), Some(CONTENT_TYPE_JSON));
        let (status, headers, _) = c.request_full("POST", "/sample", b"{not json").unwrap();
        assert_eq!(status, 400, "error bodies are JSON too");
        assert_eq!(header(&headers, "content-type"), Some(CONTENT_TYPE_JSON));
        let (status, headers, _) = c.get_full("/nope").unwrap();
        assert_eq!(status, 404);
        assert_eq!(header(&headers, "content-type"), Some(CONTENT_TYPE_JSON));
        let (status, headers, _) = c.get_full("/metrics").unwrap();
        assert_eq!(status, 200);
        assert_eq!(header(&headers, "content-type"), Some(CONTENT_TYPE_PROMETHEUS));
        server.shutdown();
    }

    /// `/metrics` renders the same registry `/stats` serializes, as valid
    /// Prometheus text: `# TYPE` lines, cumulative `le` buckets, `_count`
    /// consistent with the completed-request count.
    #[test]
    fn metrics_route_serves_prometheus_text() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, _) = c.post_json("/sample", r#"{"n":3,"seed":2}"#).unwrap();
        assert_eq!(status, 200);
        let (status, body) = c.get("/metrics").unwrap();
        assert_eq!(status, 200);
        let text = String::from_utf8(body).unwrap();
        assert!(text.contains("# TYPE serve_requests_completed counter"), "{text}");
        assert!(text.contains("serve_requests_completed 1"), "{text}");
        assert!(text.contains("# TYPE serve_request_latency histogram"), "{text}");
        assert!(text.contains("serve_request_latency_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("serve_request_latency_count 1"), "{text}");
        let mut last = 0u64;
        for line in
            text.lines().filter(|l| l.starts_with("serve_request_latency_bucket{"))
        {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket counts must be cumulative: {line}");
            last = v;
        }
        assert_eq!(last, 1, "+Inf bucket equals the sample count");
        server.shutdown();
    }

    /// With tracing on at rate 1, a `POST /sample` leaves a full waterfall
    /// (parse → queue_wait → dispatch → drain → write) in the ring,
    /// readable over `GET /trace`.
    #[test]
    fn trace_route_returns_sampled_request_waterfalls() {
        let _guard = crate::telemetry::flag_test_lock();
        trace::set_trace_rate(1.0);
        trace::reset_sampler();
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, _) = c.post_json("/sample", r#"{"n":4,"seed":11}"#).unwrap();
        assert_eq!(status, 200);
        let (status, body) = c.get("/trace?n=4").unwrap();
        trace::set_trace_rate(0.0);
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.get("rate").and_then(Json::as_f64), Some(1.0));
        let traces = j.req_arr("traces").unwrap();
        assert!(!traces.is_empty(), "rate-1 tracing must capture the request");
        // Newest first; nothing else pushed under the flag lock.
        let t = &traces[0];
        assert_eq!(t.req_str("kind").unwrap(), "http_request");
        assert_eq!(t.get("ok").and_then(Json::as_bool), Some(true));
        let total = t.get("total_ns").and_then(Json::as_f64).unwrap();
        let segs = t.req_arr("segments").unwrap();
        let names: Vec<String> =
            segs.iter().map(|s| s.req_str("name").unwrap().to_string()).collect();
        for want in ["parse", "queue_wait", "dispatch", "drain", "write"] {
            assert!(names.iter().any(|n| n == want), "missing segment {want}: {names:?}");
        }
        for s in segs {
            let start = s.get("start_ns").and_then(Json::as_f64).unwrap();
            let dur = s.get("dur_ns").and_then(Json::as_f64).unwrap();
            assert!(start + dur <= total, "segment exceeds the trace window");
        }
        server.shutdown();
    }

    /// The watchdog: a wedged worker with work pending flips `/healthz` to
    /// 503 naming the stalled heartbeat; an idle worker with an old
    /// heartbeat stays healthy; recovery flips it back.
    #[test]
    fn healthz_flags_wedged_worker_within_stall_window() {
        use std::sync::{Condvar, Mutex};

        #[derive(Default)]
        struct WedgeState {
            arrived: bool,
            open: bool,
        }
        type WedgeGate = Arc<(Mutex<WedgeState>, Condvar)>;
        struct WedgePolicy {
            inner: UniformPolicy,
            gate: WedgeGate,
        }
        impl BatchPolicy for WedgePolicy {
            fn shape(&self) -> PolicyShape {
                self.inner.shape()
            }
            fn eval(
                &mut self,
                obs: &[f32],
                fwd: &[f32],
                bwd: &[f32],
            ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
                let (m, cv) = &*self.gate;
                let mut st = m.lock().unwrap();
                st.arrived = true;
                cv.notify_all();
                while !st.open {
                    st = cv.wait(st).unwrap();
                }
                drop(st);
                self.inner.eval(obs, fwd, bwd)
            }
        }

        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, 4);
        let gate: WedgeGate = Arc::new((Mutex::new(WedgeState::default()), Condvar::new()));
        let g = Arc::clone(&gate);
        let svc = Arc::new(SamplerService::spawn(env, move || {
            Ok(Box::new(WedgePolicy { inner: UniformPolicy::new(shape), gate: Arc::clone(&g) })
                as Box<dyn BatchPolicy>)
        }));
        let cfg = HttpServerConfig {
            stall_window: Duration::from_millis(50),
            ..HttpServerConfig::default()
        };
        let server = HttpServer::serve(
            "127.0.0.1:0",
            Arc::clone(&svc),
            ServeIdentity {
                family: "hypergrid".to_string(),
                config: "hypergrid_small".to_string(),
                model: "mlp".to_string(),
            },
            cfg,
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        let mut c = HttpClient::connect(&addr).unwrap();

        // Idle: healthy no matter how stale the heartbeat grows.
        std::thread::sleep(Duration::from_millis(80));
        let (status, body) = c.get("/healthz").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));

        // Submit work the wedged policy will sit on.
        let addr2 = addr.clone();
        let waiter = std::thread::spawn(move || {
            let mut c = HttpClient::connect(&addr2).unwrap();
            c.post_json("/sample", r#"{"n":2,"seed":5}"#).unwrap()
        });
        {
            let (m, cv) = &*gate;
            let deadline = Instant::now() + Duration::from_secs(10);
            let mut st = m.lock().unwrap();
            while !st.arrived {
                let (g2, _) = cv.wait_timeout(st, Duration::from_millis(50)).unwrap();
                st = g2;
                assert!(Instant::now() < deadline, "worker never dispatched");
            }
        }
        std::thread::sleep(Duration::from_millis(120)); // age past the window

        let (status, body) = c.get("/healthz").unwrap();
        let body = String::from_utf8(body).unwrap();
        assert_eq!(status, 503, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        let reasons = j.req_arr("reasons").unwrap();
        assert!(
            reasons
                .iter()
                .any(|r| r.as_str().is_some_and(|s| s.contains("worker_stalled"))),
            "{body}"
        );
        assert!(
            body.contains("worker_heartbeat_s"),
            "reason names the stalled heartbeat gauge: {body}"
        );
        assert!(j.get("inflight").and_then(Json::as_f64).unwrap() >= 1.0, "{body}");

        // Open the gate: the request completes and health recovers.
        {
            let (m, cv) = &*gate;
            m.lock().unwrap().open = true;
            cv.notify_all();
        }
        let (status, _) = waiter.join().unwrap();
        assert_eq!(status, 200);
        let (status, body) = c.get("/healthz").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        server.shutdown();
    }

    #[test]
    fn obj_json_covers_every_family_obj_type() {
        assert_eq!(vec![1i32, 2].to_json().to_string(), "[1,2]");
        assert_eq!(vec![3i16].to_json().to_string(), "[3]");
        assert_eq!(vec![-1i8, 1].to_json().to_string(), "[-1,1]");
        assert_eq!(u64::MAX.to_json().to_string(), format!("\"{}\"", u64::MAX));
        let tree = PhyloTree::Node(
            Box::new(PhyloTree::Leaf(0)),
            Box::new(PhyloTree::Node(
                Box::new(PhyloTree::Leaf(1)),
                Box::new(PhyloTree::Leaf(2)),
            )),
        );
        assert_eq!(tree.to_json().to_string(), "[0,[1,2]]");
    }
}
