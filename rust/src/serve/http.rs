//! The network front end: an HTTP/1.1 server multiplexing many concurrent
//! clients onto one [`SamplerService`].
//!
//! ## Routes
//!
//! - `POST /sample` — body `{"n": 64, "seed": 7}` plus optional
//!   `"temperature"` (softmax temperature, default 1.0), `"deadline_ms"`
//!   (clamped to the server's max), and `"config"`/`"model"` (validated
//!   against what this server actually serves — a client asking for a
//!   different checkpoint gets 400, not silently wrong samples). Answers
//!   `200` with `{"outputs": [{"obj", "log_pf", "log_reward", "length"}…]}`,
//!   `503` when the admission queue sheds (`Retry-After: 1`), `504` when
//!   the request's deadline expires (in queue or mid-drain), `400` on
//!   malformed or mismatched requests.
//! - `GET /stats` — the service's telemetry [`Registry`] as JSON (the
//!   `serve.*` counters/histograms/gauges), wrapped with the served
//!   family/config/model identity.
//! - `GET /healthz` — `{"ok": true}` liveness probe.
//!
//! ## Concurrency shape
//!
//! One accept thread (non-blocking listener polled against a stop flag)
//! spawns a handler thread per connection, capped at
//! [`HttpServerConfig::max_connections`] — beyond the cap a connection is
//! answered `503` immediately and closed, the connection-level twin of
//! queue shedding. Each connection gets a distinct fairness lane
//! ([`SubmitOptions::client`]), so the worker round-robins trajectories
//! across connections and a greedy client cannot starve the rest. Every
//! request carries a deadline (client-supplied or the server default),
//! enforced by the worker in-queue and mid-drain, and the handler waits
//! with [`SampleTicket::wait_timeout`] at 2× the deadline so even a wedged
//! worker cannot strand a connection.
//!
//! [`SampleTicket::wait_timeout`]: super::request::SampleTicket::wait_timeout

use super::conn::{read_request, write_response, ReadOutcome, Request};
use super::request::{is_timeout, SampleRequest};
use super::worker::{SamplerService, SubmitOptions, SubmitOutcome};
use crate::reward::parsimony::PhyloTree;
use crate::util::json::Json;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Terminal objects a server can put on the wire. Implemented by every
/// registered env family's `Obj` type (the registry's `EnvDriver` bound),
/// so `serve --env <any-of-9>` type-checks.
pub trait ObjJson {
    fn to_json(&self) -> Json;
}

impl ObjJson for Vec<i32> {
    fn to_json(&self) -> Json {
        Json::Arr(self.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl ObjJson for Vec<i16> {
    fn to_json(&self) -> Json {
        Json::Arr(self.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl ObjJson for Vec<i8> {
    fn to_json(&self) -> Json {
        Json::Arr(self.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl ObjJson for u64 {
    /// Bayesnet adjacency masks can exceed 2^53, so a JSON number (f64)
    /// would silently round; serialize as a decimal string.
    fn to_json(&self) -> Json {
        Json::Str(self.to_string())
    }
}

impl ObjJson for PhyloTree {
    /// Leaves as numbers, internal nodes as `[left, right]`.
    fn to_json(&self) -> Json {
        match self {
            PhyloTree::Leaf(i) => Json::Num(*i as f64),
            PhyloTree::Node(l, r) => Json::Arr(vec![l.to_json(), r.to_json()]),
        }
    }
}

/// What this server serves, echoed on `/stats` and validated against the
/// optional `"config"`/`"model"` fields of sample requests.
#[derive(Clone, Debug)]
pub struct ServeIdentity {
    pub family: String,
    pub config: String,
    /// `"mlp"` or `"transformer"` — whatever checkpoint/backend is live.
    pub model: String,
}

/// Tunables of the HTTP front end.
#[derive(Clone, Debug)]
pub struct HttpServerConfig {
    /// Concurrent-connection cap; excess connections get an immediate 503.
    pub max_connections: usize,
    /// Deadline applied when a request names none.
    pub default_deadline: Duration,
    /// Upper clamp on client-supplied `deadline_ms`.
    pub max_deadline: Duration,
    /// Request body cap in bytes.
    pub max_body: usize,
    /// Keep-alive idle window before a silent connection is closed.
    pub idle_timeout: Duration,
    /// Per-request sample-count cap (`n`).
    pub max_n: usize,
}

impl Default for HttpServerConfig {
    fn default() -> Self {
        HttpServerConfig {
            max_connections: 256,
            default_deadline: Duration::from_secs(30),
            max_deadline: Duration::from_secs(120),
            max_body: 64 * 1024,
            idle_timeout: Duration::from_secs(60),
            max_n: 100_000,
        }
    }
}

/// A running HTTP front end. Dropping (or [`HttpServer::shutdown`]) stops
/// the accept loop and joins every connection handler.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `listen` (e.g. `127.0.0.1:8080`, or port `0` for an ephemeral
    /// port — read it back via [`HttpServer::local_addr`]) and serve `svc`.
    pub fn serve<Obj>(
        listen: &str,
        svc: Arc<SamplerService<Obj>>,
        identity: ServeIdentity,
        cfg: HttpServerConfig,
    ) -> anyhow::Result<HttpServer>
    where
        Obj: ObjJson + Send + 'static,
    {
        let listener = TcpListener::bind(listen)
            .map_err(|e| anyhow::anyhow!("cannot bind {listen}: {e}"))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("gfnx-http-accept".to_string())
            .spawn(move || accept_loop(listener, svc, identity, cfg, accept_stop))
            .expect("failed to spawn http accept thread");
        Ok(HttpServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain connection handlers, join.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop<Obj>(
    listener: TcpListener,
    svc: Arc<SamplerService<Obj>>,
    identity: ServeIdentity,
    cfg: HttpServerConfig,
    stop: Arc<AtomicBool>,
) where
    Obj: ObjJson + Send + 'static,
{
    let identity = Arc::new(identity);
    let cfg = Arc::new(cfg);
    let next_client = Arc::new(AtomicU64::new(1)); // 0 is the anonymous lane
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    let conn_refused = svc.registry().counter("serve.http.conn_refused");
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                handlers.retain(|h| !h.is_finished());
                if handlers.len() >= cfg.max_connections {
                    // Connection-level shedding: answer 503 and close
                    // instead of queueing unbounded handler threads.
                    conn_refused.inc();
                    let _ = write_response(
                        &mut stream,
                        503,
                        br#"{"error":"connection limit reached"}"#,
                        &["retry-after: 1"],
                    );
                    continue;
                }
                let svc = Arc::clone(&svc);
                let identity = Arc::clone(&identity);
                let cfg = Arc::clone(&cfg);
                let stop = Arc::clone(&stop);
                let client = next_client.fetch_add(1, Ordering::Relaxed);
                if let Ok(h) = std::thread::Builder::new()
                    .name(format!("gfnx-http-conn-{client}"))
                    .spawn(move || handle_connection(stream, svc, identity, cfg, client, stop))
                {
                    handlers.push(h);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_connection<Obj>(
    mut stream: TcpStream,
    svc: Arc<SamplerService<Obj>>,
    identity: Arc<ServeIdentity>,
    cfg: Arc<HttpServerConfig>,
    client: u64,
    stop: Arc<AtomicBool>,
) where
    Obj: ObjJson + Send + 'static,
{
    let requests = svc.registry().counter("serve.http.requests");
    loop {
        let req = match read_request(&mut stream, cfg.max_body, cfg.idle_timeout, &stop) {
            ReadOutcome::Request(r) => r,
            ReadOutcome::Eof | ReadOutcome::Stopped | ReadOutcome::IdleTimeout => return,
            ReadOutcome::Bad(msg) => {
                let _ = write_response(&mut stream, 400, &error_body(&msg), &[]);
                return;
            }
        };
        requests.inc();
        let keep_alive = req.keep_alive;
        let (status, body, extra): (u16, String, &[&str]) =
            match (req.method.as_str(), req.path.as_str()) {
                ("POST", "/sample") => match handle_sample(&req, &svc, &identity, &cfg, client) {
                    Ok(body) => (200, body, &[]),
                    Err(SampleError::Shed) => (
                        503,
                        r#"{"error":"overloaded: request shed (queue full)"}"#.to_string(),
                        &["retry-after: 1"],
                    ),
                    Err(SampleError::Closed) => {
                        (503, r#"{"error":"service is shutting down"}"#.to_string(), &[])
                    }
                    Err(SampleError::Timeout(msg)) => (504, error_body_str(&msg), &[]),
                    Err(SampleError::Bad(msg)) => (400, error_body_str(&msg), &[]),
                    Err(SampleError::Internal(msg)) => (500, error_body_str(&msg), &[]),
                },
                ("GET", "/stats") => (200, stats_body(&svc, &identity), &[]),
                ("GET", "/healthz") => (200, r#"{"ok":true}"#.to_string(), &[]),
                ("GET", "/sample") | ("POST", "/stats") | ("POST", "/healthz") => {
                    (405, r#"{"error":"method not allowed"}"#.to_string(), &[])
                }
                (_, path) => (404, error_body_str(&format!("no route {path}")), &[]),
            };
        if write_response(&mut stream, status, body.as_bytes(), extra).is_err() {
            return;
        }
        if !keep_alive {
            return;
        }
    }
}

enum SampleError {
    Shed,
    Closed,
    Timeout(String),
    Bad(String),
    Internal(String),
}

fn handle_sample<Obj>(
    req: &Request,
    svc: &SamplerService<Obj>,
    identity: &ServeIdentity,
    cfg: &HttpServerConfig,
    client: u64,
) -> Result<String, SampleError>
where
    Obj: ObjJson + Send + 'static,
{
    let body = std::str::from_utf8(&req.body)
        .map_err(|_| SampleError::Bad("body is not UTF-8".to_string()))?;
    let json = Json::parse(body).map_err(|e| SampleError::Bad(e.to_string()))?;

    let n = json
        .get("n")
        .and_then(Json::as_usize)
        .ok_or_else(|| SampleError::Bad("missing or non-numeric field 'n'".to_string()))?;
    if n > cfg.max_n {
        return Err(SampleError::Bad(format!(
            "n = {n} exceeds this server's limit of {}",
            cfg.max_n
        )));
    }
    let seed = parse_seed(&json)?;

    // A client may pin the config/model it expects; serving something else
    // silently would hand it samples from the wrong distribution.
    for (field, served) in [("config", &identity.config), ("model", &identity.model)] {
        if let Some(want) = json.get(field).and_then(Json::as_str) {
            if want != served {
                return Err(SampleError::Bad(format!(
                    "this server serves {field} {served:?}, not {want:?}"
                )));
            }
        }
    }

    let temperature = match json.get("temperature") {
        None => 1.0,
        Some(t) => t.as_f64().filter(|t| t.is_finite() && *t > 0.0).ok_or_else(|| {
            SampleError::Bad("'temperature' must be a finite number > 0".to_string())
        })?,
    };

    let deadline = match json.get("deadline_ms") {
        None => cfg.default_deadline,
        Some(d) => {
            let ms = d.as_f64().filter(|m| m.is_finite() && *m > 0.0).ok_or_else(|| {
                SampleError::Bad("'deadline_ms' must be a number > 0".to_string())
            })?;
            Duration::from_millis(ms as u64).min(cfg.max_deadline)
        }
    };

    let now = Instant::now();
    let opts = SubmitOptions {
        deadline: Some(now + deadline),
        temperature,
        client,
    };
    let ticket = match svc.try_submit(SampleRequest { n_samples: n, seed }, opts) {
        SubmitOutcome::Ticket(t) => t,
        SubmitOutcome::Shed => return Err(SampleError::Shed),
        SubmitOutcome::Closed => return Err(SampleError::Closed),
    };
    // The worker resolves expiries itself (in-queue and mid-drain); the 2×
    // client-side bound only exists so a wedged worker cannot strand the
    // connection — and it keeps the "resolve within 2× deadline" guarantee
    // unconditional.
    let outputs = match ticket.wait_timeout(2 * deadline) {
        Ok(outs) => outs,
        Err(e) if is_timeout(&e) => return Err(SampleError::Timeout(e.to_string())),
        Err(e) => return Err(SampleError::Internal(e.to_string())),
    };

    let rows: Vec<Json> = outputs
        .iter()
        .map(|o| {
            Json::obj(vec![
                ("obj", o.obj.to_json()),
                ("log_pf", Json::Num(o.log_pf)),
                ("log_reward", Json::Num(o.log_reward)),
                ("length", Json::Num(o.length as f64)),
                ("traj_index", Json::Num(o.traj_index as f64)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("n", Json::Num(n as f64)),
        ("seed", Json::Str(seed.to_string())),
        ("temperature", Json::Num(temperature)),
        ("outputs", Json::Arr(rows)),
    ])
    .to_string())
}

/// Seeds are u64; JSON numbers are f64 and lose precision past 2^53, so a
/// string form is accepted (and echoed back) for full-range seeds.
fn parse_seed(json: &Json) -> Result<u64, SampleError> {
    match json.get("seed") {
        None => Err(SampleError::Bad("missing field 'seed'".to_string())),
        Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 && *x <= 2f64.powi(53) => {
            Ok(*x as u64)
        }
        Some(Json::Str(s)) => s.parse::<u64>().map_err(|_| {
            SampleError::Bad(format!("'seed' string {s:?} is not a u64"))
        }),
        Some(_) => Err(SampleError::Bad(
            "'seed' must be a non-negative integer (use a string beyond 2^53)".to_string(),
        )),
    }
}

fn stats_body<Obj: Send + 'static>(
    svc: &SamplerService<Obj>,
    identity: &ServeIdentity,
) -> String {
    Json::obj(vec![
        ("family", Json::Str(identity.family.clone())),
        ("config", Json::Str(identity.config.clone())),
        ("model", Json::Str(identity.model.clone())),
        ("registry", svc.registry().to_json()),
    ])
    .to_string()
}

fn error_body(msg: &str) -> Vec<u8> {
    error_body_str(msg).into_bytes()
}

fn error_body_str(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::hypergrid::HypergridEnv;
    use crate::reward::hypergrid::HypergridReward;
    use crate::runtime::policy::{BatchPolicy, PolicyShape, UniformPolicy};
    use crate::serve::conn::HttpClient;

    fn http_service() -> (Arc<SamplerService<Vec<i32>>>, HttpServer, String) {
        let env = HypergridEnv::new(2, 6, HypergridReward::standard(6));
        let shape = PolicyShape::of_env(&env, 4);
        let svc = Arc::new(SamplerService::spawn(env, move || {
            Ok(Box::new(UniformPolicy::new(shape)) as Box<dyn BatchPolicy>)
        }));
        let server = HttpServer::serve(
            "127.0.0.1:0",
            Arc::clone(&svc),
            ServeIdentity {
                family: "hypergrid".to_string(),
                config: "hypergrid_small".to_string(),
                model: "mlp".to_string(),
            },
            HttpServerConfig::default(),
        )
        .unwrap();
        let addr = server.local_addr().to_string();
        (svc, server, addr)
    }

    #[test]
    fn sample_roundtrip_is_deterministic_and_complete() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, body) = c.post_json("/sample", r#"{"n":5,"seed":3}"#).unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let outs = j.req_arr("outputs").unwrap();
        assert_eq!(outs.len(), 5);
        for o in outs {
            let obj = o.req_arr("obj").unwrap();
            assert!(obj.iter().all(|c| (0.0..6.0).contains(&c.as_f64().unwrap())));
            assert!(o.get("log_pf").unwrap().as_f64().unwrap() < 0.0);
            assert!(o.get("log_reward").unwrap().as_f64().is_some());
            assert!(o.req_usize("length").unwrap() >= 1);
        }
        // Same request, same bytes: the seed pins the trajectory streams.
        let (_, body2) = c.post_json("/sample", r#"{"n":5,"seed":3}"#).unwrap();
        assert_eq!(body, body2, "repeat requests must be bit-identical");
        server.shutdown();
    }

    #[test]
    fn stats_route_serves_registry_json_with_identity() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let (status, _) = c.post_json("/sample", r#"{"n":2,"seed":1}"#).unwrap();
        assert_eq!(status, 200);
        let (status, body) = c.get("/stats").unwrap();
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.req_str("family").unwrap(), "hypergrid");
        assert_eq!(j.req_str("config").unwrap(), "hypergrid_small");
        assert_eq!(j.req_str("model").unwrap(), "mlp");
        let reg = j.req("registry").unwrap();
        // Registry::to_json schema: counters/gauges/histograms objects.
        let counters = reg.get("counters").expect("registry.counters");
        assert_eq!(
            counters.get("serve.requests_completed").and_then(Json::as_usize),
            Some(1)
        );
        assert!(counters.get("serve.http.requests").is_some());
        assert!(reg
            .get("histograms")
            .and_then(|h| h.get("serve.request_latency"))
            .is_some());
        server.shutdown();
    }

    #[test]
    fn malformed_and_mismatched_requests_get_400() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let cases: &[(&str, &str)] = &[
            ("{not json", "parse"),
            (r#"{"seed":1}"#, "'n'"),
            (r#"{"n":3}"#, "'seed'"),
            (r#"{"n":3,"seed":-2}"#, "'seed'"),
            (r#"{"n":3,"seed":1,"temperature":0}"#, "'temperature'"),
            (r#"{"n":3,"seed":1,"deadline_ms":"soon"}"#, "'deadline_ms'"),
            (r#"{"n":3,"seed":1,"config":"hypergrid_8d_10"}"#, "hypergrid_small"),
            (r#"{"n":3,"seed":1,"model":"transformer"}"#, "mlp"),
        ];
        for (body, needle) in cases {
            let (status, resp) = c.post_json("/sample", body).unwrap();
            let resp = String::from_utf8_lossy(&resp).to_string();
            assert_eq!(status, 400, "{body} -> {resp}");
            assert!(
                resp.to_lowercase().contains(&needle.to_lowercase()),
                "{body}: error {resp:?} should mention {needle:?}"
            );
        }
        // Still serving after a pile of bad requests.
        let (status, _) = c.post_json("/sample", r#"{"n":1,"seed":9}"#).unwrap();
        assert_eq!(status, 200);
        server.shutdown();
    }

    #[test]
    fn routing_unknown_paths_and_methods() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        assert_eq!(c.get("/healthz").unwrap().0, 200);
        assert_eq!(c.get("/nope").unwrap().0, 404);
        assert_eq!(c.get("/sample").unwrap().0, 405);
        assert_eq!(c.post_json("/stats", "{}").unwrap().0, 405);
        server.shutdown();
    }

    #[test]
    fn seed_accepts_full_range_strings() {
        let (_svc, server, addr) = http_service();
        let mut c = HttpClient::connect(&addr).unwrap();
        let big = u64::MAX.to_string();
        let (status, body) = c
            .post_json("/sample", &format!(r#"{{"n":2,"seed":"{big}"}}"#))
            .unwrap();
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.req_str("seed").unwrap(), big, "seed echoed losslessly");
        server.shutdown();
    }

    #[test]
    fn obj_json_covers_every_family_obj_type() {
        assert_eq!(vec![1i32, 2].to_json().to_string(), "[1,2]");
        assert_eq!(vec![3i16].to_json().to_string(), "[3]");
        assert_eq!(vec![-1i8, 1].to_json().to_string(), "[-1,1]");
        assert_eq!(u64::MAX.to_json().to_string(), format!("\"{}\"", u64::MAX));
        let tree = PhyloTree::Node(
            Box::new(PhyloTree::Leaf(0)),
            Box::new(PhyloTree::Node(
                Box::new(PhyloTree::Leaf(1)),
                Box::new(PhyloTree::Leaf(2)),
            )),
        );
        assert_eq!(tree.to_json().to_string(), "[0,[1,2]]");
    }
}
