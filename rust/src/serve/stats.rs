//! Service counters: lock-free, written by the worker thread, snapshot-read
//! from any thread (the monitoring side of the QPS story).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Shared atomic counters of one [`SamplerService`].
///
/// [`SamplerService`]: crate::serve::SamplerService
pub struct ServeStats {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    /// Requests answered with an error (shutdown, policy failure). Together
    /// with `requests_completed` this accounts for every submitted request,
    /// so "pending = submitted − completed − failed" stays meaningful for
    /// monitors after a failure.
    pub requests_failed: AtomicU64,
    pub trajectories_completed: AtomicU64,
    pub policy_dispatches: AtomicU64,
    pub active_row_steps: AtomicU64,
    pub total_row_steps: AtomicU64,
    /// Hot-swaps applied by the worker (see `SamplerService::hot_swap`).
    pub policy_swaps: AtomicU64,
    /// Hot-swaps dropped because the incoming policy's dispatch shape did
    /// not match the serving one.
    pub swaps_rejected: AtomicU64,
    started: Instant,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            requests_failed: AtomicU64::new(0),
            trajectories_completed: AtomicU64::new(0),
            policy_dispatches: AtomicU64::new(0),
            active_row_steps: AtomicU64::new(0),
            total_row_steps: AtomicU64::new(0),
            policy_swaps: AtomicU64::new(0),
            swaps_rejected: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        ServeSnapshot {
            requests_submitted: self.requests_submitted.load(Ordering::Relaxed),
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            requests_failed: self.requests_failed.load(Ordering::Relaxed),
            trajectories_completed: self.trajectories_completed.load(Ordering::Relaxed),
            policy_dispatches: self.policy_dispatches.load(Ordering::Relaxed),
            active_row_steps: self.active_row_steps.load(Ordering::Relaxed),
            total_row_steps: self.total_row_steps.load(Ordering::Relaxed),
            policy_swaps: self.policy_swaps.load(Ordering::Relaxed),
            swaps_rejected: self.swaps_rejected.load(Ordering::Relaxed),
            elapsed_s: self.started.elapsed().as_secs_f64(),
        }
    }
}

/// A point-in-time copy of [`ServeStats`].
#[derive(Clone, Copy, Debug)]
pub struct ServeSnapshot {
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub requests_failed: u64,
    pub trajectories_completed: u64,
    pub policy_dispatches: u64,
    pub active_row_steps: u64,
    pub total_row_steps: u64,
    pub policy_swaps: u64,
    pub swaps_rejected: u64,
    pub elapsed_s: f64,
}

impl ServeSnapshot {
    /// Fraction of dispatched slot-steps that carried a live trajectory.
    pub fn occupancy(&self) -> f64 {
        if self.total_row_steps == 0 {
            1.0
        } else {
            self.active_row_steps as f64 / self.total_row_steps as f64
        }
    }

    /// Completed trajectories per second of service lifetime.
    pub fn objs_per_sec(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            0.0
        } else {
            self.trajectories_completed as f64 / self.elapsed_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let s = ServeStats::new();
        s.trajectories_completed.fetch_add(10, Ordering::Relaxed);
        s.active_row_steps.fetch_add(30, Ordering::Relaxed);
        s.total_row_steps.fetch_add(40, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.trajectories_completed, 10);
        assert!((snap.occupancy() - 0.75).abs() < 1e-12);
        assert!(snap.elapsed_s >= 0.0);
        let empty = ServeStats::new().snapshot();
        assert_eq!(empty.occupancy(), 1.0);
    }
}
