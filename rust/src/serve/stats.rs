//! Service metrics, exposed *through* the telemetry [`Registry`]: every
//! counter, latency histogram, and gauge of one [`SamplerService`] is a
//! named registry metric (`serve.*`), so a service's stats appear in the
//! same `Registry::to_json` payload / JSONL export as the trainer's and
//! engine's — there is no second bookkeeping system beside the registry.
//!
//! The handles are plain `Arc`ed atomics, written lock-free by the worker
//! thread and snapshot-read from any thread. By default each service gets
//! its own scoped registry (tests and multiple services do not share
//! counters); [`SamplerService::spawn_in`] lets a caller hand in the
//! process-wide [`telemetry::global`] registry so serve metrics ride the
//! `--telemetry-file` export stream.
//!
//! [`SamplerService`]: crate::serve::SamplerService
//! [`SamplerService::spawn_in`]: crate::serve::SamplerService::spawn_in
//! [`telemetry::global`]: crate::telemetry::global

use crate::telemetry::{Counter, Gauge, Histogram, Registry};
use std::sync::Arc;
use std::time::Instant;

/// Shared metric handles of one [`SamplerService`], all registered in a
/// telemetry [`Registry`] under `serve.*` names.
///
/// [`SamplerService`]: crate::serve::SamplerService
pub struct ServeStats {
    registry: Arc<Registry>,
    pub requests_submitted: Arc<Counter>,
    pub requests_completed: Arc<Counter>,
    /// Requests answered with an error (shutdown, policy failure, shed,
    /// deadline). Together with `requests_completed` this accounts for every
    /// submitted request, so "pending = submitted − completed − failed"
    /// stays meaningful for monitors after a failure.
    pub requests_failed: Arc<Counter>,
    /// Requests refused at admission because the bounded queue was full
    /// (load shedding; a subset of `requests_failed`). The HTTP layer
    /// answers these with 503.
    pub shed: Arc<Counter>,
    /// Requests cancelled by the worker because their deadline expired
    /// in-queue or mid-drain (a subset of `requests_failed`). Client-side
    /// `wait_timeout` expiries are *not* counted here — from the service's
    /// view those requests still complete.
    pub requests_timedout: Arc<Counter>,
    pub trajectories_completed: Arc<Counter>,
    pub policy_dispatches: Arc<Counter>,
    pub active_row_steps: Arc<Counter>,
    pub total_row_steps: Arc<Counter>,
    /// Hot-swaps applied by the worker (see `SamplerService::hot_swap`).
    pub policy_swaps: Arc<Counter>,
    /// Hot-swaps dropped because the incoming policy's dispatch shape did
    /// not match the serving one.
    pub swaps_rejected: Arc<Counter>,
    /// Enqueue → ticket-fulfilled latency per completed request (ns).
    pub request_latency: Arc<Histogram>,
    /// Enqueue → first trajectory issued into the slot table (ns): the
    /// queueing + admission delay a request sees before work starts.
    pub first_dispatch_latency: Arc<Histogram>,
    /// Cumulative slot occupancy (active / total row-steps), refreshed
    /// after each drain.
    pub occupancy: Arc<Gauge>,
    /// Watchdog heartbeat: the registry's elapsed-seconds clock at the
    /// worker's last sign of progress (job-source poll / dispatch / drain).
    /// `/healthz` computes the age as `registry.elapsed_s() - heartbeat` —
    /// same clock on both sides, no skew. Touch via [`ServeStats::beat`].
    pub worker_heartbeat_s: Arc<Gauge>,
    /// Requests currently admitted into the slot table (in-flight drains).
    pub inflight: Arc<Gauge>,
    /// Deepest admission-queue backlog seen so far (mirrors
    /// `Queue::high_water` into the registry so `/metrics` exports it).
    pub queue_high_water: Arc<Gauge>,
    started: Instant,
}

impl Default for ServeStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeStats {
    /// Stats backed by a fresh scoped registry (the default for tests and
    /// standalone services).
    pub fn new() -> ServeStats {
        Self::in_registry(Arc::new(Registry::new()))
    }

    /// Register the `serve.*` metrics in `registry` (get-or-register, so
    /// two services sharing a registry share — i.e. merge — counters).
    pub fn in_registry(registry: Arc<Registry>) -> ServeStats {
        ServeStats {
            requests_submitted: registry.counter("serve.requests_submitted"),
            requests_completed: registry.counter("serve.requests_completed"),
            requests_failed: registry.counter("serve.requests_failed"),
            shed: registry.counter("serve.shed"),
            requests_timedout: registry.counter("serve.requests_timedout"),
            trajectories_completed: registry.counter("serve.trajectories_completed"),
            policy_dispatches: registry.counter("serve.policy_dispatches"),
            active_row_steps: registry.counter("serve.active_row_steps"),
            total_row_steps: registry.counter("serve.total_row_steps"),
            policy_swaps: registry.counter("serve.policy_swaps"),
            swaps_rejected: registry.counter("serve.swaps_rejected"),
            request_latency: registry.histogram("serve.request_latency"),
            first_dispatch_latency: registry.histogram("serve.first_dispatch_latency"),
            occupancy: registry.gauge("serve.occupancy"),
            worker_heartbeat_s: registry.gauge("serve.worker_heartbeat_s"),
            inflight: registry.gauge("serve.inflight"),
            queue_high_water: registry.gauge("serve.queue_high_water"),
            started: Instant::now(),
            registry,
        }
    }

    /// Touch the worker heartbeat (stores the registry clock; see the
    /// field docs). Unconditional — liveness reporting must not depend on
    /// the telemetry flag.
    pub fn beat(&self) {
        self.worker_heartbeat_s.set(self.registry.elapsed_s());
    }

    /// Seconds since the last [`ServeStats::beat`] on the registry clock.
    pub fn heartbeat_age_s(&self) -> f64 {
        (self.registry.elapsed_s() - self.worker_heartbeat_s.get()).max(0.0)
    }

    /// The backing registry (scoped or shared-global).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        ServeSnapshot {
            requests_submitted: self.requests_submitted.get(),
            requests_completed: self.requests_completed.get(),
            requests_failed: self.requests_failed.get(),
            shed: self.shed.get(),
            requests_timedout: self.requests_timedout.get(),
            trajectories_completed: self.trajectories_completed.get(),
            policy_dispatches: self.policy_dispatches.get(),
            active_row_steps: self.active_row_steps.get(),
            total_row_steps: self.total_row_steps.get(),
            policy_swaps: self.policy_swaps.get(),
            swaps_rejected: self.swaps_rejected.get(),
            elapsed_s: self.started.elapsed().as_secs_f64(),
        }
    }
}

/// A point-in-time copy of [`ServeStats`].
#[derive(Clone, Copy, Debug)]
pub struct ServeSnapshot {
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub requests_failed: u64,
    pub shed: u64,
    pub requests_timedout: u64,
    pub trajectories_completed: u64,
    pub policy_dispatches: u64,
    pub active_row_steps: u64,
    pub total_row_steps: u64,
    pub policy_swaps: u64,
    pub swaps_rejected: u64,
    pub elapsed_s: f64,
}

impl ServeSnapshot {
    /// Fraction of dispatched slot-steps that carried a live trajectory.
    pub fn occupancy(&self) -> f64 {
        if self.total_row_steps == 0 {
            1.0
        } else {
            self.active_row_steps as f64 / self.total_row_steps as f64
        }
    }

    /// Completed trajectories per second of service lifetime.
    pub fn objs_per_sec(&self) -> f64 {
        if self.elapsed_s <= 0.0 {
            0.0
        } else {
            self.trajectories_completed as f64 / self.elapsed_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn snapshot_reflects_counters() {
        let s = ServeStats::new();
        s.trajectories_completed.add(10);
        s.active_row_steps.add(30);
        s.total_row_steps.add(40);
        let snap = s.snapshot();
        assert_eq!(snap.trajectories_completed, 10);
        assert!((snap.occupancy() - 0.75).abs() < 1e-12);
        assert!(snap.elapsed_s >= 0.0);
        let empty = ServeStats::new().snapshot();
        assert_eq!(empty.occupancy(), 1.0);
    }

    /// The stats ARE registry metrics: the same atoms are reachable by name
    /// and appear in the registry's JSON payload.
    #[test]
    fn stats_are_registry_metrics() {
        let s = ServeStats::new();
        s.requests_submitted.add(3);
        s.request_latency.record(1_000);
        s.occupancy.set(0.9);
        let reg = s.registry();
        assert_eq!(reg.counter("serve.requests_submitted").get(), 3);
        assert_eq!(reg.histogram("serve.request_latency").count(), 1);
        let j = reg.to_json();
        assert_eq!(
            j.get("counters")
                .and_then(|c| c.get("serve.requests_submitted"))
                .and_then(Json::as_usize),
            Some(3)
        );
        assert!(j
            .get("histograms")
            .and_then(|h| h.get("serve.request_latency"))
            .is_some());
        assert_eq!(
            j.get("gauges")
                .and_then(|g| g.get("serve.occupancy"))
                .and_then(Json::as_f64),
            Some(0.9)
        );
    }

    /// The production-envelope counters are registry metrics too, so the
    /// HTTP `/stats` route (which serializes the registry) exposes shedding
    /// and deadline cancels without extra plumbing.
    #[test]
    fn shed_and_timeout_counters_reach_registry_json() {
        let s = ServeStats::new();
        s.shed.add(2);
        s.requests_timedout.inc();
        let snap = s.snapshot();
        assert_eq!(snap.shed, 2);
        assert_eq!(snap.requests_timedout, 1);
        let j = s.registry().to_json();
        let counter = |name: &str| {
            j.get("counters").and_then(|c| c.get(name)).and_then(Json::as_usize)
        };
        assert_eq!(counter("serve.shed"), Some(2));
        assert_eq!(counter("serve.requests_timedout"), Some(1));
    }

    /// Watchdog gauges live in the registry and the heartbeat age is
    /// computed on the registry's own clock.
    #[test]
    fn heartbeat_and_watchdog_gauges_reach_registry() {
        let s = ServeStats::new();
        s.beat();
        s.inflight.set(2.0);
        s.queue_high_water.set(5.0);
        assert!(s.heartbeat_age_s() < 1.0, "fresh beat has ~zero age");
        let reg = s.registry();
        assert!(reg.gauge("serve.worker_heartbeat_s").get() >= 0.0);
        assert_eq!(reg.gauge("serve.inflight").get(), 2.0);
        assert_eq!(reg.gauge("serve.queue_high_water").get(), 5.0);
    }

    /// Two services sharing one registry merge their counters (get-or-
    /// register semantics) — the documented behavior for the global
    /// registry under `--serve --telemetry`.
    #[test]
    fn shared_registry_merges_counters() {
        let reg = Arc::new(Registry::new());
        let a = ServeStats::in_registry(Arc::clone(&reg));
        let b = ServeStats::in_registry(Arc::clone(&reg));
        a.requests_submitted.inc();
        b.requests_submitted.inc();
        assert_eq!(reg.counter("serve.requests_submitted").get(), 2);
    }
}
