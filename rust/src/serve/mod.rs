//! `serve` — a continuous-batching trajectory-sampling service.
//!
//! The training loop's [`forward_rollout`] pays the classic padded-batch
//! tax: every policy dispatch carries all `B` rows until the *slowest*
//! trajectory in the batch terminates, so short trajectories ride along as
//! no-op padding. That is the right trade for training (the train graph
//! wants one rectangular batch), but it is the wrong trade for *serving*
//! samples, where the unit of work is a trajectory, not a batch.
//!
//! This module implements the standard inference-server fix — **continuous
//! batching with slot refill**: a fixed-`B` slot table rides on top of the
//! same fixed-shape policy dispatch, and the moment a slot's trajectory
//! terminates it is refilled (via [`VecEnv::reset_row`]) with the next
//! queued trajectory. Dispatch occupancy stays near 100% under load
//! regardless of trajectory-length heterogeneity.
//!
//! Layering, bottom-up:
//!
//! - [`sampler::sample_stream`] — the synchronous slot engine: pulls
//!   trajectory jobs from a callback, steps all active slots with one
//!   [`BatchPolicy::eval`] per env step, emits finished trajectories to a
//!   sink. Usable inline (no threads) — this is what
//!   `Trainer::sample_objs_served` and the benches use.
//! - [`queue::Queue`] — a std-only MPSC queue with close semantics (the
//!   image has no tokio/rayon; mirrors `util::threadpool`'s philosophy).
//! - [`worker::SamplerService`] — the service: a dedicated worker thread
//!   owning the environment and the policy, fed by the queue, answering
//!   [`SampleRequest`]s through [`SampleTicket`]s. The serving policy is
//!   **hot-swappable** ([`SamplerService::hot_swap`]): a new snapshot
//!   takes effect at the next dispatch, mid-drain included, which is how
//!   the training engine's `train --serve` keeps live requests on the
//!   improving policy (see [`crate::engine`]).
//! - [`stats::ServeStats`] — the service's metrics (dispatches, occupancy,
//!   request latency histograms, trajectories/sec), registered as `serve.*`
//!   entries in a telemetry [`Registry`](crate::telemetry::Registry) and
//!   readable from any thread; [`SamplerService::spawn_in`] folds them into
//!   the process-wide telemetry export.
//! - [`http::HttpServer`] (+ [`conn`]) — the std-only HTTP/1.1 front end:
//!   accepts JSON sample requests over TCP and multiplexes many concurrent
//!   clients onto one `SamplerService`, adding the production envelope —
//!   bounded-queue load shedding (503), per-request deadlines (504, enforced
//!   in-queue and mid-drain), per-client round-robin fairness, and the
//!   observability routes — `/stats` (telemetry registry as JSON),
//!   `/metrics` (Prometheus text exposition), `/trace` (recent sampled
//!   request waterfalls), and a watchdog-backed `/healthz` that reports
//!   machine-readable degradation reasons (stalled worker, closed service)
//!   instead of an unconditional ok. See the README's "Serving over HTTP"
//!   section for the wire format.
//!
//! ## The production envelope
//!
//! [`SamplerService::spawn_with`] bounds the request queue; over-capacity
//! submissions are *shed* ([`SubmitOutcome::Shed`], `serve.shed`) instead of
//! growing an unbounded backlog. [`SamplerService::submit_opts`] carries
//! per-request [`SubmitOptions`]: an absolute **deadline** (expired requests
//! resolve with a [`TIMEOUT_ERROR`] error whether still queued or already
//! mid-drain), a sampling **temperature**, and a **client** id for
//! round-robin fairness across clients sharing the slot table. On the
//! client side, [`SampleTicket::wait_timeout`] bounds the wait itself.
//!
//! ## Determinism
//!
//! Trajectory `i` of a request with seed `s` draws its actions from the
//! dedicated RNG stream `Rng::new(traj_seed(s, i))`. Because every built-in
//! policy is row-wise (row `i` of a dispatch depends only on row `i` of the
//! inputs), a trajectory's result is independent of which slot it ran in
//! and of whatever else shared its dispatches. Consequently a request's
//! output is **bit-reproducible** for a fixed seed and a single worker —
//! and invariant even to the slot-table width `B` (covered by tests).
//!
//! ## When to prefer this over `forward_rollout`
//!
//! Use the service (or `sample_objs_served`) for evaluation-time and
//! serving-time sampling: heterogeneous trajectory lengths, exact sample
//! counts (`n` need not be a multiple of `B`), many concurrent requesters.
//! Keep `forward_rollout` for training, which needs the padded `[B, T+1]`
//! batch layout the train graph consumes.
//!
//! [`forward_rollout`]: crate::coordinator::rollout::forward_rollout
//! [`VecEnv::reset_row`]: crate::envs::VecEnv::reset_row
//! [`BatchPolicy::eval`]: crate::runtime::policy::BatchPolicy::eval

pub mod conn;
pub mod http;
pub mod queue;
pub mod request;
pub mod sampler;
pub mod stats;
pub mod worker;

pub use http::{HttpServer, HttpServerConfig, ObjJson, ServeIdentity};
pub use queue::PushError;
pub use request::{
    is_timeout, SampleOutput, SampleRequest, SampleTicket, TIMEOUT_ERROR,
};
pub use sampler::{sample_stream, StreamStats, TrajJob, TrajResult};
pub use stats::{ServeSnapshot, ServeStats};
pub use worker::{SamplerService, SubmitOptions, SubmitOutcome};

/// Derive the RNG seed of trajectory `traj_index` within a request seeded
/// with `request_seed` (SplitMix64-style mixing, matching how
/// `util::rng::Rng` seeds its streams).
pub fn traj_seed(request_seed: u64, traj_index: u64) -> u64 {
    let mut z = request_seed
        .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(traj_index.wrapping_add(1)));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traj_seeds_are_distinct_and_stable() {
        let mut seen = std::collections::HashSet::new();
        for req in 0..4u64 {
            for i in 0..256u64 {
                assert_eq!(traj_seed(req, i), traj_seed(req, i));
                assert!(seen.insert(traj_seed(req, i)), "seed collision at {req}/{i}");
            }
        }
    }
}
