//! QM9 small-molecule environment (Shen et al. 2023 sequence formulation;
//! gfnx env #4): prepend/append generation of 5 building blocks from an
//! 11-block vocabulary with 2 stems, scored by a (synthetic, see DESIGN.md
//! §3) frozen HOMO-LUMO-gap proxy.

use super::seq::{SeqEnv, SeqScheme};
use crate::reward::proxy::Qm9Reward;
use crate::util::stats::softmax_from_logs;

/// QM9 env: prepend/append over 11 building blocks, 5 positions.
pub type Qm9Env = SeqEnv<Qm9Reward>;

/// Build the QM9 environment (paper: reward exponent β = 10).
pub fn qm9_env(seed: u64, beta: f64) -> Qm9Env {
    SeqEnv::new(
        SeqScheme::PrependAppend,
        Qm9Reward::VOCAB,
        Qm9Reward::LEN,
        Qm9Reward::synthetic(seed, beta),
    )
}

/// Number of terminal molecules: 11^5.
pub const QM9_SPACE: usize = 161_051;

pub fn flatten(seq: &[i16]) -> usize {
    let mut idx = 0usize;
    for &t in seq {
        idx = idx * Qm9Reward::VOCAB + t as usize;
    }
    idx
}

pub fn unflatten(mut idx: usize) -> Vec<i16> {
    let mut seq = vec![0i16; Qm9Reward::LEN];
    for p in (0..Qm9Reward::LEN).rev() {
        seq[p] = (idx % Qm9Reward::VOCAB) as i16;
        idx /= Qm9Reward::VOCAB;
    }
    seq
}

/// Exact target distribution π(x) ∝ R(x) over all 11^5 molecules.
pub fn exact_target(env: &Qm9Env) -> Vec<f64> {
    use crate::reward::RewardModule;
    let logs: Vec<f64> = (0..QM9_SPACE)
        .map(|idx| env.reward.log_reward(&unflatten(idx)))
        .collect();
    softmax_from_logs(&logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{testkit, VecEnv};

    #[test]
    fn spec_matches_paper() {
        let e = qm9_env(0, 10.0);
        let s = e.spec();
        assert_eq!(s.n_actions, 22); // 11 prepend + 11 append (2 stems)
        assert_eq!(s.n_bwd_actions, 2);
        assert_eq!(s.t_max, 5);
    }

    #[test]
    fn flatten_roundtrip() {
        for idx in [0usize, 1, 161_050, 77_777] {
            assert_eq!(flatten(&unflatten(idx)), idx);
        }
    }

    #[test]
    fn exact_target_normalizes() {
        let e = qm9_env(0, 10.0);
        let p = exact_target(&e);
        assert_eq!(p.len(), QM9_SPACE);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn invariants() {
        let e = qm9_env(0, 10.0);
        testkit::check_forward_backward_inversion(&e, 8, 61);
        testkit::check_masks_and_obs(&e, 8, 62);
        testkit::check_inject_extract_roundtrip(&e, 8, 63);
        testkit::check_backward_rollout_reaches_s0(&e, 8, 64);
    }

    #[test]
    fn reset_row_matches_fresh() {
        testkit::check_reset_row(&qm9_env(0, 10.0), 8, 65);
    }
}
