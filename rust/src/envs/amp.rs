//! AMP (antimicrobial peptide) environment (Jain et al. 2022; gfnx env #5):
//! variable-length autoregressive generation over the 20 amino acids (up to
//! 60 tokens) with a (synthetic, see DESIGN.md §3) frozen classifier reward
//! R(x) = max(σ(f(x)), r_min).

use super::seq::{SeqEnv, SeqScheme};
use crate::reward::proxy::AmpReward;

/// AMP env: variable-length autoregressive, stop action last.
pub type AmpEnv = SeqEnv<AmpReward>;

pub const AMP_VOCAB: usize = 20;
pub const AMP_MAX_LEN: usize = 60;

/// Build the AMP environment with the paper's dimensions.
pub fn amp_env(seed: u64, r_min: f64) -> AmpEnv {
    amp_env_sized(seed, r_min, AMP_MAX_LEN)
}

/// Reduced-length variant for tests and budget-scaled benches.
pub fn amp_env_sized(seed: u64, r_min: f64, max_len: usize) -> AmpEnv {
    SeqEnv::new(
        SeqScheme::AutoregVar,
        AMP_VOCAB,
        max_len,
        AmpReward::synthetic(seed, max_len, AMP_VOCAB, r_min),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{testkit, VecEnv};

    #[test]
    fn spec_matches_paper() {
        let e = amp_env(0, 1e-3);
        let s = e.spec();
        assert_eq!(s.n_actions, 21); // 20 aa + stop
        assert_eq!(s.n_bwd_actions, 1);
        assert_eq!(s.t_max, 61);
        assert_eq!(s.obs_dim, 60 * 21);
    }

    #[test]
    fn variable_length_objects() {
        let e = amp_env_sized(0, 1e-3, 10);
        let mut st = e.reset(1);
        e.step(&mut st, &[4]);
        e.step(&mut st, &[7]);
        e.step(&mut st, &[e.stop_action()]);
        assert!(e.is_terminal(&st, 0));
        assert_eq!(e.extract(&st, 0), vec![4, 7]);
    }

    #[test]
    fn invariants() {
        let e = amp_env_sized(0, 1e-3, 8);
        testkit::check_forward_backward_inversion(&e, 8, 71);
        testkit::check_masks_and_obs(&e, 8, 72);
        testkit::check_inject_extract_roundtrip(&e, 8, 73);
        testkit::check_backward_rollout_reaches_s0(&e, 8, 74);
    }

    #[test]
    fn reset_row_matches_fresh() {
        testkit::check_reset_row(&amp_env_sized(0, 1e-3, 8), 8, 75);
    }
}
