//! Hypergrid environment (Bengio et al. 2021; gfnx env #1).
//!
//! A d-dimensional grid of side H. Actions 0..d increment one coordinate
//! (staying inside the grid); the **last** action is the stop/exit action
//! that moves the state to its terminal copy. Every state is reachable and
//! every state has a terminal copy, so trajectories have length ≤ d(H−1)+1.

use super::{EnvSpec, StepOut, VecEnv};
use crate::reward::RewardModule;
use crate::util::tensor::one_hot_into;

/// Batched hypergrid state: row-major `[n, d]` coordinates + terminal flags.
#[derive(Clone, Debug, PartialEq)]
pub struct HypergridState {
    pub coords: Vec<i32>,
    pub terminal: Vec<bool>,
    pub d: usize,
}

impl HypergridState {
    #[inline]
    pub fn coords_of(&self, i: usize) -> &[i32] {
        &self.coords[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    fn coords_of_mut(&mut self, i: usize) -> &mut [i32] {
        &mut self.coords[i * self.d..(i + 1) * self.d]
    }
}

/// The hypergrid environment. `R` scores terminal coordinate vectors.
#[derive(Clone, Debug)]
pub struct HypergridEnv<R> {
    pub dim: usize,
    pub side: usize,
    pub reward: R,
}

impl<R: RewardModule<Vec<i32>>> HypergridEnv<R> {
    pub fn new(dim: usize, side: usize, reward: R) -> Self {
        assert!(dim >= 1 && side >= 2);
        HypergridEnv { dim, side, reward }
    }

    /// Index of the stop action.
    #[inline]
    pub fn stop_action(&self) -> i32 {
        self.dim as i32
    }

    /// Total number of terminal states (H^d).
    pub fn num_terminal_states(&self) -> usize {
        self.side.pow(self.dim as u32)
    }

    /// Flatten coordinates to a linear index in [0, H^d).
    pub fn flat_index(&self, coords: &[i32]) -> usize {
        let mut idx = 0usize;
        for &c in coords {
            idx = idx * self.side + c as usize;
        }
        idx
    }

    /// Inverse of [`Self::flat_index`].
    pub fn unflatten(&self, mut idx: usize) -> Vec<i32> {
        let mut coords = vec![0i32; self.dim];
        for j in (0..self.dim).rev() {
            coords[j] = (idx % self.side) as i32;
            idx /= self.side;
        }
        coords
    }
}

impl<R: RewardModule<Vec<i32>>> VecEnv for HypergridEnv<R> {
    type State = HypergridState;
    type Obj = Vec<i32>;

    fn spec(&self) -> EnvSpec {
        EnvSpec {
            obs_dim: self.dim * self.side,
            n_actions: self.dim + 1,
            n_bwd_actions: self.dim,
            t_max: self.dim * (self.side - 1) + 1,
            // One coordinate one-hot per grid dimension.
            token_shape: Some((self.dim, self.side)),
        }
    }

    fn reset(&self, n: usize) -> HypergridState {
        HypergridState {
            coords: vec![0; n * self.dim],
            terminal: vec![false; n],
            d: self.dim,
        }
    }

    fn reset_row(&self, state: &mut HypergridState, idx: usize) {
        state.coords_of_mut(idx).iter_mut().for_each(|c| *c = 0);
        state.terminal[idx] = false;
    }

    fn batch_len(&self, state: &HypergridState) -> usize {
        state.terminal.len()
    }

    fn step(&self, state: &mut HypergridState, actions: &[i32]) -> StepOut {
        let n = state.terminal.len();
        debug_assert_eq!(actions.len(), n);
        let mut out = StepOut::new(n);
        for i in 0..n {
            if state.terminal[i] || actions[i] < 0 {
                out.done[i] = state.terminal[i];
                continue;
            }
            let a = actions[i];
            if a == self.stop_action() {
                state.terminal[i] = true;
                out.done[i] = true;
                out.log_reward[i] = self.reward.log_reward(&state.coords_of(i).to_vec());
            } else {
                let j = a as usize;
                debug_assert!(j < self.dim, "action out of range");
                let c = &mut state.coords_of_mut(i)[j];
                debug_assert!((*c as usize) < self.side - 1, "illegal increment");
                *c += 1;
            }
        }
        out
    }

    fn backward_step(&self, state: &mut HypergridState, actions: &[i32]) {
        let n = state.terminal.len();
        debug_assert_eq!(actions.len(), n);
        for i in 0..n {
            if actions[i] < 0 {
                continue;
            }
            if state.terminal[i] {
                // Unique parent: the non-terminal copy (undo stop).
                state.terminal[i] = false;
            } else {
                let j = actions[i] as usize;
                debug_assert!(j < self.dim);
                let c = &mut state.coords_of_mut(i)[j];
                debug_assert!(*c > 0, "illegal decrement");
                *c -= 1;
            }
        }
    }

    fn get_backward_action(&self, _prev: &HypergridState, _idx: usize, fwd_action: i32) -> i32 {
        if fwd_action == self.stop_action() {
            0 // ignored: undo-stop is deterministic
        } else {
            fwd_action
        }
    }

    fn forward_action_of(&self, state: &HypergridState, idx: usize, bwd_action: i32) -> i32 {
        if state.terminal[idx] {
            self.stop_action()
        } else {
            bwd_action
        }
    }

    fn fwd_mask_into(&self, state: &HypergridState, idx: usize, out: &mut [bool]) {
        debug_assert_eq!(out.len(), self.dim + 1);
        let coords = state.coords_of(idx);
        for j in 0..self.dim {
            out[j] = (coords[j] as usize) < self.side - 1;
        }
        out[self.dim] = true; // stop always legal
    }

    fn bwd_mask_into(&self, state: &HypergridState, idx: usize, out: &mut [bool]) {
        debug_assert_eq!(out.len(), self.dim);
        if state.terminal[idx] {
            // Deterministic undo-stop: expose a single legal pseudo-action.
            out.iter_mut().for_each(|m| *m = false);
            out[0] = true;
            return;
        }
        let coords = state.coords_of(idx);
        for j in 0..self.dim {
            out[j] = coords[j] > 0;
        }
    }

    fn obs_into(&self, state: &HypergridState, idx: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim * self.side);
        let coords = state.coords_of(idx);
        for j in 0..self.dim {
            one_hot_into(out, j * self.side, self.side, coords[j] as usize);
        }
    }

    fn is_terminal(&self, state: &HypergridState, idx: usize) -> bool {
        state.terminal[idx]
    }

    fn is_initial(&self, state: &HypergridState, idx: usize) -> bool {
        !state.terminal[idx] && state.coords_of(idx).iter().all(|&c| c == 0)
    }

    fn extract(&self, state: &HypergridState, idx: usize) -> Vec<i32> {
        debug_assert!(state.terminal[idx], "extract on non-terminal state");
        state.coords_of(idx).to_vec()
    }

    fn inject_terminal(&self, objs: &[Vec<i32>]) -> HypergridState {
        let n = objs.len();
        let mut coords = Vec::with_capacity(n * self.dim);
        for o in objs {
            assert_eq!(o.len(), self.dim);
            coords.extend_from_slice(o);
        }
        HypergridState { coords, terminal: vec![true; n], d: self.dim }
    }

    fn log_reward_obj(&self, obj: &Vec<i32>) -> f64 {
        self.reward.log_reward(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::testkit;
    use crate::reward::hypergrid::HypergridReward;

    fn env(d: usize, h: usize) -> HypergridEnv<HypergridReward> {
        HypergridEnv::new(d, h, HypergridReward::standard(h))
    }

    #[test]
    fn spec_shapes() {
        let e = env(4, 20);
        let s = e.spec();
        assert_eq!(s.obs_dim, 80);
        assert_eq!(s.n_actions, 5);
        assert_eq!(s.n_bwd_actions, 4);
        assert_eq!(s.t_max, 77);
    }

    #[test]
    fn listing1_semantics() {
        // Mirrors the paper's Listing 1: step coord 0, then stop.
        let e = env(3, 5);
        let mut st = e.reset(1);
        let out = e.step(&mut st, &[0]);
        assert!(!st.terminal[0]);
        assert_eq!(out.log_reward[0], 0.0);
        let out = e.step(&mut st, &[e.stop_action()]);
        assert!(st.terminal[0]);
        assert!(out.log_reward[0].is_finite());
        assert!(out.log_reward[0] != 0.0);
    }

    #[test]
    fn listing2_backward_inverts() {
        let e = env(3, 5);
        let mut st = e.reset(1);
        e.step(&mut st, &[0]);
        let before = st.clone();
        e.step(&mut st, &[1]);
        let bwd = e.get_backward_action(&before, 0, 1);
        e.backward_step(&mut st, &[bwd]);
        assert_eq!(st, before);
    }

    #[test]
    fn boundary_masking() {
        let e = env(2, 3);
        let mut st = e.reset(1);
        // Walk coord 0 to the edge.
        e.step(&mut st, &[0]);
        e.step(&mut st, &[0]);
        let mut mask = [false; 3];
        e.fwd_mask_into(&st, 0, &mut mask);
        assert_eq!(mask, [false, true, true]); // coord0 at edge, coord1 free, stop
    }

    #[test]
    fn flat_index_roundtrip() {
        let e = env(3, 7);
        for idx in [0usize, 1, 42, 341, 342] {
            assert_eq!(e.flat_index(&e.unflatten(idx)), idx);
        }
    }

    #[test]
    fn stepping_terminal_is_noop() {
        let e = env(2, 4);
        let mut st = e.reset(1);
        e.step(&mut st, &[e.stop_action()]);
        let snap = st.clone();
        let out = e.step(&mut st, &[0]);
        assert_eq!(st, snap);
        assert!(out.done[0]);
        assert_eq!(out.log_reward[0], 0.0); // reward only on the terminal transition
    }

    #[test]
    fn invariants_small() {
        let e = env(3, 4);
        testkit::check_forward_backward_inversion(&e, 8, 11);
        testkit::check_masks_and_obs(&e, 8, 12);
        testkit::check_inject_extract_roundtrip(&e, 8, 13);
        testkit::check_backward_rollout_reaches_s0(&e, 8, 14);
    }

    #[test]
    fn reset_row_matches_fresh() {
        testkit::check_reset_row(&env(3, 4), 8, 15);
        // Also explicitly: a terminal row refilled in place is initial again
        // while its neighbours keep their state.
        let e = env(2, 4);
        let mut st = e.reset(2);
        e.step(&mut st, &[e.stop_action(), 0]);
        assert!(e.is_terminal(&st, 0));
        e.reset_row(&mut st, 0);
        assert!(e.is_initial(&st, 0) && !e.is_terminal(&st, 0));
        assert_eq!(st.coords_of(1), &[1, 0], "neighbour row must be untouched");
    }

    #[test]
    fn invariants_paper_size() {
        let e = env(4, 20);
        testkit::check_forward_backward_inversion(&e, 4, 21);
        testkit::check_backward_rollout_reaches_s0(&e, 4, 22);
    }
}
