//! Bayesian structure learning environment (Deleu et al. 2022; gfnx env #7).
//!
//! Sequentially constructs a DAG over `d` nodes by adding edges while
//! enforcing acyclicity with an incrementally maintained transitive-closure
//! reachability matrix (the paper's "online mask updates", O(d²) per edge).
//! Every state may be terminal via an explicit stop action; the reward is a
//! modular log-posterior (BGe or linear-Gaussian local scores, precomputed
//! into a table — see [`crate::reward::bge`] / [`crate::reward::lingauss`]).
//!
//! Action layout: `u·d + v` adds edge u→v for `u, v < d`; the last action
//! (`d²`) is stop. Backward actions: `u·d + v` removes edge u→v.
//!
//! DAGs are represented as `u64` bitmasks (bit `u·d + v` = edge u→v), which
//! caps d at 8 — ample for the paper's d = 5 experiments.

use super::{EnvSpec, StepOut, VecEnv};
use crate::reward::RewardModule;

/// Batched DAG-construction state.
#[derive(Clone, Debug, PartialEq)]
pub struct BayesNetState {
    /// Adjacency bitmask per env (bit u·d+v = edge u→v).
    pub adj: Vec<u64>,
    /// Reachability bitmask per env: bit u·d+v = "there is a directed path
    /// u ⇝ v (including u = v)". This is the transitive closure used for
    /// O(d²) acyclicity masking.
    pub reach: Vec<u64>,
    pub terminal: Vec<bool>,
    pub d: usize,
}

/// The DAG environment; `R` scores adjacency bitmasks.
#[derive(Clone, Debug)]
pub struct BayesNetEnv<R> {
    pub d: usize,
    pub reward: R,
}

#[inline]
fn bit(d: usize, u: usize, v: usize) -> u64 {
    1u64 << (u * d + v)
}

/// Identity reachability (every node reaches itself).
fn reach_identity(d: usize) -> u64 {
    let mut r = 0u64;
    for u in 0..d {
        r |= bit(d, u, u);
    }
    r
}

/// Recompute reachability from an adjacency mask (used on backward steps,
/// where incremental closure updates do not apply). O(d³), d ≤ 8.
pub fn closure_of(adj: u64, d: usize) -> u64 {
    let mut r = reach_identity(d);
    // Floyd–Warshall over bitmasks.
    for k in 0..d {
        for u in 0..d {
            let uk = r & bit(d, u, k) != 0 || adj & bit(d, u, k) != 0;
            if uk {
                for v in 0..d {
                    if r & bit(d, k, v) != 0 || adj & bit(d, k, v) != 0 {
                        r |= bit(d, u, v);
                    }
                }
            }
        }
    }
    // Direct edges are paths too.
    r | adj
}

impl<R: RewardModule<u64>> BayesNetEnv<R> {
    pub fn new(d: usize, reward: R) -> Self {
        assert!(d >= 2 && d <= 8, "u64 bitmask supports d ≤ 8");
        BayesNetEnv { d, reward }
    }

    #[inline]
    pub fn stop_action(&self) -> i32 {
        (self.d * self.d) as i32
    }

    /// Parent-set bitmask of node v in adjacency mask `adj`.
    pub fn parents_of(adj: u64, d: usize, v: usize) -> u64 {
        let mut mask = 0u64;
        for u in 0..d {
            if adj & bit(d, u, v) != 0 {
                mask |= 1 << u;
            }
        }
        mask
    }
}

impl<R: RewardModule<u64>> VecEnv for BayesNetEnv<R> {
    type State = BayesNetState;
    type Obj = u64;

    fn spec(&self) -> EnvSpec {
        EnvSpec {
            obs_dim: self.d * self.d,
            n_actions: self.d * self.d + 1,
            n_bwd_actions: self.d * self.d,
            t_max: self.d * (self.d - 1) / 2 + 1,
            // Flat adjacency bitmap, not per-node feature tokens.
            token_shape: None,
        }
    }

    fn reset(&self, n: usize) -> BayesNetState {
        BayesNetState {
            adj: vec![0; n],
            reach: vec![reach_identity(self.d); n],
            terminal: vec![false; n],
            d: self.d,
        }
    }

    fn reset_row(&self, state: &mut BayesNetState, idx: usize) {
        state.adj[idx] = 0;
        state.reach[idx] = reach_identity(self.d);
        state.terminal[idx] = false;
    }

    fn batch_len(&self, state: &BayesNetState) -> usize {
        state.terminal.len()
    }

    fn step(&self, state: &mut BayesNetState, actions: &[i32]) -> StepOut {
        let n = state.terminal.len();
        let d = self.d;
        let mut out = StepOut::new(n);
        for i in 0..n {
            if state.terminal[i] || actions[i] < 0 {
                out.done[i] = state.terminal[i];
                continue;
            }
            let a = actions[i];
            if a == self.stop_action() {
                state.terminal[i] = true;
                out.done[i] = true;
                out.log_reward[i] = self.reward.log_reward(&state.adj[i]);
                continue;
            }
            let (u, v) = ((a as usize) / d, (a as usize) % d);
            debug_assert!(u != v, "self loop");
            debug_assert_eq!(state.adj[i] & bit(d, u, v), 0, "edge exists");
            debug_assert_eq!(state.reach[i] & bit(d, v, u), 0, "would create cycle");
            state.adj[i] |= bit(d, u, v);
            // Online closure update: anyone reaching u now reaches anything
            // v reaches — OR of the outer product reach[:,u] ⊗ reach[v,:].
            let reach = state.reach[i];
            let mut new_reach = reach;
            for a_ in 0..d {
                if reach & bit(d, a_, u) != 0 {
                    for b_ in 0..d {
                        if reach & bit(d, v, b_) != 0 {
                            new_reach |= bit(d, a_, b_);
                        }
                    }
                }
            }
            state.reach[i] = new_reach;
        }
        out
    }

    fn backward_step(&self, state: &mut BayesNetState, actions: &[i32]) {
        let n = state.terminal.len();
        let d = self.d;
        for i in 0..n {
            if actions[i] < 0 {
                continue;
            }
            if state.terminal[i] {
                state.terminal[i] = false; // undo stop (unique parent)
                continue;
            }
            let a = actions[i] as usize;
            let (u, v) = (a / d, a % d);
            debug_assert!(state.adj[i] & bit(d, u, v) != 0, "removing absent edge");
            state.adj[i] &= !bit(d, u, v);
            state.reach[i] = closure_of(state.adj[i], d);
        }
    }

    fn get_backward_action(&self, _prev: &BayesNetState, _idx: usize, fwd_action: i32) -> i32 {
        if fwd_action == self.stop_action() {
            0
        } else {
            fwd_action
        }
    }

    fn forward_action_of(&self, state: &BayesNetState, idx: usize, bwd_action: i32) -> i32 {
        if state.terminal[idx] {
            self.stop_action()
        } else {
            bwd_action
        }
    }

    fn fwd_mask_into(&self, state: &BayesNetState, idx: usize, out: &mut [bool]) {
        let d = self.d;
        let adj = state.adj[idx];
        let reach = state.reach[idx];
        for u in 0..d {
            for v in 0..d {
                // Legal: no self-loop, edge absent, no path v ⇝ u.
                out[u * d + v] =
                    u != v && adj & bit(d, u, v) == 0 && reach & bit(d, v, u) == 0;
            }
        }
        out[d * d] = true; // stop always legal
    }

    fn bwd_mask_into(&self, state: &BayesNetState, idx: usize, out: &mut [bool]) {
        let d = self.d;
        if state.terminal[idx] {
            out.iter_mut().for_each(|m| *m = false);
            out[0] = true; // deterministic undo-stop
            return;
        }
        let adj = state.adj[idx];
        for u in 0..d {
            for v in 0..d {
                out[u * d + v] = adj & bit(d, u, v) != 0;
            }
        }
    }

    fn obs_into(&self, state: &BayesNetState, idx: usize, out: &mut [f32]) {
        let d = self.d;
        let adj = state.adj[idx];
        for u in 0..d {
            for v in 0..d {
                out[u * d + v] = if adj & bit(d, u, v) != 0 { 1.0 } else { 0.0 };
            }
        }
    }

    fn is_terminal(&self, state: &BayesNetState, idx: usize) -> bool {
        state.terminal[idx]
    }

    fn is_initial(&self, state: &BayesNetState, idx: usize) -> bool {
        !state.terminal[idx] && state.adj[idx] == 0
    }

    fn extract(&self, state: &BayesNetState, idx: usize) -> u64 {
        debug_assert!(state.terminal[idx]);
        state.adj[idx]
    }

    fn inject_terminal(&self, objs: &[u64]) -> BayesNetState {
        let n = objs.len();
        BayesNetState {
            adj: objs.to_vec(),
            reach: objs.iter().map(|&a| closure_of(a, self.d)).collect(),
            terminal: vec![true; n],
            d: self.d,
        }
    }

    fn log_reward_obj(&self, obj: &u64) -> f64 {
        self.reward.log_reward(obj)
    }
}

/// Check a bitmask adjacency is acyclic by brute force (tests/enumeration).
pub fn is_acyclic(adj: u64, d: usize) -> bool {
    // Kahn's algorithm over the tiny graph.
    let mut indeg = [0usize; 8];
    for u in 0..d {
        for v in 0..d {
            if adj & bit(d, u, v) != 0 {
                indeg[v] += 1;
            }
        }
    }
    let mut queue: Vec<usize> = (0..d).filter(|&v| indeg[v] == 0).collect();
    let mut seen = 0;
    while let Some(u) = queue.pop() {
        seen += 1;
        for v in 0..d {
            if adj & bit(d, u, v) != 0 {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
    }
    seen == d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::testkit;
    use crate::testing::forall;

    /// Edge-count reward for structural tests.
    struct EdgeCountReward;
    impl RewardModule<u64> for EdgeCountReward {
        fn log_reward(&self, obj: &u64) -> f64 {
            -(obj.count_ones() as f64) * 0.1
        }
    }

    fn env(d: usize) -> BayesNetEnv<EdgeCountReward> {
        BayesNetEnv::new(d, EdgeCountReward)
    }

    #[test]
    fn spec_d5() {
        let s = env(5).spec();
        assert_eq!(s.n_actions, 26);
        assert_eq!(s.n_bwd_actions, 25);
        assert_eq!(s.obs_dim, 25);
        assert_eq!(s.t_max, 11);
    }

    #[test]
    fn cycle_masking() {
        let e = env(3);
        let mut st = e.reset(1);
        // Add 0→1, 1→2.
        e.step(&mut st, &[1]); // 0*3+1
        e.step(&mut st, &[5]); // 1*3+2
        let mut mask = vec![false; 10];
        e.fwd_mask_into(&st, 0, &mut mask);
        assert!(!mask[3 * 2 + 0], "2→0 would close a cycle");
        assert!(!mask[1 * 3 + 0], "1→0 would close a cycle");
        assert!(mask[0 * 3 + 2], "0→2 remains legal");
        assert!(mask[9], "stop legal");
    }

    #[test]
    fn closure_matches_bruteforce() {
        forall("closure vs floyd-warshall", 100, |rng| {
            let d = 4 + rng.below(3); // 4..6
            let e = env(d);
            let mut st = e.reset(1);
            let mut mask = vec![false; d * d + 1];
            // Random legal construction.
            for _ in 0..rng.below(d * (d - 1) / 2 + 1) {
                e.fwd_mask_into(&st, 0, &mut mask);
                // Choose a random legal non-stop action if any.
                let legal: Vec<usize> =
                    (0..d * d).filter(|&a| mask[a]).collect();
                if legal.is_empty() {
                    break;
                }
                let a = legal[rng.below(legal.len())];
                e.step(&mut st, &[a as i32]);
                // Incremental closure must equal recomputed closure.
                assert_eq!(
                    st.reach[0],
                    closure_of(st.adj[0], d),
                    "incremental closure diverged"
                );
                assert!(is_acyclic(st.adj[0], d), "produced a cyclic graph");
            }
        });
    }

    #[test]
    fn every_state_can_stop() {
        let e = env(4);
        let mut st = e.reset(1);
        let out = e.step(&mut st, &[e.stop_action()]);
        assert!(out.done[0]);
        assert!(e.is_terminal(&st, 0));
        assert_eq!(e.extract(&st, 0), 0); // empty DAG is a valid object
    }

    #[test]
    fn invariants() {
        let e = env(5);
        testkit::check_forward_backward_inversion(&e, 8, 81);
        testkit::check_masks_and_obs(&e, 8, 82);
        testkit::check_inject_extract_roundtrip(&e, 8, 83);
        testkit::check_backward_rollout_reaches_s0(&e, 8, 84);
    }

    #[test]
    fn reset_row_matches_fresh() {
        testkit::check_reset_row(&env(4), 8, 85);
        // Refill must restore the identity reachability, not just clear adj.
        let e = env(3);
        let mut st = e.reset(1);
        e.step(&mut st, &[1]); // 0→1
        e.step(&mut st, &[5]); // 1→2
        e.reset_row(&mut st, 0);
        let fresh = e.reset(1);
        assert_eq!(st.adj[0], fresh.adj[0]);
        assert_eq!(st.reach[0], fresh.reach[0]);
        assert!(e.is_initial(&st, 0));
    }

    #[test]
    fn parents_of_reads_columns() {
        let d = 4;
        let mut adj = 0u64;
        adj |= bit(d, 0, 2);
        adj |= bit(d, 3, 2);
        let pa = BayesNetEnv::<EdgeCountReward>::parents_of(adj, d, 2);
        assert_eq!(pa, (1 << 0) | (1 << 3));
    }
}
