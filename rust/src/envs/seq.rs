//! Shared sequence-generation machinery (gfnx appendix B.2).
//!
//! One vectorized environment covering the four generation schemes the paper
//! catalogues; the concrete benchmark envs (TFBind8, QM9, AMP, bit
//! sequences) are thin wrappers choosing a scheme + reward module:
//!
//! - [`SeqScheme::AutoregFixed`] — left-to-right, fixed length, no stop
//!   (TFBind8). Backward is degenerate (remove last).
//! - [`SeqScheme::AutoregVar`] — left-to-right with a stop action, variable
//!   length (AMP). Backward is degenerate.
//! - [`SeqScheme::PrependAppend`] — grow at either end to a fixed length
//!   (QM9): actions `[0, m)` prepend, `[m, 2m)` append; backward chooses
//!   remove-first / remove-last.
//! - [`SeqScheme::NonAutoreg`] — fixed length, pick (position, symbol) to
//!   fill an empty slot (bit sequences): action `p·m + v`; backward chooses
//!   which position to clear.

use super::{EnvSpec, StepOut, VecEnv};
use crate::reward::RewardModule;

/// Empty-token marker inside `SeqState::tokens`.
pub const EMPTY: i16 = -1;

/// Sequence generation scheme (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqScheme {
    AutoregFixed,
    AutoregVar,
    PrependAppend,
    NonAutoreg,
}

/// Batched sequence state: row-major `[n, max_len]` tokens (autoregressive
/// and prepend/append rows are left-aligned), fill counts, terminal flags.
#[derive(Clone, Debug, PartialEq)]
pub struct SeqState {
    pub tokens: Vec<i16>,
    pub len: Vec<u16>,
    pub terminal: Vec<bool>,
    pub max_len: usize,
}

impl SeqState {
    #[inline]
    pub fn row(&self, i: usize) -> &[i16] {
        &self.tokens[i * self.max_len..(i + 1) * self.max_len]
    }

    #[inline]
    fn row_mut(&mut self, i: usize) -> &mut [i16] {
        &mut self.tokens[i * self.max_len..(i + 1) * self.max_len]
    }
}

/// The generic sequence environment. `R` scores completed token vectors.
#[derive(Clone, Debug)]
pub struct SeqEnv<R> {
    pub scheme: SeqScheme,
    /// Vocabulary size m (symbols are `0..m`).
    pub vocab: usize,
    /// Maximum (or exact, for fixed-length schemes) sequence length.
    pub max_len: usize,
    /// Minimum length before stop becomes legal (AutoregVar only).
    pub min_len: usize,
    pub reward: R,
}

impl<R> SeqEnv<R> {
    pub fn new(scheme: SeqScheme, vocab: usize, max_len: usize, reward: R) -> Self {
        assert!(vocab >= 1 && max_len >= 1);
        SeqEnv { scheme, vocab, max_len, min_len: 1, reward }
    }

    /// Stop action index (AutoregVar only): the last action.
    #[inline]
    pub fn stop_action(&self) -> i32 {
        debug_assert_eq!(self.scheme, SeqScheme::AutoregVar);
        self.vocab as i32
    }
}

impl<R: RewardModule<Vec<i16>>> VecEnv for SeqEnv<R> {
    type State = SeqState;
    type Obj = Vec<i16>;

    fn spec(&self) -> EnvSpec {
        let (n_actions, n_bwd, t_max) = match self.scheme {
            SeqScheme::AutoregFixed => (self.vocab, 1, self.max_len),
            SeqScheme::AutoregVar => (self.vocab + 1, 1, self.max_len + 1),
            SeqScheme::PrependAppend => (2 * self.vocab, 2, self.max_len),
            SeqScheme::NonAutoreg => (self.max_len * self.vocab, self.max_len, self.max_len),
        };
        EnvSpec {
            // One-hot per position over vocab + empty class.
            obs_dim: self.max_len * (self.vocab + 1),
            n_actions,
            n_bwd_actions: n_bwd,
            t_max,
            token_shape: Some((self.max_len, self.vocab + 1)),
        }
    }

    fn reset(&self, n: usize) -> SeqState {
        SeqState {
            tokens: vec![EMPTY; n * self.max_len],
            len: vec![0; n],
            terminal: vec![false; n],
            max_len: self.max_len,
        }
    }

    fn reset_row(&self, state: &mut SeqState, idx: usize) {
        state.row_mut(idx).iter_mut().for_each(|t| *t = EMPTY);
        state.len[idx] = 0;
        state.terminal[idx] = false;
    }

    fn batch_len(&self, state: &SeqState) -> usize {
        state.terminal.len()
    }

    fn step(&self, state: &mut SeqState, actions: &[i32]) -> StepOut {
        let n = state.terminal.len();
        debug_assert_eq!(actions.len(), n);
        let mut out = StepOut::new(n);
        for i in 0..n {
            if state.terminal[i] || actions[i] < 0 {
                out.done[i] = state.terminal[i];
                continue;
            }
            let a = actions[i] as usize;
            let len = state.len[i] as usize;
            let max_len = self.max_len;
            match self.scheme {
                SeqScheme::AutoregFixed => {
                    debug_assert!(a < self.vocab && len < max_len);
                    state.row_mut(i)[len] = a as i16;
                    state.len[i] += 1;
                    if len + 1 == max_len {
                        state.terminal[i] = true;
                    }
                }
                SeqScheme::AutoregVar => {
                    if a == self.vocab {
                        debug_assert!(len >= self.min_len, "stop before min_len");
                        state.terminal[i] = true;
                    } else {
                        debug_assert!(len < max_len);
                        state.row_mut(i)[len] = a as i16;
                        state.len[i] += 1;
                    }
                }
                SeqScheme::PrependAppend => {
                    debug_assert!(len < max_len);
                    if a < self.vocab {
                        // Prepend: shift right by one, insert at 0.
                        let row = state.row_mut(i);
                        for j in (0..len).rev() {
                            row[j + 1] = row[j];
                        }
                        row[0] = a as i16;
                    } else {
                        state.row_mut(i)[len] = (a - self.vocab) as i16;
                    }
                    state.len[i] += 1;
                    if len + 1 == max_len {
                        state.terminal[i] = true;
                    }
                }
                SeqScheme::NonAutoreg => {
                    let p = a / self.vocab;
                    let v = a % self.vocab;
                    debug_assert!(p < max_len);
                    debug_assert_eq!(state.row(i)[p], EMPTY, "position already filled");
                    state.row_mut(i)[p] = v as i16;
                    state.len[i] += 1;
                    if len + 1 == max_len {
                        state.terminal[i] = true;
                    }
                }
            }
            if state.terminal[i] {
                out.done[i] = true;
                out.log_reward[i] = self.reward.log_reward(&self.extract(state, i));
            }
        }
        out
    }

    fn backward_step(&self, state: &mut SeqState, actions: &[i32]) {
        let n = state.terminal.len();
        debug_assert_eq!(actions.len(), n);
        for i in 0..n {
            if actions[i] < 0 {
                continue;
            }
            let len = state.len[i] as usize;
            match self.scheme {
                SeqScheme::AutoregFixed => {
                    // Terminal ⇔ len == max_len; removing the last token also
                    // clears terminality (no explicit stop transition).
                    debug_assert!(len > 0);
                    state.row_mut(i)[len - 1] = EMPTY;
                    state.len[i] -= 1;
                    state.terminal[i] = false;
                }
                SeqScheme::AutoregVar => {
                    if state.terminal[i] {
                        // Unique parent: undo stop.
                        state.terminal[i] = false;
                    } else {
                        debug_assert!(len > 0);
                        state.row_mut(i)[len - 1] = EMPTY;
                        state.len[i] -= 1;
                    }
                }
                SeqScheme::PrependAppend => {
                    debug_assert!(len > 0);
                    if actions[i] == 0 {
                        // Remove first: shift left.
                        let row = state.row_mut(i);
                        for j in 1..len {
                            row[j - 1] = row[j];
                        }
                        row[len - 1] = EMPTY;
                    } else {
                        state.row_mut(i)[len - 1] = EMPTY;
                    }
                    state.len[i] -= 1;
                    state.terminal[i] = false;
                }
                SeqScheme::NonAutoreg => {
                    let p = actions[i] as usize;
                    debug_assert!(state.row(i)[p] != EMPTY, "clearing empty position");
                    state.row_mut(i)[p] = EMPTY;
                    state.len[i] -= 1;
                    state.terminal[i] = false;
                }
            }
        }
    }

    fn get_backward_action(&self, _prev: &SeqState, _idx: usize, fwd_action: i32) -> i32 {
        match self.scheme {
            SeqScheme::AutoregFixed | SeqScheme::AutoregVar => 0,
            SeqScheme::PrependAppend => {
                if (fwd_action as usize) < self.vocab {
                    0 // prepend ↔ remove-first
                } else {
                    1 // append ↔ remove-last
                }
            }
            SeqScheme::NonAutoreg => fwd_action / self.vocab as i32,
        }
    }

    fn forward_action_of(&self, state: &SeqState, idx: usize, bwd_action: i32) -> i32 {
        let len = state.len[idx] as usize;
        match self.scheme {
            SeqScheme::AutoregFixed => state.row(idx)[len - 1] as i32,
            SeqScheme::AutoregVar => {
                if state.terminal[idx] {
                    self.stop_action()
                } else {
                    state.row(idx)[len - 1] as i32
                }
            }
            SeqScheme::PrependAppend => {
                if bwd_action == 0 {
                    state.row(idx)[0] as i32
                } else {
                    self.vocab as i32 + state.row(idx)[len - 1] as i32
                }
            }
            SeqScheme::NonAutoreg => {
                let p = bwd_action as usize;
                p as i32 * self.vocab as i32 + state.row(idx)[p] as i32
            }
        }
    }

    fn fwd_mask_into(&self, state: &SeqState, idx: usize, out: &mut [bool]) {
        let len = state.len[idx] as usize;
        match self.scheme {
            SeqScheme::AutoregFixed => {
                out.iter_mut().for_each(|m| *m = len < self.max_len);
            }
            SeqScheme::AutoregVar => {
                let can_append = len < self.max_len;
                out[..self.vocab].iter_mut().for_each(|m| *m = can_append);
                out[self.vocab] = len >= self.min_len;
            }
            SeqScheme::PrependAppend => {
                out.iter_mut().for_each(|m| *m = len < self.max_len);
            }
            SeqScheme::NonAutoreg => {
                let row = state.row(idx);
                for p in 0..self.max_len {
                    let empty = row[p] == EMPTY;
                    out[p * self.vocab..(p + 1) * self.vocab]
                        .iter_mut()
                        .for_each(|m| *m = empty);
                }
            }
        }
    }

    fn bwd_mask_into(&self, state: &SeqState, idx: usize, out: &mut [bool]) {
        match self.scheme {
            SeqScheme::AutoregFixed | SeqScheme::AutoregVar => {
                out[0] = true;
            }
            SeqScheme::PrependAppend => {
                let len = state.len[idx] as usize;
                out[0] = len > 0;
                out[1] = len > 0;
            }
            SeqScheme::NonAutoreg => {
                let row = state.row(idx);
                for p in 0..self.max_len {
                    out[p] = row[p] != EMPTY;
                }
            }
        }
    }

    fn obs_into(&self, state: &SeqState, idx: usize, out: &mut [f32]) {
        // Per position: one-hot over vocab symbols + trailing "empty" class.
        let w = self.vocab + 1;
        debug_assert_eq!(out.len(), self.max_len * w);
        out.iter_mut().for_each(|v| *v = 0.0);
        let row = state.row(idx);
        for p in 0..self.max_len {
            let t = row[p];
            let cls = if t == EMPTY { self.vocab } else { t as usize };
            out[p * w + cls] = 1.0;
        }
    }

    fn is_terminal(&self, state: &SeqState, idx: usize) -> bool {
        state.terminal[idx]
    }

    fn is_initial(&self, state: &SeqState, idx: usize) -> bool {
        !state.terminal[idx] && state.len[idx] == 0
    }

    fn extract(&self, state: &SeqState, idx: usize) -> Vec<i16> {
        match self.scheme {
            SeqScheme::AutoregVar => state.row(idx)[..state.len[idx] as usize].to_vec(),
            _ => state.row(idx).to_vec(),
        }
    }

    fn inject_terminal(&self, objs: &[Vec<i16>]) -> SeqState {
        let n = objs.len();
        let mut tokens = vec![EMPTY; n * self.max_len];
        let mut len = vec![0u16; n];
        for (i, o) in objs.iter().enumerate() {
            match self.scheme {
                SeqScheme::AutoregVar => {
                    assert!(o.len() <= self.max_len);
                    len[i] = o.len() as u16;
                }
                _ => {
                    assert_eq!(o.len(), self.max_len);
                    len[i] = o.iter().filter(|&&t| t != EMPTY).count() as u16;
                }
            }
            tokens[i * self.max_len..i * self.max_len + o.len()].copy_from_slice(o);
        }
        SeqState { tokens, len, terminal: vec![true; n], max_len: self.max_len }
    }

    fn log_reward_obj(&self, obj: &Vec<i16>) -> f64 {
        self.reward.log_reward(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::testkit;
    use crate::testing::forall;

    /// Toy reward: sum of tokens (finite for any completed sequence).
    struct SumReward;
    impl RewardModule<Vec<i16>> for SumReward {
        fn log_reward(&self, obj: &Vec<i16>) -> f64 {
            obj.iter().map(|&t| t.max(0) as f64).sum::<f64>() * 0.1
        }
    }

    fn env(scheme: SeqScheme, vocab: usize, max_len: usize) -> SeqEnv<SumReward> {
        SeqEnv::new(scheme, vocab, max_len, SumReward)
    }

    #[test]
    fn specs_per_scheme() {
        assert_eq!(env(SeqScheme::AutoregFixed, 4, 8).spec().n_actions, 4);
        assert_eq!(env(SeqScheme::AutoregVar, 20, 60).spec().n_actions, 21);
        assert_eq!(env(SeqScheme::PrependAppend, 11, 5).spec().n_actions, 22);
        assert_eq!(env(SeqScheme::NonAutoreg, 256, 15).spec().n_actions, 3840);
        assert_eq!(env(SeqScheme::NonAutoreg, 256, 15).spec().n_bwd_actions, 15);
    }

    #[test]
    fn autoreg_fixed_terminates_at_length() {
        let e = env(SeqScheme::AutoregFixed, 4, 3);
        let mut st = e.reset(1);
        e.step(&mut st, &[1]);
        e.step(&mut st, &[2]);
        assert!(!e.is_terminal(&st, 0));
        let out = e.step(&mut st, &[3]);
        assert!(out.done[0]);
        assert_eq!(e.extract(&st, 0), vec![1, 2, 3]);
    }

    #[test]
    fn autoreg_var_stop_and_minlen() {
        let e = env(SeqScheme::AutoregVar, 3, 5);
        let st = e.reset(1);
        let mut mask = vec![false; 4];
        e.fwd_mask_into(&st, 0, &mut mask);
        assert!(!mask[3], "stop must be illegal before min_len");
        let mut st = st;
        e.step(&mut st, &[2]);
        e.fwd_mask_into(&st, 0, &mut mask);
        assert!(mask[3]);
        e.step(&mut st, &[e.stop_action()]);
        assert!(e.is_terminal(&st, 0));
        assert_eq!(e.extract(&st, 0), vec![2]);
    }

    #[test]
    fn prepend_append_order() {
        let e = env(SeqScheme::PrependAppend, 5, 3);
        let mut st = e.reset(1);
        e.step(&mut st, &[5 + 2]); // append 2 -> [2]
        e.step(&mut st, &[1]); // prepend 1 -> [1, 2]
        e.step(&mut st, &[5 + 4]); // append 4 -> [1, 2, 4]
        assert!(e.is_terminal(&st, 0));
        assert_eq!(e.extract(&st, 0), vec![1, 2, 4]);
    }

    #[test]
    fn nonautoreg_fills_positions() {
        let e = env(SeqScheme::NonAutoreg, 2, 3);
        let mut st = e.reset(1);
        e.step(&mut st, &[1 * 2 + 1]); // pos1 = 1
        let mut mask = vec![false; 6];
        e.fwd_mask_into(&st, 0, &mut mask);
        assert_eq!(mask, vec![true, true, false, false, true, true]);
        e.step(&mut st, &[0]); // pos0 = 0
        e.step(&mut st, &[2 * 2 + 1]); // pos2 = 1
        assert!(e.is_terminal(&st, 0));
        assert_eq!(e.extract(&st, 0), vec![0, 1, 1]);
    }

    #[test]
    fn invariants_all_schemes() {
        for (scheme, vocab, max_len) in [
            (SeqScheme::AutoregFixed, 4, 6),
            (SeqScheme::AutoregVar, 5, 7),
            (SeqScheme::PrependAppend, 6, 5),
            (SeqScheme::NonAutoreg, 3, 5),
        ] {
            let e = env(scheme, vocab, max_len);
            testkit::check_forward_backward_inversion(&e, 8, 31);
            testkit::check_masks_and_obs(&e, 8, 32);
            testkit::check_inject_extract_roundtrip(&e, 8, 33);
            testkit::check_backward_rollout_reaches_s0(&e, 8, 34);
        }
    }

    #[test]
    fn reset_row_matches_fresh_all_schemes() {
        for (scheme, vocab, max_len) in [
            (SeqScheme::AutoregFixed, 4, 6),
            (SeqScheme::AutoregVar, 5, 7),
            (SeqScheme::PrependAppend, 6, 5),
            (SeqScheme::NonAutoreg, 3, 5),
        ] {
            let e = env(scheme, vocab, max_len);
            testkit::check_reset_row(&e, 8, 35);
        }
    }

    #[test]
    fn reset_row_leaves_neighbours_alone() {
        let e = env(SeqScheme::AutoregFixed, 4, 3);
        let mut st = e.reset(2);
        e.step(&mut st, &[1, 2]);
        e.reset_row(&mut st, 0);
        assert!(e.is_initial(&st, 0));
        assert_eq!(st.row(1)[0], 2);
        assert_eq!(st.len[1], 1);
    }

    #[test]
    fn property_random_walks_stay_valid() {
        forall("seq env random walks valid", 25, |rng| {
            let schemes = [
                SeqScheme::AutoregFixed,
                SeqScheme::AutoregVar,
                SeqScheme::PrependAppend,
                SeqScheme::NonAutoreg,
            ];
            let scheme = schemes[rng.below(4)];
            let vocab = 2 + rng.below(6);
            let max_len = 2 + rng.below(5);
            let e = env(scheme, vocab, max_len);
            let spec = e.spec();
            let mut st = e.reset(4);
            let mut mask = vec![false; spec.n_actions];
            for _ in 0..spec.t_max {
                let mut actions = vec![0i32; 4];
                for i in 0..4 {
                    if !e.is_terminal(&st, i) {
                        e.fwd_mask_into(&st, i, &mut mask);
                        actions[i] = rng.uniform_masked(&mask) as i32;
                    }
                }
                e.step(&mut st, &actions);
            }
            for i in 0..4 {
                // Fill counts consistent with tokens.
                let filled = st.row(i).iter().filter(|&&t| t != EMPTY).count();
                assert_eq!(filled, st.len[i] as usize);
            }
        });
    }
}
