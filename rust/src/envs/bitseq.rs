//! Bit-sequence environment (Malkin et al. 2022 / Tiapkin et al. 2024;
//! gfnx env #2): non-autoregressive generation of n-bit strings split into
//! k-bit tokens, with the mode-set Hamming reward.

use super::seq::{SeqEnv, SeqScheme};
use crate::data::modes::{bits_to_tokens, generate_modes};
use crate::reward::hamming::HammingReward;
use crate::util::rng::Rng;

/// Bit-sequence env: `SeqEnv` in non-autoregressive mode with vocab 2^k.
pub type BitSeqEnv = SeqEnv<HammingReward>;

/// Configuration for the bit-sequence benchmark.
#[derive(Clone, Copy, Debug)]
pub struct BitSeqConfig {
    /// Total bit length n (the paper benchmarks n = 120).
    pub n_bits: usize,
    /// Bits per token k (paper: k = 8). Must divide n.
    pub k: usize,
    /// Number of modes |M| (paper: 60).
    pub n_modes: usize,
    /// Reward exponent β (paper: 3).
    pub beta: f64,
    /// Mode-set seed.
    pub seed: u64,
}

impl BitSeqConfig {
    pub fn paper() -> Self {
        BitSeqConfig { n_bits: 120, k: 8, n_modes: 60, beta: 3.0, seed: 0 }
    }

    /// A small variant for tests/quick benches.
    pub fn small() -> Self {
        BitSeqConfig { n_bits: 24, k: 4, n_modes: 10, beta: 3.0, seed: 0 }
    }
}

/// Build the environment together with its (hidden) mode set.
pub fn bitseq_env(cfg: BitSeqConfig) -> (BitSeqEnv, Vec<Vec<u8>>) {
    assert!(cfg.n_bits % cfg.k == 0);
    let mut rng = Rng::new(cfg.seed);
    let modes = generate_modes(cfg.n_bits, cfg.n_modes, &mut rng);
    let reward = HammingReward::new(&modes, cfg.k, cfg.beta);
    let env = SeqEnv::new(
        SeqScheme::NonAutoreg,
        1usize << cfg.k,
        cfg.n_bits / cfg.k,
        reward,
    );
    (env, modes)
}

/// Convert test-set bit strings to token sequences for this config.
pub fn test_set_tokens(cfg: BitSeqConfig, test_bits: &[Vec<u8>]) -> Vec<Vec<i16>> {
    test_bits.iter().map(|b| bits_to_tokens(b, cfg.k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{testkit, VecEnv};

    #[test]
    fn paper_config_shapes() {
        let (env, modes) = bitseq_env(BitSeqConfig::paper());
        let spec = env.spec();
        assert_eq!(spec.n_actions, 15 * 256);
        assert_eq!(spec.n_bwd_actions, 15);
        assert_eq!(spec.obs_dim, 15 * 257);
        assert_eq!(spec.t_max, 15);
        assert_eq!(modes.len(), 60);
    }

    #[test]
    fn mode_sequences_get_max_reward() {
        let cfg = BitSeqConfig::small();
        let (env, modes) = bitseq_env(cfg);
        let tokens = bits_to_tokens(&modes[0], cfg.k);
        assert_eq!(env.log_reward_obj(&tokens), 0.0); // d = 0 ⇒ log R = 0
    }

    #[test]
    fn invariants() {
        let (env, _) = bitseq_env(BitSeqConfig::small());
        testkit::check_forward_backward_inversion(&env, 6, 41);
        testkit::check_masks_and_obs(&env, 6, 42);
        testkit::check_inject_extract_roundtrip(&env, 6, 43);
        testkit::check_backward_rollout_reaches_s0(&env, 6, 44);
    }

    #[test]
    fn reset_row_matches_fresh() {
        let (env, _) = bitseq_env(BitSeqConfig::small());
        testkit::check_reset_row(&env, 6, 45);
    }
}
