//! Phylogenetic tree generation environment (Zhou et al. 2024 PhyloGFN /
//! Deleu et al. 2024 setting; gfnx env #6).
//!
//! The state is a forest over `n` species: initially `n` singleton trees in
//! slots 0..n; each step merges the trees in two active slots under a new
//! common ancestor. After n−1 merges a single rooted binary tree remains
//! (terminal — no stop action). A merged tree is stored in the slot holding
//! the minimum leaf index of its union, which makes slot assignment a pure
//! function of the tree (needed for exact backward inversion).
//!
//! Forward actions enumerate unordered slot pairs (i<j); backward actions
//! pick the slot whose root merge is undone. Fitch state sets and mutation
//! counts are maintained incrementally per merge, giving the FLDB energy
//! E(s) = Σ_{roots} muts(root) for free.

use super::{EnvSpec, StepOut, VecEnv};
use crate::reward::parsimony::{Alignment, ParsimonyReward, PhyloTree};
use crate::reward::RewardModule;
use std::sync::Arc;

/// One arena node: a rooted (sub)tree with cached Fitch data.
#[derive(Clone, Debug, PartialEq)]
struct Node {
    left: Option<usize>,
    right: Option<usize>,
    leaf: Option<u16>,
    leaf_set: u64,
    /// Per-site Fitch state masks of this root.
    fitch: Vec<u8>,
    /// Total mutations in this subtree (Fitch count).
    muts: u32,
}

/// One environment instance: an arena of nodes plus slot → node mapping.
#[derive(Clone, Debug, PartialEq)]
pub struct Forest {
    nodes: Vec<Node>,
    /// `slots[i]` = arena index of the root living in slot i (None = empty).
    slots: Vec<Option<usize>>,
    n_active: usize,
}

/// Batched phylogenetic state.
#[derive(Clone, Debug, PartialEq)]
pub struct PhyloState {
    pub forests: Vec<Forest>,
}

/// The phylogenetics environment.
#[derive(Clone, Debug)]
pub struct PhyloEnv {
    pub n_species: usize,
    pub alignment: Arc<Alignment>,
    pub reward: ParsimonyReward,
}

impl PhyloEnv {
    pub fn new(alignment: Alignment, c: f64, alpha: f64) -> Self {
        let n = alignment.n_species();
        assert!(n >= 2 && n <= 64);
        let alignment = Arc::new(alignment);
        PhyloEnv {
            n_species: n,
            alignment: alignment.clone(),
            reward: ParsimonyReward {
                alignment: (*alignment).clone(),
                c,
                alpha,
            },
        }
    }

    /// Number of unordered slot pairs = forward action count.
    pub fn n_pairs(&self) -> usize {
        self.n_species * (self.n_species - 1) / 2
    }

    /// Map an unordered pair (i < j) to its action index.
    pub fn pair_to_action(&self, i: usize, j: usize) -> i32 {
        debug_assert!(i < j && j < self.n_species);
        let n = self.n_species;
        (i * n - i * (i + 1) / 2 + (j - i - 1)) as i32
    }

    /// Inverse of [`Self::pair_to_action`].
    pub fn action_to_pair(&self, a: i32) -> (usize, usize) {
        let n = self.n_species;
        let mut a = a as usize;
        for i in 0..n {
            let row = n - i - 1;
            if a < row {
                return (i, i + 1 + a);
            }
            a -= row;
        }
        panic!("action out of range");
    }

    fn leaf_node(&self, species: u16) -> Node {
        let aln = &self.alignment;
        Node {
            left: None,
            right: None,
            leaf: Some(species),
            leaf_set: 1u64 << species,
            fitch: (0..aln.n_sites)
                .map(|s| aln.leaf_mask(species as usize, s))
                .collect(),
            muts: 0,
        }
    }

    fn merge_nodes(&self, f: &mut Forest, a: usize, b: usize) -> usize {
        let (ma, mb) = (f.nodes[a].fitch.clone(), f.nodes[b].fitch.clone());
        let mut fitch = Vec::with_capacity(ma.len());
        let mut new_muts = 0u32;
        for s in 0..ma.len() {
            let inter = ma[s] & mb[s];
            if inter == 0 {
                fitch.push(ma[s] | mb[s]);
                new_muts += 1;
            } else {
                fitch.push(inter);
            }
        }
        let node = Node {
            left: Some(a),
            right: Some(b),
            leaf: None,
            leaf_set: f.nodes[a].leaf_set | f.nodes[b].leaf_set,
            fitch,
            muts: f.nodes[a].muts + f.nodes[b].muts + new_muts,
        };
        f.nodes.push(node);
        f.nodes.len() - 1
    }

    /// FLDB energy of env `idx`: total mutations across active roots
    /// (E(s₀) = 0; at terminal states E = M(x)).
    pub fn energy(&self, state: &PhyloState, idx: usize) -> f64 {
        let f = &state.forests[idx];
        f.slots
            .iter()
            .flatten()
            .map(|&ni| f.nodes[ni].muts as f64)
            .sum()
    }

    fn build_tree(&self, f: &Forest, ni: usize) -> PhyloTree {
        let n = &f.nodes[ni];
        match n.leaf {
            Some(l) => PhyloTree::Leaf(l),
            None => PhyloTree::node(
                self.build_tree(f, n.left.unwrap()),
                self.build_tree(f, n.right.unwrap()),
            ),
        }
    }

    /// A fresh forest of `n_species` singleton trees (the initial state of
    /// one environment instance; shared by `reset` and `reset_row`).
    fn fresh_forest(&self) -> Forest {
        Forest {
            slots: (0..self.n_species).map(Some).collect(),
            nodes: (0..self.n_species).map(|s| self.leaf_node(s as u16)).collect(),
            n_active: self.n_species,
        }
    }

    fn insert_tree(&self, f: &mut Forest, tree: &PhyloTree) -> usize {
        match tree {
            PhyloTree::Leaf(l) => {
                f.nodes.push(self.leaf_node(*l));
                f.nodes.len() - 1
            }
            PhyloTree::Node(a, b) => {
                let ia = self.insert_tree(f, a);
                let ib = self.insert_tree(f, b);
                self.merge_nodes(f, ia, ib)
            }
        }
    }
}

impl VecEnv for PhyloEnv {
    type State = PhyloState;
    type Obj = PhyloTree;

    fn spec(&self) -> EnvSpec {
        let m = self.alignment.n_sites;
        EnvSpec {
            // Per slot: active flag + 4 Fitch bits per site.
            obs_dim: self.n_species * (1 + 4 * m),
            n_actions: self.n_pairs(),
            n_bwd_actions: self.n_species,
            t_max: self.n_species - 1,
            token_shape: Some((self.n_species, 1 + 4 * m)),
        }
    }

    fn reset(&self, n: usize) -> PhyloState {
        PhyloState { forests: (0..n).map(|_| self.fresh_forest()).collect() }
    }

    fn reset_row(&self, state: &mut PhyloState, idx: usize) {
        state.forests[idx] = self.fresh_forest();
    }

    fn batch_len(&self, state: &PhyloState) -> usize {
        state.forests.len()
    }

    fn step(&self, state: &mut PhyloState, actions: &[i32]) -> StepOut {
        let n = state.forests.len();
        let mut out = StepOut::new(n);
        for i in 0..n {
            if state.forests[i].n_active == 1 || actions[i] < 0 {
                out.done[i] = state.forests[i].n_active == 1;
                continue;
            }
            let (si, sj) = self.action_to_pair(actions[i]);
            let f = &mut state.forests[i];
            let (a, b) = (
                f.slots[si].expect("merge from empty slot"),
                f.slots[sj].expect("merge from empty slot"),
            );
            let merged = self.merge_nodes(f, a, b);
            f.slots[si] = Some(merged);
            f.slots[sj] = None;
            f.n_active -= 1;
            if f.n_active == 1 {
                out.done[i] = true;
                let tree = self.build_tree(&state.forests[i], state.forests[i].slots[si].unwrap());
                out.log_reward[i] = self.reward.log_reward(&tree);
            }
        }
        out
    }

    fn backward_step(&self, state: &mut PhyloState, actions: &[i32]) {
        for (i, f) in state.forests.iter_mut().enumerate() {
            if actions[i] < 0 {
                continue;
            }
            let s = actions[i] as usize;
            let ni = f.slots[s].expect("split on empty slot");
            let node = f.nodes[ni].clone();
            let (l, r) = (
                node.left.expect("split on a leaf"),
                node.right.expect("split on a leaf"),
            );
            // Children return to their min-leaf slots.
            let sl = f.nodes[l].leaf_set.trailing_zeros() as usize;
            let sr = f.nodes[r].leaf_set.trailing_zeros() as usize;
            debug_assert!(sl == s || sr == s, "merged slot must be a child's min leaf");
            f.slots[sl] = Some(l);
            f.slots[sr] = Some(r);
            f.n_active += 1;
            // Free the node if it is the last allocated (keeps the arena
            // tight during backward rollouts).
            if ni == f.nodes.len() - 1 {
                f.nodes.pop();
            }
        }
    }

    fn get_backward_action(&self, prev: &PhyloState, idx: usize, fwd_action: i32) -> i32 {
        let (i, j) = self.action_to_pair(fwd_action);
        debug_assert!(prev.forests[idx].slots[i].is_some());
        i.min(j) as i32
    }

    fn forward_action_of(&self, state: &PhyloState, idx: usize, bwd_action: i32) -> i32 {
        let f = &state.forests[idx];
        let ni = f.slots[bwd_action as usize].expect("bwd action on empty slot");
        let node = &f.nodes[ni];
        let sl = f.nodes[node.left.unwrap()].leaf_set.trailing_zeros() as usize;
        let sr = f.nodes[node.right.unwrap()].leaf_set.trailing_zeros() as usize;
        self.pair_to_action(sl.min(sr), sl.max(sr))
    }

    fn fwd_mask_into(&self, state: &PhyloState, idx: usize, out: &mut [bool]) {
        let f = &state.forests[idx];
        for i in 0..self.n_species {
            for j in (i + 1)..self.n_species {
                out[self.pair_to_action(i, j) as usize] =
                    f.slots[i].is_some() && f.slots[j].is_some();
            }
        }
    }

    fn bwd_mask_into(&self, state: &PhyloState, idx: usize, out: &mut [bool]) {
        let f = &state.forests[idx];
        for s in 0..self.n_species {
            out[s] = f.slots[s]
                .map(|ni| f.nodes[ni].leaf.is_none())
                .unwrap_or(false);
        }
    }

    fn obs_into(&self, state: &PhyloState, idx: usize, out: &mut [f32]) {
        let m = self.alignment.n_sites;
        let w = 1 + 4 * m;
        out.iter_mut().for_each(|v| *v = 0.0);
        let f = &state.forests[idx];
        for s in 0..self.n_species {
            if let Some(ni) = f.slots[s] {
                let base = s * w;
                out[base] = 1.0;
                let fitch = &f.nodes[ni].fitch;
                for (site, &mask) in fitch.iter().enumerate() {
                    for b in 0..4 {
                        if mask & (1 << b) != 0 {
                            out[base + 1 + site * 4 + b] = 1.0;
                        }
                    }
                }
            }
        }
    }

    fn is_terminal(&self, state: &PhyloState, idx: usize) -> bool {
        state.forests[idx].n_active == 1
    }

    fn is_initial(&self, state: &PhyloState, idx: usize) -> bool {
        state.forests[idx].n_active == self.n_species
    }

    fn extract(&self, state: &PhyloState, idx: usize) -> PhyloTree {
        let f = &state.forests[idx];
        debug_assert_eq!(f.n_active, 1);
        let root = f.slots.iter().flatten().next().expect("no active root");
        self.build_tree(f, *root)
    }

    fn inject_terminal(&self, objs: &[PhyloTree]) -> PhyloState {
        let forests = objs
            .iter()
            .map(|tree| {
                assert_eq!(tree.leaf_count(), self.n_species);
                let mut f = Forest {
                    nodes: Vec::new(),
                    slots: vec![None; self.n_species],
                    n_active: 1,
                };
                let root = self.insert_tree(&mut f, tree);
                let slot = f.nodes[root].leaf_set.trailing_zeros() as usize;
                f.slots[slot] = Some(root);
                f
            })
            .collect();
        PhyloState { forests }
    }

    fn log_reward_obj(&self, obj: &PhyloTree) -> f64 {
        self.reward.log_reward(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::phylo_data::synthetic_alignment;
    use crate::envs::testkit;
    use crate::util::rng::Rng;

    fn env(n: usize, m: usize) -> PhyloEnv {
        let mut rng = Rng::new(7);
        let aln = synthetic_alignment(n, m, 0.15, &mut rng);
        PhyloEnv::new(aln, 2.0 * m as f64, 4.0)
    }

    #[test]
    fn pair_action_roundtrip() {
        let e = env(6, 4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..6 {
            for j in (i + 1)..6 {
                let a = e.pair_to_action(i, j);
                assert_eq!(e.action_to_pair(a), (i, j));
                seen.insert(a);
            }
        }
        assert_eq!(seen.len(), 15);
        assert_eq!(e.n_pairs(), 15);
    }

    #[test]
    fn trajectory_has_fixed_length() {
        let e = env(5, 4);
        let mut st = e.reset(1);
        let mut rng = Rng::new(0);
        let mut steps = 0;
        while !e.is_terminal(&st, 0) {
            let a = e.random_fwd_action(&st, 0, &mut rng);
            e.step(&mut st, &[a]);
            steps += 1;
        }
        assert_eq!(steps, 4); // n - 1
        let tree = e.extract(&st, 0);
        assert_eq!(tree.leaf_count(), 5);
    }

    #[test]
    fn energy_matches_final_parsimony() {
        use crate::reward::parsimony::parsimony_score;
        let e = env(6, 8);
        let mut st = e.reset(1);
        let mut rng = Rng::new(3);
        assert_eq!(e.energy(&st, 0), 0.0); // E(s0) = 0
        while !e.is_terminal(&st, 0) {
            let a = e.random_fwd_action(&st, 0, &mut rng);
            e.step(&mut st, &[a]);
        }
        let tree = e.extract(&st, 0);
        assert_eq!(
            e.energy(&st, 0),
            parsimony_score(&tree, &e.alignment) as f64,
            "incremental Fitch count must equal recursive Fitch"
        );
    }

    #[test]
    fn merged_slot_is_min_leaf() {
        let e = env(4, 4);
        let mut st = e.reset(1);
        // Merge slots 1 and 3 → goes to slot 1.
        e.step(&mut st, &[e.pair_to_action(1, 3)]);
        assert!(st.forests[0].slots[1].is_some());
        assert!(st.forests[0].slots[3].is_none());
        // Merge slots 0 and 1 → slot 0.
        e.step(&mut st, &[e.pair_to_action(0, 1)]);
        assert!(st.forests[0].slots[0].is_some());
        assert!(st.forests[0].slots[1].is_none());
    }

    #[test]
    fn invariants() {
        let e = env(6, 6);
        testkit::check_forward_backward_inversion(&e, 6, 91);
        testkit::check_masks_and_obs(&e, 6, 92);
        testkit::check_inject_extract_roundtrip(&e, 6, 93);
        testkit::check_backward_rollout_reaches_s0(&e, 6, 94);
    }

    #[test]
    fn reset_row_matches_fresh() {
        testkit::check_reset_row(&env(5, 4), 6, 95);
        // A refilled forest drops merged arena nodes entirely.
        let e = env(4, 4);
        let mut st = e.reset(1);
        e.step(&mut st, &[e.pair_to_action(0, 1)]);
        assert!(st.forests[0].nodes.len() > 4);
        e.reset_row(&mut st, 0);
        assert_eq!(st.forests[0].nodes.len(), 4);
        assert!(e.is_initial(&st, 0));
        assert_eq!(e.energy(&st, 0), 0.0);
    }
}
