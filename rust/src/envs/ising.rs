//! Ising model environment (Zhang et al. 2022; gfnx env #8): states are
//! partial spin assignments s ∈ {−1, +1, ∅}^D on an N×N toroidal lattice;
//! each step picks an unassigned site and sets its spin; after D steps the
//! configuration is complete (terminal — no stop action).
//!
//! Action layout: `site·2 + b` with b = 0 → spin −1, b = 1 → spin +1.
//! Backward actions: `site` (unassign), legal when assigned.

use super::{EnvSpec, StepOut, VecEnv};
use crate::reward::RewardModule;

/// Batched partial-assignment state. `spins` holds −1/0/+1 (0 = unassigned).
#[derive(Clone, Debug, PartialEq)]
pub struct IsingState {
    pub spins: Vec<i8>,
    pub n_assigned: Vec<u16>,
    pub d: usize,
}

impl IsingState {
    #[inline]
    pub fn row(&self, i: usize) -> &[i8] {
        &self.spins[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    fn row_mut(&mut self, i: usize) -> &mut [i8] {
        &mut self.spins[i * self.d..(i + 1) * self.d]
    }
}

/// The Ising environment; `R` scores full configurations.
#[derive(Clone)]
pub struct IsingEnv<R> {
    /// Number of sites D = N².
    pub d: usize,
    pub reward: R,
}

impl<R: RewardModule<Vec<i8>>> IsingEnv<R> {
    pub fn new(d: usize, reward: R) -> Self {
        IsingEnv { d, reward }
    }

    /// Convenience: N×N torus with D = N² sites.
    pub fn lattice(n: usize, reward: R) -> Self {
        Self::new(n * n, reward)
    }
}

impl<R: RewardModule<Vec<i8>>> VecEnv for IsingEnv<R> {
    type State = IsingState;
    type Obj = Vec<i8>;

    fn spec(&self) -> EnvSpec {
        EnvSpec {
            // Two channels: spin value and assigned mask.
            obs_dim: 2 * self.d,
            n_actions: 2 * self.d,
            n_bwd_actions: self.d,
            t_max: self.d,
            // Channel-major layout (all spins, then all masks) — not a
            // per-site token grid.
            token_shape: None,
        }
    }

    fn reset(&self, n: usize) -> IsingState {
        IsingState { spins: vec![0; n * self.d], n_assigned: vec![0; n], d: self.d }
    }

    fn reset_row(&self, state: &mut IsingState, idx: usize) {
        state.row_mut(idx).iter_mut().for_each(|s| *s = 0);
        state.n_assigned[idx] = 0;
    }

    fn batch_len(&self, state: &IsingState) -> usize {
        state.n_assigned.len()
    }

    fn step(&self, state: &mut IsingState, actions: &[i32]) -> StepOut {
        let n = state.n_assigned.len();
        let mut out = StepOut::new(n);
        for i in 0..n {
            if state.n_assigned[i] as usize == self.d || actions[i] < 0 {
                out.done[i] = state.n_assigned[i] as usize == self.d;
                continue;
            }
            let a = actions[i] as usize;
            let (site, b) = (a / 2, a % 2);
            debug_assert_eq!(state.row(i)[site], 0, "site already assigned");
            state.row_mut(i)[site] = if b == 0 { -1 } else { 1 };
            state.n_assigned[i] += 1;
            if state.n_assigned[i] as usize == self.d {
                out.done[i] = true;
                out.log_reward[i] = self.reward.log_reward(&state.row(i).to_vec());
            }
        }
        out
    }

    fn backward_step(&self, state: &mut IsingState, actions: &[i32]) {
        let n = state.n_assigned.len();
        for i in 0..n {
            if actions[i] < 0 {
                continue;
            }
            let site = actions[i] as usize;
            debug_assert!(state.row(i)[site] != 0, "unassigning empty site");
            state.row_mut(i)[site] = 0;
            state.n_assigned[i] -= 1;
        }
    }

    fn get_backward_action(&self, _prev: &IsingState, _idx: usize, fwd_action: i32) -> i32 {
        fwd_action / 2
    }

    fn forward_action_of(&self, state: &IsingState, idx: usize, bwd_action: i32) -> i32 {
        let site = bwd_action as usize;
        let spin = state.row(idx)[site];
        debug_assert!(spin != 0);
        (site * 2 + if spin > 0 { 1 } else { 0 }) as i32
    }

    fn fwd_mask_into(&self, state: &IsingState, idx: usize, out: &mut [bool]) {
        let row = state.row(idx);
        for site in 0..self.d {
            let empty = row[site] == 0;
            out[site * 2] = empty;
            out[site * 2 + 1] = empty;
        }
    }

    fn bwd_mask_into(&self, state: &IsingState, idx: usize, out: &mut [bool]) {
        let row = state.row(idx);
        for site in 0..self.d {
            out[site] = row[site] != 0;
        }
    }

    fn obs_into(&self, state: &IsingState, idx: usize, out: &mut [f32]) {
        let row = state.row(idx);
        for site in 0..self.d {
            out[site] = row[site] as f32;
            out[self.d + site] = if row[site] != 0 { 1.0 } else { 0.0 };
        }
    }

    fn is_terminal(&self, state: &IsingState, idx: usize) -> bool {
        state.n_assigned[idx] as usize == self.d
    }

    fn is_initial(&self, state: &IsingState, idx: usize) -> bool {
        state.n_assigned[idx] == 0
    }

    fn extract(&self, state: &IsingState, idx: usize) -> Vec<i8> {
        debug_assert!(self.is_terminal(state, idx));
        state.row(idx).to_vec()
    }

    fn inject_terminal(&self, objs: &[Vec<i8>]) -> IsingState {
        let n = objs.len();
        let mut spins = Vec::with_capacity(n * self.d);
        for o in objs {
            assert_eq!(o.len(), self.d);
            assert!(o.iter().all(|&s| s == 1 || s == -1));
            spins.extend_from_slice(o);
        }
        IsingState { spins, n_assigned: vec![self.d as u16; n], d: self.d }
    }

    fn log_reward_obj(&self, obj: &Vec<i8>) -> f64 {
        self.reward.log_reward(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::testkit;
    use crate::reward::ising::IsingReward;

    fn env(n: usize, sigma: f64) -> IsingEnv<IsingReward> {
        IsingEnv::lattice(n, IsingReward::torus(n, sigma))
    }

    #[test]
    fn spec_n9() {
        let s = env(9, 0.1).spec();
        assert_eq!(s.n_actions, 162);
        assert_eq!(s.n_bwd_actions, 81);
        assert_eq!(s.t_max, 81);
        assert_eq!(s.obs_dim, 162);
    }

    #[test]
    fn assignment_sequence() {
        let e = env(2, 0.5);
        let mut st = e.reset(1);
        e.step(&mut st, &[0 * 2 + 1]); // site0 = +1
        e.step(&mut st, &[3 * 2 + 0]); // site3 = -1
        assert_eq!(st.row(0), &[1, 0, 0, -1]);
        assert!(!e.is_terminal(&st, 0));
        e.step(&mut st, &[1 * 2 + 1]);
        let out = e.step(&mut st, &[2 * 2 + 1]);
        assert!(out.done[0]);
        assert!(out.log_reward[0].is_finite());
    }

    #[test]
    fn invariants() {
        let e = env(3, 0.2);
        testkit::check_forward_backward_inversion(&e, 6, 101);
        testkit::check_masks_and_obs(&e, 6, 102);
        testkit::check_inject_extract_roundtrip(&e, 6, 103);
        testkit::check_backward_rollout_reaches_s0(&e, 6, 104);
    }

    #[test]
    fn reset_row_matches_fresh() {
        testkit::check_reset_row(&env(2, 0.5), 6, 105);
        let e = env(2, 0.5);
        let mut st = e.reset(2);
        e.step(&mut st, &[1, 3]);
        e.reset_row(&mut st, 0);
        assert!(e.is_initial(&st, 0));
        assert_eq!(st.row(1), &[0, -1, 0, 0], "neighbour row must be untouched");
    }
}
