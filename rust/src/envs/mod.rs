//! Vectorized GFlowNet environments.
//!
//! Mirrors the reference gfnx design: environments are *stateless* — all
//! mutable data lives in a state struct returned by [`VecEnv::reset`] and
//! modified explicitly by [`VecEnv::step`] / [`VecEnv::backward_step`].
//! Rewards are decoupled from dynamics (see [`crate::reward`]), environments
//! emit **log-rewards** on terminal transitions and zero otherwise, and
//! backward transitions mirror forward ones closely enough that a backward
//! rollout is "replace initial states by terminal ones and `step` by
//! `backward_step`" (paper §2, Listing 2).
//!
//! Action conventions:
//! - Forward actions are `i32` indices in `[0, spec().n_actions)`.
//! - The sentinel [`NOOP`] (−1) leaves a row untouched in both `step` and
//!   `backward_step`; rollout code uses it for rows that already finished.
//! - Backward actions are indices in `[0, spec().n_bwd_actions)`; where a
//!   parent is unique the backward policy is degenerate and
//!   `n_bwd_actions == 1`.
//! - Environments with explicit termination expose the stop action as the
//!   **last** forward action (`spec().n_actions - 1`), as in gfnx.

pub mod hypergrid;
pub mod seq;
pub mod bitseq;
pub mod tfbind8;
pub mod qm9;
pub mod amp;
pub mod phylo;
pub mod bayesnet;
pub mod ising;

use crate::util::rng::Rng;

/// Sentinel action: leave this batch row untouched.
pub const NOOP: i32 = -1;

/// Static shape information about an environment family instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EnvSpec {
    /// Flattened observation length per environment instance.
    pub obs_dim: usize,
    /// Number of forward actions (including the stop action if any).
    pub n_actions: usize,
    /// Number of backward actions (1 when the parent is unique).
    pub n_bwd_actions: usize,
    /// Maximum trajectory length (number of forward transitions, including
    /// the stop transition if any). Rollout buffers are padded to this.
    pub t_max: usize,
}

/// Result of stepping a batch of environments.
#[derive(Clone, Debug, Default)]
pub struct StepOut {
    /// Per-env log-reward: the terminal log-reward for transitions that
    /// *became* terminal this step, 0.0 otherwise (paper convention).
    pub log_reward: Vec<f64>,
    /// Per-env terminal flag *after* this step.
    pub done: Vec<bool>,
}

impl StepOut {
    pub fn new(n: usize) -> Self {
        StepOut { log_reward: vec![0.0; n], done: vec![false; n] }
    }
}

/// A vectorized, stateless GFlowNet environment.
///
/// `State` holds the batch of mutable env states; `Obj` is the type of a
/// completed (terminal) object, used to inject terminal states for backward
/// rollouts and by the metrics code.
pub trait VecEnv {
    type State;
    type Obj: Clone;

    /// Shape information (constant for a given env instance).
    fn spec(&self) -> EnvSpec;

    /// Fresh batch of `n` initial states.
    fn reset(&self, n: usize) -> Self::State;

    /// Reset row `idx` of an existing batch to the initial state, leaving
    /// every other row untouched. A refilled row must be indistinguishable
    /// from the corresponding row of a fresh [`VecEnv::reset`]: same
    /// observation encoding, same masks, `is_initial` true, `is_terminal`
    /// false. This is the primitive behind continuous-batching slot refill
    /// (see [`crate::serve`]).
    fn reset_row(&self, state: &mut Self::State, idx: usize);

    /// Number of env instances in a state batch.
    fn batch_len(&self, state: &Self::State) -> usize;

    /// Apply forward `actions` (one per env). Envs that are already terminal
    /// are left untouched and report `done = true`, `log_reward = 0`.
    fn step(&self, state: &mut Self::State, actions: &[i32]) -> StepOut;

    /// Apply backward `actions`. Backward from a terminal state with an
    /// explicit stop transition first undoes the stop (unique parent); the
    /// provided action is then interpreted in the pre-stop state where the
    /// environment documents so.
    fn backward_step(&self, state: &mut Self::State, actions: &[i32]);

    /// The backward action that inverts `fwd_action` taken from `prev` —
    /// i.e. `backward_step(step(prev, a), get_backward_action(prev, a))`
    /// restores `prev` (paper Listing 2).
    fn get_backward_action(&self, prev: &Self::State, idx: usize, fwd_action: i32) -> i32;

    /// The forward action that the backward action `bwd_action` undoes from
    /// state `state` (used to score backward rollouts under `P_F`).
    fn forward_action_of(&self, state: &Self::State, idx: usize, bwd_action: i32) -> i32;

    /// Write the legal-forward-action mask of env `idx` into `out`
    /// (`out.len() == n_actions`).
    fn fwd_mask_into(&self, state: &Self::State, idx: usize, out: &mut [bool]);

    /// Write the legal-backward-action mask of env `idx` into `out`
    /// (`out.len() == n_bwd_actions`).
    fn bwd_mask_into(&self, state: &Self::State, idx: usize, out: &mut [bool]);

    /// Encode env `idx` into `out` (`out.len() == obs_dim`).
    fn obs_into(&self, state: &Self::State, idx: usize, out: &mut [f32]);

    /// Is env `idx` in a terminal state?
    fn is_terminal(&self, state: &Self::State, idx: usize) -> bool;

    /// Is env `idx` in the initial state (backward rollout finished)?
    fn is_initial(&self, state: &Self::State, idx: usize) -> bool;

    /// Extract the completed object of a terminal env.
    fn extract(&self, state: &Self::State, idx: usize) -> Self::Obj;

    /// Build a batch of *terminal* states from objects (for backward
    /// rollouts, P̂_θ estimation, and EB-GFN negative sampling).
    fn inject_terminal(&self, objs: &[Self::Obj]) -> Self::State;

    /// Log-reward of a completed object (delegates to the reward module).
    fn log_reward_obj(&self, obj: &Self::Obj) -> f64;

    /// Sample a uniformly random legal forward action for env `idx`
    /// (ε-uniform exploration helper).
    fn random_fwd_action(&self, state: &Self::State, idx: usize, rng: &mut Rng) -> i32 {
        let mut mask = vec![false; self.spec().n_actions];
        self.fwd_mask_into(state, idx, &mut mask);
        rng.uniform_masked(&mask) as i32
    }
}

/// Shared helper: number of legal actions in a mask (used for uniform P_B
/// log-probabilities and in tests).
pub fn mask_count(mask: &[bool]) -> usize {
    mask.iter().filter(|&&m| m).count()
}

#[cfg(test)]
pub(crate) mod testkit {
    //! Generic invariant checks run by every environment's test module.
    use super::*;

    /// Roll random legal forward actions until all terminal; at every step
    /// check mask consistency and forward/backward inversion via snapshots.
    pub fn check_forward_backward_inversion<E>(env: &E, n: usize, seed: u64)
    where
        E: VecEnv,
        E::State: Clone,
    {
        let mut rng = Rng::new(seed);
        let spec = env.spec();
        let mut state = env.reset(n);
        for i in 0..n {
            assert!(env.is_initial(&state, i), "reset not initial at {i}");
            assert!(!env.is_terminal(&state, i), "reset terminal at {i}");
        }
        let mut steps = 0usize;
        loop {
            let all_done = (0..n).all(|i| env.is_terminal(&state, i));
            if all_done {
                break;
            }
            assert!(steps <= spec.t_max, "trajectory exceeded t_max={}", spec.t_max);
            // Pick random legal actions (NOOP for terminal rows).
            let mut actions = vec![NOOP; n];
            for i in 0..n {
                if !env.is_terminal(&state, i) {
                    actions[i] = env.random_fwd_action(&state, i, &mut rng);
                }
            }
            let prev = state.clone();
            let out = env.step(&mut state, &actions);
            assert_eq!(out.done.len(), n);
            // Inversion: applying the matching backward action must restore
            // the previous state exactly.
            let mut undone = state.clone();
            let mut bwd = vec![NOOP; n];
            for i in 0..n {
                if !env.is_terminal(&prev, i) {
                    bwd[i] = env.get_backward_action(&prev, i, actions[i]);
                    let fwd_again = env.forward_action_of(&state, i, bwd[i]);
                    assert_eq!(
                        fwd_again, actions[i],
                        "forward_action_of does not invert get_backward_action at env {i}"
                    );
                }
            }
            env.backward_step(&mut undone, &bwd);
            for i in 0..n {
                if !env.is_terminal(&prev, i) {
                    // Compare via obs encoding + flags (state types may
                    // carry caches that are allowed to differ).
                    let mut a = vec![0f32; spec.obs_dim];
                    let mut b = vec![0f32; spec.obs_dim];
                    env.obs_into(&prev, i, &mut a);
                    env.obs_into(&undone, i, &mut b);
                    assert_eq!(a, b, "backward_step did not invert step at env {i}");
                    assert_eq!(
                        env.is_terminal(&prev, i),
                        env.is_terminal(&undone, i),
                        "terminal flag mismatch after inversion at env {i}"
                    );
                }
            }
            steps += 1;
        }
        // Terminal rewards are finite.
        for i in 0..n {
            let obj = env.extract(&state, i);
            let lr = env.log_reward_obj(&obj);
            assert!(lr.is_finite(), "non-finite log reward at env {i}");
        }
    }

    /// Masks must always admit at least one action for non-terminal states,
    /// and the obs encoding must have the declared length with finite values.
    pub fn check_masks_and_obs<E: VecEnv>(env: &E, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let spec = env.spec();
        let mut state = env.reset(n);
        let mut obs = vec![0f32; spec.obs_dim];
        let mut mask = vec![false; spec.n_actions];
        for _ in 0..spec.t_max {
            let mut actions = vec![NOOP; n];
            for i in 0..n {
                env.obs_into(&state, i, &mut obs);
                assert!(obs.iter().all(|v| v.is_finite()));
                if !env.is_terminal(&state, i) {
                    env.fwd_mask_into(&state, i, &mut mask);
                    assert!(
                        mask_count(&mask) > 0,
                        "non-terminal state with empty action mask"
                    );
                    actions[i] = rng.uniform_masked(&mask) as i32;
                }
            }
            env.step(&mut state, &actions);
            if (0..n).all(|i| env.is_terminal(&state, i)) {
                break;
            }
        }
    }

    /// inject_terminal(extract(s)) must be terminal, decode to the same
    /// object, and encode to the same observation.
    pub fn check_inject_extract_roundtrip<E>(env: &E, n: usize, seed: u64)
    where
        E: VecEnv,
        E::Obj: PartialEq + std::fmt::Debug,
    {
        let mut rng = Rng::new(seed);
        let mut state = env.reset(n);
        for _ in 0..env.spec().t_max + 1 {
            if (0..n).all(|i| env.is_terminal(&state, i)) {
                break;
            }
            let mut actions = vec![NOOP; n];
            for i in 0..n {
                if !env.is_terminal(&state, i) {
                    actions[i] = env.random_fwd_action(&state, i, &mut rng);
                }
            }
            env.step(&mut state, &actions);
        }
        let objs: Vec<E::Obj> = (0..n).map(|i| env.extract(&state, i)).collect();
        let injected = env.inject_terminal(&objs);
        for i in 0..n {
            assert!(env.is_terminal(&injected, i), "injected state not terminal");
            assert_eq!(env.extract(&injected, i), objs[i], "inject/extract mismatch");
            let mut a = vec![0f32; env.spec().obs_dim];
            let mut b = vec![0f32; env.spec().obs_dim];
            env.obs_into(&state, i, &mut a);
            env.obs_into(&injected, i, &mut b);
            assert_eq!(a, b, "injected obs mismatch at env {i}");
        }
    }

    /// [`VecEnv::reset_row`] must make a row indistinguishable from the same
    /// row of a fresh [`VecEnv::reset`] batch: drive rows an uneven number of
    /// steps (row `i` takes up to `i + 1`), refill every row, compare obs +
    /// masks + flags against a fresh batch, then roll the refilled batch to
    /// termination to prove it still functions.
    pub fn check_reset_row<E: VecEnv>(env: &E, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let spec = env.spec();
        let fresh = env.reset(n);
        let mut state = env.reset(n);
        for t in 0..spec.t_max {
            let mut actions = vec![NOOP; n];
            let mut any = false;
            for i in 0..n {
                if t < i + 1 && !env.is_terminal(&state, i) {
                    actions[i] = env.random_fwd_action(&state, i, &mut rng);
                    any = true;
                }
            }
            if !any {
                break;
            }
            env.step(&mut state, &actions);
        }
        for i in 0..n {
            env.reset_row(&mut state, i);
        }
        let mut obs_a = vec![0f32; spec.obs_dim];
        let mut obs_b = vec![0f32; spec.obs_dim];
        let mut fm_a = vec![false; spec.n_actions];
        let mut fm_b = vec![false; spec.n_actions];
        let mut bm_a = vec![false; spec.n_bwd_actions];
        let mut bm_b = vec![false; spec.n_bwd_actions];
        for i in 0..n {
            assert!(env.is_initial(&state, i), "refilled row {i} not initial");
            assert!(!env.is_terminal(&state, i), "refilled row {i} terminal");
            env.obs_into(&state, i, &mut obs_a);
            env.obs_into(&fresh, i, &mut obs_b);
            assert_eq!(obs_a, obs_b, "refilled obs differs from fresh at row {i}");
            env.fwd_mask_into(&state, i, &mut fm_a);
            env.fwd_mask_into(&fresh, i, &mut fm_b);
            assert_eq!(fm_a, fm_b, "refilled fwd mask differs at row {i}");
            env.bwd_mask_into(&state, i, &mut bm_a);
            env.bwd_mask_into(&fresh, i, &mut bm_b);
            assert_eq!(bm_a, bm_b, "refilled bwd mask differs at row {i}");
        }
        // The refilled batch must behave exactly like a fresh one.
        for _ in 0..spec.t_max + 1 {
            if (0..n).all(|i| env.is_terminal(&state, i)) {
                break;
            }
            let mut actions = vec![NOOP; n];
            for i in 0..n {
                if !env.is_terminal(&state, i) {
                    actions[i] = env.random_fwd_action(&state, i, &mut rng);
                }
            }
            env.step(&mut state, &actions);
        }
        for i in 0..n {
            assert!(env.is_terminal(&state, i), "refilled row {i} did not terminate");
            let lr = env.log_reward_obj(&env.extract(&state, i));
            assert!(lr.is_finite(), "refilled row {i} has non-finite reward");
        }
    }

    /// Backward rollout from terminal states reaches the initial state in at
    /// most t_max steps, with legal backward actions throughout.
    pub fn check_backward_rollout_reaches_s0<E>(env: &E, n: usize, seed: u64)
    where
        E: VecEnv,
    {
        let mut rng = Rng::new(seed);
        // Forward to terminal first.
        let mut state = env.reset(n);
        for _ in 0..env.spec().t_max + 1 {
            if (0..n).all(|i| env.is_terminal(&state, i)) {
                break;
            }
            let mut actions = vec![NOOP; n];
            for i in 0..n {
                if !env.is_terminal(&state, i) {
                    actions[i] = env.random_fwd_action(&state, i, &mut rng);
                }
            }
            env.step(&mut state, &actions);
        }
        // Now walk backward.
        let spec = env.spec();
        let mut bmask = vec![false; spec.n_bwd_actions];
        for _ in 0..2 * (spec.t_max + 1) {
            if (0..n).all(|i| env.is_initial(&state, i)) {
                break;
            }
            let mut actions = vec![NOOP; n];
            for i in 0..n {
                if !env.is_initial(&state, i) {
                    env.bwd_mask_into(&state, i, &mut bmask);
                    assert!(
                        mask_count(&bmask) > 0,
                        "non-initial state with empty backward mask"
                    );
                    actions[i] = rng.uniform_masked(&bmask) as i32;
                }
            }
            env.backward_step(&mut state, &actions);
        }
        for i in 0..n {
            assert!(
                env.is_initial(&state, i),
                "backward rollout did not reach s0 at env {i}"
            );
        }
    }
}
