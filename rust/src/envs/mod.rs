//! Vectorized GFlowNet environments.
//!
//! Mirrors the reference gfnx design: environments are *stateless* — all
//! mutable data lives in a state struct returned by [`VecEnv::reset`] and
//! modified explicitly by [`VecEnv::step`] / [`VecEnv::backward_step`].
//! Rewards are decoupled from dynamics (see [`crate::reward`]), environments
//! emit **log-rewards** on terminal transitions and zero otherwise, and
//! backward transitions mirror forward ones closely enough that a backward
//! rollout is "replace initial states by terminal ones and `step` by
//! `backward_step`" (paper §2, Listing 2).
//!
//! Action conventions:
//! - Forward actions are `i32` indices in `[0, spec().n_actions)`.
//! - The sentinel [`NOOP`] (−1) leaves a row untouched in both `step` and
//!   `backward_step`; rollout code uses it for rows that already finished.
//! - Backward actions are indices in `[0, spec().n_bwd_actions)`; where a
//!   parent is unique the backward policy is degenerate and
//!   `n_bwd_actions == 1`.
//! - Environments with explicit termination expose the stop action as the
//!   **last** forward action (`spec().n_actions - 1`), as in gfnx.

pub mod hypergrid;
pub mod seq;
pub mod bitseq;
pub mod tfbind8;
pub mod qm9;
pub mod amp;
pub mod phylo;
pub mod bayesnet;
pub mod ising;

use crate::util::rng::Rng;

/// Sentinel action: leave this batch row untouched.
pub const NOOP: i32 = -1;

/// Static shape information about an environment family instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EnvSpec {
    /// Flattened observation length per environment instance.
    pub obs_dim: usize,
    /// Number of forward actions (including the stop action if any).
    pub n_actions: usize,
    /// Number of backward actions (1 when the parent is unique).
    pub n_bwd_actions: usize,
    /// Maximum trajectory length (number of forward transitions, including
    /// the stop transition if any). Rollout buffers are padded to this.
    pub t_max: usize,
    /// The `[seq_len, token_dim]` grid the flat observation factors into,
    /// for envs whose observations are per-position feature blocks (one-hot
    /// tokens, per-slot descriptors). `None` for genuinely flat
    /// observations. Tokenizing policies (the native transformer) only bind
    /// to envs where this is `Some` and matches their architecture — see
    /// `runtime::policy::check_env_token_shape`.
    pub token_shape: Option<(usize, usize)>,
}

/// Result of stepping a batch of environments.
#[derive(Clone, Debug, Default)]
pub struct StepOut {
    /// Per-env log-reward: the terminal log-reward for transitions that
    /// *became* terminal this step, 0.0 otherwise (paper convention).
    pub log_reward: Vec<f64>,
    /// Per-env terminal flag *after* this step.
    pub done: Vec<bool>,
}

impl StepOut {
    pub fn new(n: usize) -> Self {
        StepOut { log_reward: vec![0.0; n], done: vec![false; n] }
    }
}

/// A vectorized, stateless GFlowNet environment.
///
/// `State` holds the batch of mutable env states; `Obj` is the type of a
/// completed (terminal) object, used to inject terminal states for backward
/// rollouts and by the metrics code.
pub trait VecEnv {
    type State;
    type Obj: Clone;

    /// Shape information (constant for a given env instance).
    fn spec(&self) -> EnvSpec;

    /// Fresh batch of `n` initial states.
    fn reset(&self, n: usize) -> Self::State;

    /// Reset row `idx` of an existing batch to the initial state, leaving
    /// every other row untouched. A refilled row must be indistinguishable
    /// from the corresponding row of a fresh [`VecEnv::reset`]: same
    /// observation encoding, same masks, `is_initial` true, `is_terminal`
    /// false. This is the primitive behind continuous-batching slot refill
    /// (see [`crate::serve`]).
    fn reset_row(&self, state: &mut Self::State, idx: usize);

    /// Number of env instances in a state batch.
    fn batch_len(&self, state: &Self::State) -> usize;

    /// Apply forward `actions` (one per env). Envs that are already terminal
    /// are left untouched and report `done = true`, `log_reward = 0`.
    fn step(&self, state: &mut Self::State, actions: &[i32]) -> StepOut;

    /// Apply backward `actions`. Backward from a terminal state with an
    /// explicit stop transition first undoes the stop (unique parent); the
    /// provided action is then interpreted in the pre-stop state where the
    /// environment documents so.
    fn backward_step(&self, state: &mut Self::State, actions: &[i32]);

    /// The backward action that inverts `fwd_action` taken from `prev` —
    /// i.e. `backward_step(step(prev, a), get_backward_action(prev, a))`
    /// restores `prev` (paper Listing 2).
    fn get_backward_action(&self, prev: &Self::State, idx: usize, fwd_action: i32) -> i32;

    /// The forward action that the backward action `bwd_action` undoes from
    /// state `state` (used to score backward rollouts under `P_F`).
    fn forward_action_of(&self, state: &Self::State, idx: usize, bwd_action: i32) -> i32;

    /// Write the legal-forward-action mask of env `idx` into `out`
    /// (`out.len() == n_actions`).
    fn fwd_mask_into(&self, state: &Self::State, idx: usize, out: &mut [bool]);

    /// Write the legal-backward-action mask of env `idx` into `out`
    /// (`out.len() == n_bwd_actions`).
    fn bwd_mask_into(&self, state: &Self::State, idx: usize, out: &mut [bool]);

    /// Encode env `idx` into `out` (`out.len() == obs_dim`).
    fn obs_into(&self, state: &Self::State, idx: usize, out: &mut [f32]);

    /// Is env `idx` in a terminal state?
    fn is_terminal(&self, state: &Self::State, idx: usize) -> bool;

    /// Is env `idx` in the initial state (backward rollout finished)?
    fn is_initial(&self, state: &Self::State, idx: usize) -> bool;

    /// Extract the completed object of a terminal env.
    fn extract(&self, state: &Self::State, idx: usize) -> Self::Obj;

    /// Build a batch of *terminal* states from objects (for backward
    /// rollouts, P̂_θ estimation, and EB-GFN negative sampling).
    fn inject_terminal(&self, objs: &[Self::Obj]) -> Self::State;

    /// Log-reward of a completed object (delegates to the reward module).
    fn log_reward_obj(&self, obj: &Self::Obj) -> f64;

    /// Sample a uniformly random legal forward action for env `idx`
    /// (ε-uniform exploration helper).
    fn random_fwd_action(&self, state: &Self::State, idx: usize, rng: &mut Rng) -> i32 {
        let mut mask = vec![false; self.spec().n_actions];
        self.fwd_mask_into(state, idx, &mut mask);
        rng.uniform_masked(&mask) as i32
    }
}

/// Shared helper: number of legal actions in a mask (used for uniform P_B
/// log-probabilities and in tests).
pub fn mask_count(mask: &[bool]) -> usize {
    mask.iter().filter(|&&m| m).count()
}

#[cfg(test)]
pub(crate) mod testkit {
    //! Test-local alias for the public [`VecEnv`] conformance harness in
    //! [`crate::testing`] — per-env unit tests call these by their old
    //! `testkit::` names; `tests/integration_envs.rs` runs the combined
    //! [`check_vec_env`](crate::testing::check_vec_env) suite over all
    //! nine environments.
    pub(crate) use crate::testing::{
        check_backward_rollout_reaches_s0, check_forward_backward_inversion,
        check_inject_extract_roundtrip, check_masks_and_obs, check_reset_row,
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_count_counts_true_entries() {
        assert_eq!(mask_count(&[]), 0);
        assert_eq!(mask_count(&[false, false]), 0);
        assert_eq!(mask_count(&[true, false, true, true]), 3);
    }

    #[test]
    fn step_out_initializes_per_env() {
        let out = StepOut::new(3);
        assert_eq!(out.log_reward, vec![0.0; 3]);
        assert_eq!(out.done, vec![false; 3]);
    }

    /// The default `random_fwd_action` samples only legal actions (it backs
    /// ε-exploration and every conformance walk).
    #[test]
    fn random_fwd_action_respects_masks() {
        use crate::envs::hypergrid::HypergridEnv;
        use crate::reward::hypergrid::HypergridReward;
        let e = HypergridEnv::new(2, 3, HypergridReward::standard(3));
        let mut rng = Rng::new(9);
        let mut state = e.reset(1);
        // Walk coord 0 to the edge: increments of dim 0 become illegal.
        e.step(&mut state, &[0]);
        e.step(&mut state, &[0]);
        let mut mask = vec![false; e.spec().n_actions];
        e.fwd_mask_into(&state, 0, &mut mask);
        for _ in 0..50 {
            let a = e.random_fwd_action(&state, 0, &mut rng);
            assert!(mask[a as usize], "sampled illegal action {a}");
        }
    }
}
