//! TFBind8 environment (Shen et al. 2023; gfnx env #3): autoregressive
//! generation of length-8 nucleotide sequences, scored by a (synthetic,
//! see DESIGN.md §3) DNA-binding landscape over all 4^8 sequences.

use super::seq::{SeqEnv, SeqScheme};
use crate::reward::proxy::TfBindReward;
use crate::util::stats::softmax_from_logs;

/// TFBind8 env: fixed-length autoregressive over vocab {A, C, G, T}.
pub type TfBind8Env = SeqEnv<TfBindReward>;

/// Build the TFBind8 environment with the synthetic landscape.
/// Paper hyperparameters use reward exponent β = 10.
pub fn tfbind8_env(seed: u64, beta: f64) -> TfBind8Env {
    SeqEnv::new(
        SeqScheme::AutoregFixed,
        TfBindReward::VOCAB,
        TfBindReward::LEN,
        TfBindReward::synthetic(seed, beta),
    )
}

/// Exact target distribution π(x) = R(x)/Z over all 65 536 sequences
/// (flattened index order). Used for the Fig. 4 TV metric.
pub fn exact_target(env: &TfBind8Env) -> Vec<f64> {
    let logs: Vec<f64> = (0..TfBindReward::SPACE)
        .map(|idx| {
            let seq = TfBindReward::unflatten(idx);
            use crate::reward::RewardModule;
            env.reward.log_reward(&seq)
        })
        .collect();
    softmax_from_logs(&logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::{testkit, VecEnv};

    #[test]
    fn spec_matches_paper() {
        let e = tfbind8_env(0, 10.0);
        let s = e.spec();
        assert_eq!(s.n_actions, 4);
        assert_eq!(s.n_bwd_actions, 1);
        assert_eq!(s.t_max, 8);
        assert_eq!(s.obs_dim, 8 * 5);
    }

    #[test]
    fn exact_target_is_distribution() {
        let e = tfbind8_env(0, 10.0);
        let p = exact_target(&e);
        assert_eq!(p.len(), 65_536);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn invariants() {
        let e = tfbind8_env(0, 10.0);
        testkit::check_forward_backward_inversion(&e, 8, 51);
        testkit::check_masks_and_obs(&e, 8, 52);
        testkit::check_inject_extract_roundtrip(&e, 8, 53);
        testkit::check_backward_rollout_reaches_s0(&e, 8, 54);
    }

    #[test]
    fn reset_row_matches_fresh() {
        testkit::check_reset_row(&tfbind8_env(0, 10.0), 8, 55);
    }
}
