//! Mini property-testing harness (the image has no `proptest`).
//!
//! Provides seeded random-input generation with failure-seed reporting so a
//! failing case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath set for normal targets)
//! use gfnx::testing::forall;
//! forall("sorted idempotent", 100, |rng| {
//!     let mut v: Vec<u32> = (0..rng.below(20)).map(|_| rng.next_u64() as u32).collect();
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```
//!
//! Each case runs with an independent RNG derived from a base seed. On
//! panic, the harness re-raises with the case index and seed embedded so the
//! exact input can be regenerated with [`case_rng`].

use crate::util::rng::Rng;

/// Base seed for all property tests; override with `GFNX_PROPTEST_SEED`.
pub fn base_seed() -> u64 {
    std::env::var("GFNX_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// The RNG used for case `i` of property `name`.
pub fn case_rng(name: &str, i: usize) -> Rng {
    // Mix the property name into the stream so different properties in the
    // same test binary explore different inputs.
    let mut h: u64 = 1469598103934665603; // FNV offset
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(1099511628211);
    }
    Rng::new(base_seed() ^ h ^ ((i as u64) << 32))
}

/// Run `prop` against `cases` independently seeded RNGs. Panics with a
/// replay message naming the failing case.
pub fn forall<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    for i in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = case_rng(name, i);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {i} (seed base {:#x}): {msg}\n\
                 replay: testing::case_rng(\"{name}\", {i})",
                base_seed()
            );
        }
    }
}

/// Generate a random f32 vector of length `n` in [lo, hi).
pub fn gen_vec_f32(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| lo + (hi - lo) * rng.uniform_f32()).collect()
}

/// Generate a random boolean mask of length `n` with at least one `true`.
pub fn gen_mask(rng: &mut Rng, n: usize) -> Vec<bool> {
    assert!(n > 0);
    let mut m: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.5)).collect();
    if !m.iter().any(|&b| b) {
        let i = rng.below(n);
        m[i] = true;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 xor self is zero", 50, |rng| {
            let x = rng.next_u64();
            assert_eq!(x ^ x, 0);
        });
    }

    #[test]
    fn forall_reports_failure_with_case() {
        let r = std::panic::catch_unwind(|| {
            forall("always fails", 3, |_rng| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("always fails"), "{msg}");
        assert!(msg.contains("case 0"), "{msg}");
    }

    #[test]
    fn case_rng_is_deterministic() {
        let mut a = case_rng("p", 3);
        let mut b = case_rng("p", 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = case_rng("p", 4);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_mask_never_empty() {
        forall("mask nonempty", 100, |rng| {
            let n = 1 + rng.below(16);
            let m = gen_mask(rng, n);
            assert!(m.iter().any(|&b| b));
        });
    }
}
