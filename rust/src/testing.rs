//! Mini property-testing harness (the image has no `proptest`) and the
//! [`check_vec_env`] conformance suite every [`VecEnv`] implementation must
//! pass (instantiated for all nine environments in
//! `tests/integration_envs.rs`; per-env unit tests reuse the same checks
//! through `envs::testkit`).
//!
//! The property harness provides seeded random-input generation with
//! failure-seed reporting so a failing case can be replayed
//! deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath set for normal targets)
//! use gfnx::testing::forall;
//! forall("sorted idempotent", 100, |rng| {
//!     let mut v: Vec<u32> = (0..rng.below(20)).map(|_| rng.next_u64() as u32).collect();
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```
//!
//! Each case runs with an independent RNG derived from a base seed. On
//! panic, the harness re-raises with the case index and seed embedded so the
//! exact input can be regenerated with [`case_rng`].

use crate::util::rng::Rng;

/// Base seed for all property tests; override with `GFNX_PROPTEST_SEED`.
pub fn base_seed() -> u64 {
    std::env::var("GFNX_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// The RNG used for case `i` of property `name`.
pub fn case_rng(name: &str, i: usize) -> Rng {
    // Mix the property name into the stream so different properties in the
    // same test binary explore different inputs.
    let mut h: u64 = 1469598103934665603; // FNV offset
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(1099511628211);
    }
    Rng::new(base_seed() ^ h ^ ((i as u64) << 32))
}

/// Run `prop` against `cases` independently seeded RNGs. Panics with a
/// replay message naming the failing case.
pub fn forall<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) + std::panic::RefUnwindSafe,
{
    for i in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = case_rng(name, i);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {i} (seed base {:#x}): {msg}\n\
                 replay: testing::case_rng(\"{name}\", {i})",
                base_seed()
            );
        }
    }
}

/// Generate a random f32 vector of length `n` in [lo, hi).
pub fn gen_vec_f32(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| lo + (hi - lo) * rng.uniform_f32()).collect()
}

// ---------------------------------------------------------------------------
// VecEnv conformance suite
// ---------------------------------------------------------------------------

use crate::coordinator::rollout::{
    backward_rollout_to_batch_with_policy, forward_rollout_with_policy, ExtraSource, RolloutCtx,
};
use crate::envs::{mask_count, VecEnv, NOOP};
use crate::runtime::policy::{PolicyShape, UniformPolicy};

/// The full [`VecEnv`] conformance suite: every invariant the rollout,
/// replay and serve layers rely on, checked with `n` parallel instances
/// from one base `seed`. Panics (with the failing env index) on violation.
///
/// Covers: EnvSpec shape agreement, step-mask consistency, exact
/// forward/backward inversion, reset/reset_row equivalence,
/// inject/extract round-trips, backward reachability of s0, the padded
/// `TrajBatch` sentinel conventions after termination (including zeroed
/// `extra` on skip/padded rows), and the forward→backward replay
/// round-trip through [`backward_rollout_to_batch_with_policy`].
pub fn check_vec_env<E>(env: &E, n: usize, seed: u64)
where
    E: VecEnv,
    E::State: Clone,
    E::Obj: PartialEq + std::fmt::Debug,
{
    check_spec_sanity(env);
    check_forward_backward_inversion(env, n, seed);
    check_masks_and_obs(env, n, seed.wrapping_add(1));
    check_inject_extract_roundtrip(env, n, seed.wrapping_add(2));
    check_backward_rollout_reaches_s0(env, n, seed.wrapping_add(3));
    check_reset_row(env, n, seed.wrapping_add(4));
    check_traj_padding_and_extras(env, n, seed.wrapping_add(5));
    check_backward_replay_roundtrip(env, n, seed.wrapping_add(6));
}

/// EnvSpec shape agreement: all dimensions positive and within the fixed
/// dispatch layout's assumptions.
pub fn check_spec_sanity<E: VecEnv>(env: &E) {
    let s = env.spec();
    assert!(s.obs_dim > 0, "obs_dim must be positive");
    assert!(s.n_actions > 0, "n_actions must be positive");
    assert!(s.n_bwd_actions > 0, "n_bwd_actions must be positive");
    assert!(s.t_max > 0, "t_max must be positive");
}

/// Roll random legal forward actions until all terminal; at every step
/// check mask consistency and forward/backward inversion via snapshots.
pub fn check_forward_backward_inversion<E>(env: &E, n: usize, seed: u64)
where
    E: VecEnv,
    E::State: Clone,
{
    let mut rng = Rng::new(seed);
    let spec = env.spec();
    let mut state = env.reset(n);
    for i in 0..n {
        assert!(env.is_initial(&state, i), "reset not initial at {i}");
        assert!(!env.is_terminal(&state, i), "reset terminal at {i}");
    }
    let mut steps = 0usize;
    loop {
        let all_done = (0..n).all(|i| env.is_terminal(&state, i));
        if all_done {
            break;
        }
        assert!(steps <= spec.t_max, "trajectory exceeded t_max={}", spec.t_max);
        // Pick random legal actions (NOOP for terminal rows).
        let mut actions = vec![NOOP; n];
        for i in 0..n {
            if !env.is_terminal(&state, i) {
                actions[i] = env.random_fwd_action(&state, i, &mut rng);
            }
        }
        let prev = state.clone();
        let out = env.step(&mut state, &actions);
        assert_eq!(out.done.len(), n);
        // Inversion: applying the matching backward action must restore
        // the previous state exactly.
        let mut undone = state.clone();
        let mut bwd = vec![NOOP; n];
        for i in 0..n {
            if !env.is_terminal(&prev, i) {
                bwd[i] = env.get_backward_action(&prev, i, actions[i]);
                let fwd_again = env.forward_action_of(&state, i, bwd[i]);
                assert_eq!(
                    fwd_again, actions[i],
                    "forward_action_of does not invert get_backward_action at env {i}"
                );
            }
        }
        env.backward_step(&mut undone, &bwd);
        for i in 0..n {
            if !env.is_terminal(&prev, i) {
                // Compare via obs encoding + flags (state types may
                // carry caches that are allowed to differ).
                let mut a = vec![0f32; spec.obs_dim];
                let mut b = vec![0f32; spec.obs_dim];
                env.obs_into(&prev, i, &mut a);
                env.obs_into(&undone, i, &mut b);
                assert_eq!(a, b, "backward_step did not invert step at env {i}");
                assert_eq!(
                    env.is_terminal(&prev, i),
                    env.is_terminal(&undone, i),
                    "terminal flag mismatch after inversion at env {i}"
                );
            }
        }
        steps += 1;
    }
    // Terminal rewards are finite.
    for i in 0..n {
        let obj = env.extract(&state, i);
        let lr = env.log_reward_obj(&obj);
        assert!(lr.is_finite(), "non-finite log reward at env {i}");
    }
}

/// Masks must always admit at least one action for non-terminal states,
/// and the obs encoding must have the declared length with finite values.
pub fn check_masks_and_obs<E: VecEnv>(env: &E, n: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let spec = env.spec();
    let mut state = env.reset(n);
    let mut obs = vec![0f32; spec.obs_dim];
    let mut mask = vec![false; spec.n_actions];
    for _ in 0..spec.t_max {
        let mut actions = vec![NOOP; n];
        for i in 0..n {
            env.obs_into(&state, i, &mut obs);
            assert!(obs.iter().all(|v| v.is_finite()));
            if !env.is_terminal(&state, i) {
                env.fwd_mask_into(&state, i, &mut mask);
                assert!(
                    mask_count(&mask) > 0,
                    "non-terminal state with empty action mask"
                );
                actions[i] = rng.uniform_masked(&mask) as i32;
            }
        }
        env.step(&mut state, &actions);
        if (0..n).all(|i| env.is_terminal(&state, i)) {
            break;
        }
    }
}

/// inject_terminal(extract(s)) must be terminal, decode to the same
/// object, and encode to the same observation.
pub fn check_inject_extract_roundtrip<E>(env: &E, n: usize, seed: u64)
where
    E: VecEnv,
    E::Obj: PartialEq + std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    let mut state = env.reset(n);
    for _ in 0..env.spec().t_max + 1 {
        if (0..n).all(|i| env.is_terminal(&state, i)) {
            break;
        }
        let mut actions = vec![NOOP; n];
        for i in 0..n {
            if !env.is_terminal(&state, i) {
                actions[i] = env.random_fwd_action(&state, i, &mut rng);
            }
        }
        env.step(&mut state, &actions);
    }
    let objs: Vec<E::Obj> = (0..n).map(|i| env.extract(&state, i)).collect();
    let injected = env.inject_terminal(&objs);
    for i in 0..n {
        assert!(env.is_terminal(&injected, i), "injected state not terminal");
        assert_eq!(env.extract(&injected, i), objs[i], "inject/extract mismatch");
        let mut a = vec![0f32; env.spec().obs_dim];
        let mut b = vec![0f32; env.spec().obs_dim];
        env.obs_into(&state, i, &mut a);
        env.obs_into(&injected, i, &mut b);
        assert_eq!(a, b, "injected obs mismatch at env {i}");
    }
}

/// [`VecEnv::reset_row`] must make a row indistinguishable from the same
/// row of a fresh [`VecEnv::reset`] batch: drive rows an uneven number of
/// steps (row `i` takes up to `i + 1`), refill every row, compare obs +
/// masks + flags against a fresh batch, then roll the refilled batch to
/// termination to prove it still functions.
pub fn check_reset_row<E: VecEnv>(env: &E, n: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let spec = env.spec();
    let fresh = env.reset(n);
    let mut state = env.reset(n);
    for t in 0..spec.t_max {
        let mut actions = vec![NOOP; n];
        let mut any = false;
        for i in 0..n {
            if t < i + 1 && !env.is_terminal(&state, i) {
                actions[i] = env.random_fwd_action(&state, i, &mut rng);
                any = true;
            }
        }
        if !any {
            break;
        }
        env.step(&mut state, &actions);
    }
    for i in 0..n {
        env.reset_row(&mut state, i);
    }
    let mut obs_a = vec![0f32; spec.obs_dim];
    let mut obs_b = vec![0f32; spec.obs_dim];
    let mut fm_a = vec![false; spec.n_actions];
    let mut fm_b = vec![false; spec.n_actions];
    let mut bm_a = vec![false; spec.n_bwd_actions];
    let mut bm_b = vec![false; spec.n_bwd_actions];
    for i in 0..n {
        assert!(env.is_initial(&state, i), "refilled row {i} not initial");
        assert!(!env.is_terminal(&state, i), "refilled row {i} terminal");
        env.obs_into(&state, i, &mut obs_a);
        env.obs_into(&fresh, i, &mut obs_b);
        assert_eq!(obs_a, obs_b, "refilled obs differs from fresh at row {i}");
        env.fwd_mask_into(&state, i, &mut fm_a);
        env.fwd_mask_into(&fresh, i, &mut fm_b);
        assert_eq!(fm_a, fm_b, "refilled fwd mask differs at row {i}");
        env.bwd_mask_into(&state, i, &mut bm_a);
        env.bwd_mask_into(&fresh, i, &mut bm_b);
        assert_eq!(bm_a, bm_b, "refilled bwd mask differs at row {i}");
    }
    // The refilled batch must behave exactly like a fresh one.
    for _ in 0..spec.t_max + 1 {
        if (0..n).all(|i| env.is_terminal(&state, i)) {
            break;
        }
        let mut actions = vec![NOOP; n];
        for i in 0..n {
            if !env.is_terminal(&state, i) {
                actions[i] = env.random_fwd_action(&state, i, &mut rng);
            }
        }
        env.step(&mut state, &actions);
    }
    for i in 0..n {
        assert!(env.is_terminal(&state, i), "refilled row {i} did not terminate");
        let lr = env.log_reward_obj(&env.extract(&state, i));
        assert!(lr.is_finite(), "refilled row {i} has non-finite reward");
    }
}

/// Backward rollout from terminal states reaches the initial state in at
/// most t_max steps, with legal backward actions throughout.
pub fn check_backward_rollout_reaches_s0<E>(env: &E, n: usize, seed: u64)
where
    E: VecEnv,
{
    let mut rng = Rng::new(seed);
    // Forward to terminal first.
    let mut state = env.reset(n);
    for _ in 0..env.spec().t_max + 1 {
        if (0..n).all(|i| env.is_terminal(&state, i)) {
            break;
        }
        let mut actions = vec![NOOP; n];
        for i in 0..n {
            if !env.is_terminal(&state, i) {
                actions[i] = env.random_fwd_action(&state, i, &mut rng);
            }
        }
        env.step(&mut state, &actions);
    }
    // Now walk backward.
    let spec = env.spec();
    let mut bmask = vec![false; spec.n_bwd_actions];
    for _ in 0..2 * (spec.t_max + 1) {
        if (0..n).all(|i| env.is_initial(&state, i)) {
            break;
        }
        let mut actions = vec![NOOP; n];
        for i in 0..n {
            if !env.is_initial(&state, i) {
                env.bwd_mask_into(&state, i, &mut bmask);
                assert!(
                    mask_count(&bmask) > 0,
                    "non-initial state with empty backward mask"
                );
                actions[i] = rng.uniform_masked(&bmask) as i32;
            }
        }
        env.backward_step(&mut state, &actions);
    }
    for i in 0..n {
        assert!(
            env.is_initial(&state, i),
            "backward rollout did not reach s0 at env {i}"
        );
    }
}

/// Forward-rollout a [`TrajBatch`](crate::coordinator::rollout::TrajBatch)
/// under the masked-uniform policy and check the padded-slot sentinel
/// conventions every loss relies on: single-legal fwd masks, nonempty bwd
/// masks, terminal-obs repetition — and that the `extra` channel stays
/// **zero** everywhere when no [`ExtraSource`] is given (the stale-staging
/// bug class: skip rows and padding slots must never carry leftover
/// values).
pub fn check_traj_padding_and_extras<E: VecEnv>(env: &E, n: usize, seed: u64) {
    let spec = env.spec();
    let shape = PolicyShape::of_env(env, n);
    let mut policy = UniformPolicy::new(shape);
    let mut ctx = RolloutCtx::for_shape(&shape);
    let mut rng = Rng::new(seed);
    let (batch, objs) = forward_rollout_with_policy(
        env, &mut policy, &mut ctx, &mut rng, 0.1, &ExtraSource::None,
    )
    .expect("forward rollout");
    assert_eq!(objs.len(), n);
    assert!(batch.extra.iter().all(|&x| x == 0.0), "extra must stay zero without a source");
    for i in 0..n {
        let len = batch.length[i] as usize;
        assert!(len >= 1 && len <= spec.t_max, "row {i}: length {len}");
        let want = env.log_reward_obj(&objs[i]) as f32;
        assert!(
            (batch.log_reward[i] - want).abs() < 1e-4,
            "row {i}: batch log_reward vs object"
        );
        for t in len..batch.t1 {
            let fm = &batch.fwd_masks
                [(i * batch.t1 + t) * spec.n_actions..(i * batch.t1 + t + 1) * spec.n_actions];
            assert_eq!(fm[0], 1.0, "row {i} slot {t}: fm[0] sentinel");
            assert_eq!(fm.iter().sum::<f32>(), 1.0, "row {i} slot {t}: single legal");
            let bm = &batch.bwd_masks[(i * batch.t1 + t) * spec.n_bwd_actions
                ..(i * batch.t1 + t + 1) * spec.n_bwd_actions];
            assert!(
                bm.iter().sum::<f32>() >= 1.0,
                "row {i} slot {t}: bwd mask must admit at least one action"
            );
            let o_t = &batch.obs
                [(i * batch.t1 + t) * spec.obs_dim..(i * batch.t1 + t + 1) * spec.obs_dim];
            let o_len = &batch.obs
                [(i * batch.t1 + len) * spec.obs_dim..(i * batch.t1 + len + 1) * spec.obs_dim];
            assert_eq!(o_t, o_len, "row {i} slot {t}: padded obs repeats terminal");
        }
    }
}

/// Forward→backward replay round-trip: walk forward to terminal objects,
/// assemble a backward-rollout batch from them, then replay the recorded
/// forward actions from s0 — every recorded observation, action legality,
/// fwd/bwd action pairing and the final object must match.
pub fn check_backward_replay_roundtrip<E>(env: &E, n: usize, seed: u64)
where
    E: VecEnv,
    E::Obj: PartialEq + std::fmt::Debug,
{
    let spec = env.spec();
    let shape = PolicyShape::of_env(env, n);
    let mut policy = UniformPolicy::new(shape);
    let mut ctx = RolloutCtx::for_shape(&shape);
    let mut rng = Rng::new(seed);
    // Terminal objects from a forward rollout.
    let (_fwd, objs) = forward_rollout_with_policy(
        env, &mut policy, &mut ctx, &mut rng, 0.0, &ExtraSource::None,
    )
    .expect("forward rollout");
    let (batch, _) = backward_rollout_to_batch_with_policy(
        env, &mut policy, &mut ctx, &mut rng, &objs, &ExtraSource::None,
    )
    .expect("backward rollout");
    let mut state = env.reset(n);
    let mut obs = vec![0f32; spec.obs_dim];
    let mut mask = vec![false; spec.n_actions];
    for t in 0..spec.t_max {
        for i in 0..n {
            let len = batch.length[i] as usize;
            if t > len {
                continue;
            }
            env.obs_into(&state, i, &mut obs);
            let slot = &batch.obs
                [(i * batch.t1 + t) * spec.obs_dim..(i * batch.t1 + t + 1) * spec.obs_dim];
            assert_eq!(obs.as_slice(), slot, "row {i} slot {t}: replayed obs");
        }
        let mut actions = vec![NOOP; n];
        let mut any = false;
        for i in 0..n {
            let len = batch.length[i] as usize;
            if t < len {
                let a = batch.fwd_actions[i * (batch.t1 - 1) + t];
                env.fwd_mask_into(&state, i, &mut mask);
                assert!(mask[a as usize], "row {i} slot {t}: recorded action illegal");
                assert_eq!(
                    batch.bwd_actions[i * (batch.t1 - 1) + t],
                    env.get_backward_action(&state, i, a),
                    "row {i} slot {t}: bwd/fwd action pairing"
                );
                actions[i] = a;
                any = true;
            }
        }
        if !any {
            break;
        }
        env.step(&mut state, &actions);
    }
    for i in 0..n {
        assert!(env.is_terminal(&state, i), "row {i}: replay must terminate");
        assert_eq!(env.extract(&state, i), objs[i], "row {i}: replay object");
        let want = env.log_reward_obj(&objs[i]) as f32;
        assert!(
            (batch.log_reward[i] - want).abs() < 1e-4,
            "row {i}: replayed log_reward"
        );
    }
}

/// Generate a random boolean mask of length `n` with at least one `true`.
pub fn gen_mask(rng: &mut Rng, n: usize) -> Vec<bool> {
    assert!(n > 0);
    let mut m: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.5)).collect();
    if !m.iter().any(|&b| b) {
        let i = rng.below(n);
        m[i] = true;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 xor self is zero", 50, |rng| {
            let x = rng.next_u64();
            assert_eq!(x ^ x, 0);
        });
    }

    #[test]
    fn forall_reports_failure_with_case() {
        let r = std::panic::catch_unwind(|| {
            forall("always fails", 3, |_rng| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("always fails"), "{msg}");
        assert!(msg.contains("case 0"), "{msg}");
    }

    #[test]
    fn case_rng_is_deterministic() {
        let mut a = case_rng("p", 3);
        let mut b = case_rng("p", 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = case_rng("p", 4);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_mask_never_empty() {
        forall("mask nonempty", 100, |rng| {
            let n = 1 + rng.below(16);
            let m = gen_mask(rng, n);
            assert!(m.iter().any(|&b| b));
        });
    }
}
