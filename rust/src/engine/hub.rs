//! The versioned policy hub: the single-writer, many-reader publication
//! point between the learner and its actors (and any serve hot-swap
//! subscribers).
//!
//! The learner publishes an owned policy snapshot under a monotonically
//! increasing version tag; actors poll [`PolicyHub::latest`] (one mutex
//! lock + `Arc` clone — O(1), no parameter copy) and re-clone the network
//! only when the version actually moved. The deterministic synchronous
//! mode rides on [`PolicyHub::wait_for_version`], a condvar rendezvous that
//! blocks an actor until the learner's publish catches up.

use std::sync::{Arc, Condvar, Mutex};

/// One published policy: parameters frozen at `steps` learner steps.
pub struct Snapshot<P> {
    /// Publish counter (0 = the pre-training initial snapshot).
    pub version: u64,
    /// Learner train steps taken when this snapshot was captured. Actors
    /// use it as the exploration-schedule position, so ε anneals by
    /// *training progress*, not by per-actor rollout counts.
    pub steps: u64,
    pub policy: P,
}

struct HubState<P> {
    snap: Arc<Snapshot<P>>,
    closed: bool,
}

/// The publication slot (see the module docs).
pub struct PolicyHub<P> {
    state: Mutex<HubState<P>>,
    cv: Condvar,
}

impl<P> PolicyHub<P> {
    /// A hub holding the initial snapshot (version 0, captured at `steps`
    /// learner steps — nonzero when resuming from a checkpoint).
    pub fn new(policy: P, steps: u64) -> PolicyHub<P> {
        PolicyHub {
            state: Mutex::new(HubState {
                snap: Arc::new(Snapshot { version: 0, steps, policy }),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Publish a new snapshot. `version` must be strictly greater than the
    /// current one (the learner is the only writer).
    pub fn publish(&self, snap: Arc<Snapshot<P>>) {
        let mut g = self.state.lock().unwrap();
        debug_assert!(snap.version > g.snap.version, "hub versions must increase");
        g.snap = snap;
        self.cv.notify_all();
    }

    /// The latest snapshot (cheap: lock + `Arc` clone).
    pub fn latest(&self) -> Arc<Snapshot<P>> {
        Arc::clone(&self.state.lock().unwrap().snap)
    }

    /// Current version without cloning the snapshot.
    pub fn version(&self) -> u64 {
        self.state.lock().unwrap().snap.version
    }

    /// Block until the published version reaches `version` (the sync-mode
    /// rendezvous). Returns `None` once the hub closes before (or while)
    /// waiting — the actor's shutdown signal.
    pub fn wait_for_version(&self, version: u64) -> Option<Arc<Snapshot<P>>> {
        let mut g = self.state.lock().unwrap();
        loop {
            if g.snap.version >= version {
                return Some(Arc::clone(&g.snap));
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Close the hub: wakes every waiter; `wait_for_version` returns
    /// `None` for unreached versions from now on.
    pub fn close(&self) {
        let mut g = self.state.lock().unwrap();
        g.closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latest_tracks_publishes() {
        let hub = PolicyHub::new(10u32, 0);
        assert_eq!(hub.version(), 0);
        assert_eq!(hub.latest().policy, 10);
        hub.publish(Arc::new(Snapshot { version: 1, steps: 5, policy: 20 }));
        let s = hub.latest();
        assert_eq!((s.version, s.steps, s.policy), (1, 5, 20));
    }

    #[test]
    fn wait_for_version_rendezvous() {
        let hub = Arc::new(PolicyHub::new(0u32, 0));
        // Already-reached versions return immediately.
        assert_eq!(hub.wait_for_version(0).unwrap().policy, 0);
        let h2 = Arc::clone(&hub);
        let t = std::thread::spawn(move || h2.wait_for_version(2).map(|s| s.policy));
        std::thread::sleep(std::time::Duration::from_millis(20));
        hub.publish(Arc::new(Snapshot { version: 1, steps: 1, policy: 1 }));
        hub.publish(Arc::new(Snapshot { version: 2, steps: 2, policy: 2 }));
        assert_eq!(t.join().unwrap(), Some(2));
    }

    #[test]
    fn close_releases_waiters() {
        let hub: Arc<PolicyHub<u32>> = Arc::new(PolicyHub::new(0, 0));
        let h2 = Arc::clone(&hub);
        let t = std::thread::spawn(move || h2.wait_for_version(99));
        std::thread::sleep(std::time::Duration::from_millis(20));
        hub.close();
        assert!(t.join().unwrap().is_none());
        // Reached versions still resolve after close.
        assert!(hub.wait_for_version(0).is_some());
    }
}
