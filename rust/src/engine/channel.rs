//! A std-only **bounded** MPSC channel with close semantics — the
//! actor → learner trajectory pipe.
//!
//! The serve queue ([`crate::serve::queue::Queue`]) is unbounded because a
//! service must absorb bursts; the engine wants the opposite: a bounded
//! channel is the engine's **backpressure**. Actors that outrun the
//! learner block in [`Bounded::push_blocking`] instead of piling up
//! batches sampled from ever-older policy versions, which keeps the
//! staleness of consumed batches near `queue_depth / publish_every + 1`
//! publishes (queue residency; a descheduled actor mid-rollout can add a
//! little more, which the learner's staleness histogram makes visible).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct State<T> {
    items: VecDeque<T>,
    cap: usize,
    closed: bool,
}

struct Inner<T> {
    state: Mutex<State<T>>,
    /// Signaled when space frees up (producers wait here).
    space: Condvar,
    /// Signaled when an item arrives or the channel closes (consumer waits
    /// here).
    items: Condvar,
}

/// A bounded multi-producer channel; clones share the same channel.
pub struct Bounded<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Bounded<T> {
    fn clone(&self) -> Self {
        Bounded { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Bounded<T> {
    /// A channel holding at most `cap` in-flight items (`cap` ≥ 1).
    pub fn new(cap: usize) -> Bounded<T> {
        assert!(cap >= 1, "bounded channel needs capacity ≥ 1");
        Bounded {
            inner: Arc::new(Inner {
                state: Mutex::new(State { items: VecDeque::new(), cap, closed: false }),
                space: Condvar::new(),
                items: Condvar::new(),
            }),
        }
    }

    /// Enqueue, blocking while the channel is full. Returns `false`
    /// (dropping the item) once the channel is closed — the producers'
    /// shutdown signal.
    pub fn push_blocking(&self, item: T) -> bool {
        let mut g = self.inner.state.lock().unwrap();
        loop {
            if g.closed {
                return false;
            }
            if g.items.len() < g.cap {
                g.items.push_back(item);
                self.inner.items.notify_one();
                return true;
            }
            g = self.inner.space.wait(g).unwrap();
        }
    }

    /// Dequeue, blocking until an item arrives or the channel is closed
    /// *and* drained (`None`).
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.inner.state.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.inner.space.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.inner.items.wait(g).unwrap();
        }
    }

    /// Close the channel: future pushes fail, blocked producers and the
    /// consumer wake immediately.
    pub fn close(&self) {
        let mut g = self.inner.state.lock().unwrap();
        g.closed = true;
        self.inner.space.notify_all();
        self.inner.items.notify_all();
    }

    /// Current backlog depth.
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn fifo_within_capacity() {
        let c = Bounded::new(4);
        for i in 0..4 {
            assert!(c.push_blocking(i));
        }
        assert_eq!(c.len(), 4);
        for i in 0..4 {
            assert_eq!(c.pop_blocking(), Some(i));
        }
    }

    #[test]
    fn push_blocks_at_capacity_until_pop() {
        let c = Bounded::new(1);
        assert!(c.push_blocking(0));
        let pushed = Arc::new(AtomicUsize::new(0));
        let (c2, p2) = (c.clone(), Arc::clone(&pushed));
        let t = std::thread::spawn(move || {
            assert!(c2.push_blocking(1));
            p2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(pushed.load(Ordering::SeqCst), 0, "push must block while full");
        assert_eq!(c.pop_blocking(), Some(0));
        t.join().unwrap();
        assert_eq!(pushed.load(Ordering::SeqCst), 1);
        assert_eq!(c.pop_blocking(), Some(1));
    }

    #[test]
    fn close_wakes_blocked_producer_and_consumer() {
        let c: Bounded<u32> = Bounded::new(1);
        assert!(c.push_blocking(7));
        let c2 = c.clone();
        let producer = std::thread::spawn(move || c2.push_blocking(8));
        let c3 = c.clone();
        let closer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            c3.close();
        });
        // The blocked producer must observe the close and give up.
        assert!(!producer.join().unwrap());
        closer.join().unwrap();
        // The backlog drains, then the consumer sees the end.
        assert_eq!(c.pop_blocking(), Some(7));
        assert_eq!(c.pop_blocking(), None);
        assert!(!c.push_blocking(9), "push after close must fail");
    }

    #[test]
    fn multi_producer_items_all_arrive() {
        let c: Bounded<usize> = Bounded::new(2);
        let producers: Vec<_> = (0..3)
            .map(|p| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for i in 0..50 {
                        assert!(c.push_blocking(p * 50 + i));
                    }
                })
            })
            .collect();
        let mut got = Vec::new();
        while got.len() < 150 {
            got.push(c.pop_blocking().unwrap());
        }
        for t in producers {
            t.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, (0..150).collect::<Vec<_>>());
    }
}
