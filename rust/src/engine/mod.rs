//! `engine` — asynchronous actor–learner training with versioned policy
//! snapshots.
//!
//! The serial [`Trainer`](crate::coordinator::trainer::Trainer) alternates
//! rollout and fused train step on one thread, so the optimizer idles while
//! trajectories are sampled and vice versa. This module splits the loop:
//!
//! - **N actor threads** each hold an owned policy snapshot
//!   ([`SnapshotBackend::Snapshot`], e.g. a
//!   [`NativePolicy`](crate::runtime::NativePolicy)) and assemble
//!   trajectory batches — on-policy forward rollouts plus, when replay is
//!   configured, backward rollouts from a **per-actor replay shard** — into
//!   a bounded MPSC channel ([`channel::Bounded`]).
//! - **One learner** (the calling thread) drains the channel, applies the
//!   fused `train_step`, and every `publish_every` steps publishes a
//!   version-tagged snapshot through the [`hub::PolicyHub`]. Actors pick it
//!   up before their next rollout; serve-side subscribers (the
//!   `SamplerService` hot-swap hook) get it through the `on_publish`
//!   callback.
//!
//! Actor batches trained between publishes were sampled from a *stale*
//! policy — exactly the off-policy data Shen et al. (2023) show trains
//! GFlowNets well; the channel's backpressure keeps staleness near
//! `queue_depth / publish_every + 1` publishes, and the learner accounts
//! for every consumed batch in a per-staleness histogram
//! ([`EngineStats::staleness_hist`]).
//!
//! With tracing on ([`telemetry::trace`](crate::telemetry::trace)), sampled
//! learner steps record an `engine_step` waterfall — rollout → push_wait →
//! pop_wait → learn → publish, annotated with actor/version/staleness —
//! and every step touches the `engine.learner_heartbeat_s` watchdog gauge.
//!
//! ## Determinism
//!
//! Async mode is nondeterministic by construction (thread interleaving
//! decides which actor's batch trains next). The **synchronous mode**
//! (`sync: true` ⇒ 1 actor, publish-every-step, condvar rendezvous) is
//! proven **bitwise-identical** to the serial `Trainer` from the same seed:
//! actor 0 seeds its RNG with the trainer seed, runs the *same*
//! [`assemble_batch_with_policy`] code path, and waits for publish `i`
//! before assembling batch `i` — reproducing the serial
//! rollout → step → rollout ordering exactly (asserted over 50+ steps in
//! the tests, params and loss trace compared bit-for-bit).

pub mod channel;
pub mod hub;

pub use hub::{PolicyHub, Snapshot};

use crate::coordinator::buffer::RingBuffer;
use crate::coordinator::explore::EpsSchedule;
use crate::coordinator::rollout::{ExtraSource, RolloutCtx, TrajBatch};
use crate::coordinator::trainer::{
    assemble_batch_with_policy, bank_top_half, IterStats, ReplayConfig,
};
use crate::envs::VecEnv;
use crate::runtime::backend::SnapshotBackend;
use crate::runtime::policy::BatchPolicy;
use crate::serve::traj_seed;
use crate::telemetry::trace::{self, TraceRecord, TraceSegment};
use channel::Bounded;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Engine topology and scheduling knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Actor (rollout) threads. ≥ 1.
    pub actors: usize,
    /// Learner steps between snapshot publishes (K). 1 = publish every
    /// step.
    pub publish_every: u64,
    /// Bounded channel depth (backpressure / staleness cap). 0 = the
    /// default `2 × actors`.
    pub queue_depth: usize,
    /// Deterministic synchronous mode: requires `actors == 1` and
    /// `publish_every == 1`; adds the rendezvous barrier that makes the
    /// run bitwise-identical to the serial `Trainer`.
    pub sync: bool,
    /// Base RNG seed. Actor 0 uses it verbatim (the sync-mode parity
    /// contract); actor k > 0 derives an independent stream.
    pub seed: u64,
    /// Per-actor replay shards (None = pure on-policy).
    pub replay: Option<ReplayConfig>,
    /// Write a checkpoint here on every publish (see
    /// [`SnapshotBackend::checkpoint`]). Each save serializes the full
    /// optimizer state on the learner's critical path, so with small K
    /// (sync mode is K = 1) this trades wall-clock for durability — raise
    /// `publish_every` or drop the checkpoint for throughput runs.
    pub checkpoint: Option<PathBuf>,
}

impl EngineConfig {
    /// An async engine with `actors` actors publishing every `publish_every`
    /// steps.
    pub fn new(actors: usize, publish_every: u64, seed: u64) -> EngineConfig {
        EngineConfig {
            actors,
            publish_every,
            queue_depth: 0,
            sync: false,
            seed,
            replay: None,
            checkpoint: None,
        }
    }

    /// The deterministic synchronous configuration (1 actor, K = 1,
    /// rendezvous).
    pub fn sync(seed: u64) -> EngineConfig {
        EngineConfig { sync: true, ..EngineConfig::new(1, 1, seed) }
    }

    pub fn with_replay(mut self, replay: ReplayConfig) -> EngineConfig {
        self.replay = Some(replay);
        self
    }

    pub fn with_checkpoint(mut self, path: PathBuf) -> EngineConfig {
        self.checkpoint = Some(path);
        self
    }

    fn effective_depth(&self) -> usize {
        if self.queue_depth > 0 {
            self.queue_depth
        } else {
            2 * self.actors.max(1)
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.actors >= 1, "engine needs at least one actor");
        anyhow::ensure!(self.publish_every >= 1, "publish_every must be ≥ 1");
        if self.sync {
            anyhow::ensure!(
                self.actors == 1 && self.publish_every == 1,
                "sync mode is defined as 1 actor + publish-every-step \
                 (got actors {}, publish_every {})",
                self.actors,
                self.publish_every
            );
        }
        if let Some(r) = &self.replay {
            anyhow::ensure!(r.cap > 0, "replay capacity must be positive");
            anyhow::ensure!(
                (0.0..=1.0).contains(&r.frac),
                "replay fraction {} outside [0, 1]",
                r.frac
            );
        }
        Ok(())
    }
}

/// One actor-produced trajectory batch, tagged for staleness accounting.
pub struct TaggedBatch<Obj> {
    pub batch: TrajBatch,
    /// Terminal objects of the batch (EB-GFN's CD phase consumes these).
    pub objs: Vec<Obj>,
    /// Hub version of the snapshot that sampled this batch.
    pub version: u64,
    /// Producing actor index.
    pub actor: usize,
    /// Whether this was a replay (backward-rollout) batch.
    pub replayed: bool,
    /// Actor-side assembly time of this batch (0 when tracing is off).
    pub rollout_ns: u64,
    /// Time the producing actor spent blocked pushing this batch
    /// (backpressure). The actor stores it *after* `push_blocking` returns,
    /// so a learner that pops the batch immediately may read 0 — a benign
    /// race; the value is best-effort trace annotation, never control flow.
    pub push_wait_ns: Arc<AtomicU64>,
}

/// What the engine needs from "the thing that learns": consume one tagged
/// batch, expose snapshots + the step counter, optionally checkpoint.
///
/// Two implementations ship in-tree: [`LossLearner`] (the standard fused
/// `train_step` over any [`SnapshotBackend`]) and
/// [`EbGfnLearner`](crate::coordinator::ebgfn::EbGfnLearner) (the
/// alternating EB-GFN update consuming actor batches as its forward
/// sample stream).
pub trait EngineLearner<E: VecEnv> {
    type Snap: BatchPolicy + Clone + Send + Sync + 'static;

    /// Snapshot the current policy (called once per publish).
    fn snapshot(&self) -> Self::Snap;

    /// Train steps taken so far (the exploration-schedule position carried
    /// by each published snapshot).
    fn steps(&self) -> u64;

    /// Consume one batch (may mutate it in place, e.g. MDB delta
    /// conversion).
    fn learn(&mut self, tagged: &mut TaggedBatch<E::Obj>) -> anyhow::Result<IterStats>;

    /// Persist the learner state (used by `EngineConfig::checkpoint`).
    fn checkpoint(&self, path: &std::path::Path) -> anyhow::Result<()>;
}

/// The standard engine learner: fused `Backend::train_step` over a
/// [`SnapshotBackend`], with the same MDB delta conversion the serial
/// `Trainer` applies.
pub struct LossLearner<'a, B: SnapshotBackend> {
    pub backend: &'a mut B,
    mdb_deltas: bool,
}

impl<'a, B: SnapshotBackend> LossLearner<'a, B> {
    pub fn new(backend: &'a mut B) -> LossLearner<'a, B> {
        let mdb_deltas = backend.loss_name() == "mdb";
        LossLearner { backend, mdb_deltas }
    }
}

impl<E: VecEnv, B: SnapshotBackend> EngineLearner<E> for LossLearner<'_, B> {
    type Snap = B::Snapshot;

    fn snapshot(&self) -> B::Snapshot {
        self.backend.snapshot_policy()
    }

    fn steps(&self) -> u64 {
        self.backend.steps()
    }

    fn learn(&mut self, tagged: &mut TaggedBatch<E::Obj>) -> anyhow::Result<IterStats> {
        if self.mdb_deltas {
            tagged.batch.extra_to_deltas();
        }
        let (loss, log_z) = self.backend.train_step(&tagged.batch)?;
        let b = tagged.batch.b as f64;
        Ok(IterStats {
            loss,
            log_z,
            mean_log_reward: tagged.batch.log_reward.iter().map(|&x| x as f64).sum::<f64>() / b,
            mean_length: tagged.batch.length.iter().map(|&x| x as f64).sum::<f64>() / b,
        })
    }

    fn checkpoint(&self, path: &std::path::Path) -> anyhow::Result<()> {
        self.backend.checkpoint(path)
    }
}

/// Aggregate statistics of one engine run.
#[derive(Debug, Default)]
pub struct EngineStats {
    /// Learner steps taken.
    pub iters: u64,
    /// Snapshots published (excluding the initial version 0).
    pub publishes: u64,
    /// Per-step loss trace (the sync-mode parity object).
    pub losses: Vec<f32>,
    /// logZ after the final step.
    pub final_log_z: f32,
    /// Mean log-reward of the final consumed batch.
    pub final_mean_log_reward: f64,
    /// Per-version staleness accounting: consumed-batch count keyed by
    /// `learner_version − batch_version` (in publishes). Sync mode is all
    /// zeros by construction.
    pub staleness_hist: BTreeMap<u64, u64>,
    /// Batches consumed per producing actor.
    pub batches_per_actor: Vec<u64>,
    /// Consumed batches that were replay (backward-rollout) batches.
    pub replay_batches: u64,
    /// Wall-clock of the whole run (scope entry to scope exit).
    pub wall_secs: f64,
}

impl EngineStats {
    /// Total batches consumed (= learner steps).
    pub fn batches(&self) -> u64 {
        self.staleness_hist.values().sum()
    }

    /// Mean staleness over consumed batches, in publishes.
    pub fn mean_staleness(&self) -> f64 {
        let n = self.batches();
        if n == 0 {
            return 0.0;
        }
        let sum: u64 = self.staleness_hist.iter().map(|(&s, &c)| s * c).sum();
        sum as f64 / n as f64
    }

    /// Largest staleness observed.
    pub fn max_staleness(&self) -> u64 {
        self.staleness_hist.keys().next_back().copied().unwrap_or(0)
    }

    /// Trajectory-batch throughput of the run (the `engine_scaling` bench
    /// metric; multiply by the batch width B for trajectories/sec).
    pub fn batches_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.iters as f64 / self.wall_secs
        }
    }
}

/// Timings of one sampled learner step, waiting for the publish phase (the
/// body loop owns publish timing) before the `engine_step` trace record is
/// assembled.
struct PendingStepTrace {
    rollout_ns: u64,
    push_wait_ns: u64,
    pop_wait_ns: u64,
    learn_ns: u64,
    actor: usize,
    version: u64,
    staleness: u64,
    replayed: bool,
}

/// Assemble and push one `engine_step` trace record. The phases overlap in
/// wall-clock (the actor rolls out batch `i+1` while the learner trains on
/// batch `i`), so segments are laid out at *logical* sequential offsets —
/// the waterfall reads as one batch's journey through the pipeline, and
/// `total_ns` is that journey's critical-path length, not the step's
/// wall-clock.
fn push_step_trace(p: PendingStepTrace, publish_ns: u64, step: u64) {
    let phases = [
        ("rollout", p.rollout_ns),
        ("push_wait", p.push_wait_ns),
        ("pop_wait", p.pop_wait_ns),
        ("learn", p.learn_ns),
        ("publish", publish_ns),
    ];
    let mut segments = Vec::with_capacity(phases.len());
    let mut cursor = 0u64;
    for (name, dur_ns) in phases {
        segments.push(TraceSegment { name: name.to_string(), start_ns: cursor, dur_ns });
        cursor += dur_ns;
    }
    let tracer = trace::tracer();
    tracer.push_record(TraceRecord {
        id: tracer.mint_id(),
        kind: "engine_step".to_string(),
        total_ns: cursor,
        ok: true,
        segments,
        meta: vec![
            ("step".to_string(), step as f64),
            ("actor".to_string(), p.actor as f64),
            ("version".to_string(), p.version as f64),
            ("staleness".to_string(), p.staleness as f64),
            ("replayed".to_string(), if p.replayed { 1.0 } else { 0.0 }),
        ],
    });
}

/// Runs its closure on drop — the engine's shutdown guard (see its use in
/// [`run`]).
struct CloseOnDrop<F: FnMut()>(F);

impl<F: FnMut()> Drop for CloseOnDrop<F> {
    fn drop(&mut self) {
        (self.0)();
    }
}

/// RNG seed of actor `k`. Actor 0 gets the base seed **verbatim** — with
/// one actor in sync mode its draw stream is then identical to the serial
/// `Trainer`'s, which is what the bitwise parity guarantee rests on.
/// Higher actors derive independent SplitMix streams.
pub fn actor_seed(seed: u64, actor: usize) -> u64 {
    if actor == 0 {
        seed
    } else {
        traj_seed(seed ^ 0xE16E_A51C_0FF1_CE00, actor as u64)
    }
}

/// The actor loop: fetch the freshest snapshot, assemble one batch through
/// the shared [`assemble_batch_with_policy`] path, bank on-policy
/// discoveries into the local replay shard, push. Exits when the channel
/// (async) or the hub (sync rendezvous) closes.
#[allow(clippy::too_many_arguments)]
fn actor_loop<E, P>(
    env: &E,
    actor: usize,
    cfg: &EngineConfig,
    explore: EpsSchedule,
    extra: &ExtraSource<'_, E>,
    hub: &PolicyHub<P>,
    chan: Bounded<anyhow::Result<TaggedBatch<E::Obj>>>,
) where
    E: VecEnv,
    P: BatchPolicy + Clone,
{
    let mut rng = crate::util::rng::Rng::new(actor_seed(cfg.seed, actor));
    let mut snap = hub.latest();
    let mut policy: P = snap.policy.clone();
    let mut ctx = RolloutCtx::for_shape(&policy.shape());
    let mut shard: Option<(ReplayConfig, RingBuffer<E::Obj>)> =
        cfg.replay.map(|r| (r, RingBuffer::new(r.cap)));
    let mut produced: u64 = 0;
    loop {
        if cfg.sync {
            // Rendezvous: batch i is assembled only against publish i (the
            // learner publishes after every step in sync mode), which
            // reproduces the serial rollout → step → rollout ordering.
            match hub.wait_for_version(produced) {
                Some(s) => {
                    if s.version != snap.version {
                        policy = s.policy.clone();
                    }
                    snap = s;
                }
                None => return,
            }
        } else {
            let latest = hub.latest();
            if latest.version != snap.version {
                snap = latest;
                policy = snap.policy.clone();
            }
        }
        let eps = explore.at(snap.steps);
        // Trace annotations are clock reads only (no RNG, no control flow),
        // so the sync-mode parity contract holds with tracing on.
        let rollout_start = trace::trace_enabled().then(Instant::now);
        let assembled = {
            let _t = crate::span!("engine.rollout");
            assemble_batch_with_policy(
                env,
                &mut policy,
                &mut ctx,
                &mut rng,
                eps,
                shard.as_mut().map(|(c, b)| (&*c, b)),
                extra,
            )
        };
        let rollout_ns =
            rollout_start.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
        let item = match assembled {
            Ok((batch, objs, replayed)) => {
                if !replayed {
                    if let Some((_, buf)) = shard.as_mut() {
                        bank_top_half(buf, &batch, &objs);
                    }
                }
                Ok(TaggedBatch {
                    batch,
                    objs,
                    version: snap.version,
                    actor,
                    replayed,
                    rollout_ns,
                    push_wait_ns: Arc::new(AtomicU64::new(0)),
                })
            }
            Err(e) => Err(e),
        };
        let failed = item.is_err();
        let push_wait =
            item.as_ref().ok().map(|t| Arc::clone(&t.push_wait_ns));
        let push_start = trace::trace_enabled().then(Instant::now);
        let pushed = {
            // Time spent here beyond the channel's own bookkeeping is the
            // actor blocked on backpressure (queue full).
            let _t = crate::span!("engine.actor_push_wait");
            chan.push_blocking(item)
        };
        if let (Some(pw), Some(t0)) = (push_wait, push_start) {
            pw.store(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        if !pushed || failed {
            // Channel closed (learner done) or own rollout failure — either
            // way this actor is finished.
            return;
        }
        produced += 1;
    }
}

/// Run `iters` learner steps of asynchronous (or sync-mode) actor–learner
/// training. The learner runs on the calling thread; actors are scoped
/// threads borrowing `env` and `extra`. `on_publish` fires after every
/// snapshot publish (serve hot-swap, logging); the initial version-0
/// snapshot does not fire it.
pub fn run<E, L, F>(
    env: &E,
    learner: &mut L,
    explore: EpsSchedule,
    extra: &ExtraSource<'_, E>,
    cfg: &EngineConfig,
    iters: u64,
    mut on_publish: F,
) -> anyhow::Result<EngineStats>
where
    E: VecEnv + Sync,
    E::Obj: Send,
    L: EngineLearner<E>,
    F: FnMut(&Arc<Snapshot<L::Snap>>) -> anyhow::Result<()>,
{
    cfg.validate()?;
    let hub: PolicyHub<L::Snap> = PolicyHub::new(learner.snapshot(), learner.steps());
    let chan: Bounded<anyhow::Result<TaggedBatch<E::Obj>>> =
        Bounded::new(cfg.effective_depth());
    let t0 = Instant::now();

    let result = std::thread::scope(|scope| {
        for a in 0..cfg.actors {
            let chan = chan.clone();
            let hub = &hub;
            let explore = explore;
            scope.spawn(move || {
                // A panicking actor must not strand the learner in
                // pop_blocking: catch the unwind and surface it as a
                // channel error so the run fails cleanly instead of
                // hanging (env/policy asserts inside a rollout are the
                // realistic source).
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    actor_loop(env, a, cfg, explore, extra, hub, chan.clone())
                }));
                if let Err(payload) = caught {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    chan.push_blocking(Err(anyhow::anyhow!(
                        "actor {a} panicked during rollout: {msg}"
                    )));
                }
            });
        }

        // Close the pipeline however the learner exits — normal return,
        // error, *or panic*. Without this guard a learner-side panic
        // (learner code, a checkpoint write, the on_publish hook) would
        // skip the closes and leave actors blocked in push/wait while the
        // scope waits to join them: a permanent hang instead of a
        // propagated panic. Declared first so it drops last.
        let _shutdown = CloseOnDrop(|| {
            chan.close();
            hub.close();
        });

        let mut stats = EngineStats {
            batches_per_actor: vec![0; cfg.actors],
            losses: Vec::with_capacity(iters as usize),
            ..EngineStats::default()
        };
        let mut version: u64 = 0;
        let learn = |stats: &mut EngineStats,
                     learner: &mut L,
                     version: u64|
         -> anyhow::Result<Option<PendingStepTrace>> {
            // Sampling decision is counter-based (no RNG) and made up
            // front, so an untraced step pays one relaxed load and zero
            // clock reads beyond the existing spans.
            let traced = trace::sampled();
            let pop_start = traced.then(Instant::now);
            let mut tagged = {
                // Learner blocked on an empty queue (actor-bound runs).
                let _t = crate::span!("engine.learner_pop_wait");
                chan.pop_blocking()
            }
            .expect("engine channel closed while the learner still runs")?;
            let pop_wait_ns =
                pop_start.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
            let learn_start = traced.then(Instant::now);
            let s = {
                let _t = crate::span!("engine.learn");
                learner.learn(&mut tagged)
            }?;
            let learn_ns =
                learn_start.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
            // Liveness heartbeat for the watchdog: unconditional, one gauge
            // store per step on the shared registry clock.
            trace::beat(crate::telemetry::global(), "engine.learner_heartbeat_s");
            anyhow::ensure!(
                s.loss.is_finite(),
                "engine loss diverged at step {} (actor {}, version {})",
                stats.iters,
                tagged.actor,
                tagged.version
            );
            // Re-expose the staleness/batch accounting through the global
            // registry (same numbers as `EngineStats`, live instead of
            // end-of-run).
            crate::record!("engine.staleness", version - tagged.version);
            crate::count!("engine.batches", 1);
            *stats.staleness_hist.entry(version - tagged.version).or_insert(0) += 1;
            stats.batches_per_actor[tagged.actor] += 1;
            if tagged.replayed {
                crate::count!("engine.replay_batches", 1);
                stats.replay_batches += 1;
            }
            stats.losses.push(s.loss);
            stats.final_log_z = s.log_z;
            stats.final_mean_log_reward = s.mean_log_reward;
            stats.iters += 1;
            Ok(traced.then(|| PendingStepTrace {
                rollout_ns: tagged.rollout_ns,
                push_wait_ns: tagged.push_wait_ns.load(Ordering::Relaxed),
                pop_wait_ns,
                learn_ns,
                actor: tagged.actor,
                version,
                staleness: version - tagged.version,
                replayed: tagged.replayed,
            }))
        };
        let body = (|| -> anyhow::Result<()> {
            for step in 0..iters {
                let pending = learn(&mut stats, learner, version)?;
                let mut publish_ns = 0u64;
                if (step + 1) % cfg.publish_every == 0 || step + 1 == iters {
                    version += 1;
                    let publish_start = pending.is_some().then(Instant::now);
                    // Per-publish snapshot latency: snapshot + hub publish +
                    // optional checkpoint (the user `on_publish` hook is
                    // excluded — it is not engine cost).
                    let snap = {
                        let _t = crate::span!("engine.publish");
                        let snap = Arc::new(Snapshot {
                            version,
                            steps: learner.steps(),
                            policy: learner.snapshot(),
                        });
                        hub.publish(Arc::clone(&snap));
                        if let Some(path) = &cfg.checkpoint {
                            learner.checkpoint(path)?;
                        }
                        snap
                    };
                    publish_ns = publish_start
                        .map(|t| t.elapsed().as_nanos() as u64)
                        .unwrap_or(0);
                    stats.publishes += 1;
                    crate::count!("engine.publishes", 1);
                    on_publish(&snap)?;
                }
                // Sampled step trace: the publish segment is 0 on
                // non-publish steps (nothing was published).
                if let Some(p) = pending {
                    push_step_trace(p, publish_ns, step);
                }
            }
            Ok(())
        })();
        // `_shutdown` closes the channel + hub when this closure's locals
        // drop (i.e. before the scope joins the actors), on success, error
        // and unwind alike.
        body.map(|()| stats)
    });
    result.map(|mut stats| {
        stats.wall_secs = t0.elapsed().as_secs_f64();
        stats
    })
}

/// Convenience wrapper for the standard path: async (or sync) training of
/// a [`SnapshotBackend`] on `env` — the engine-side counterpart of
/// `Trainer::train_iter` loops.
pub fn train<E, B, F>(
    env: &E,
    backend: &mut B,
    explore: EpsSchedule,
    extra: &ExtraSource<'_, E>,
    cfg: &EngineConfig,
    iters: u64,
    on_publish: F,
) -> anyhow::Result<EngineStats>
where
    E: VecEnv + Sync,
    E::Obj: Send,
    B: SnapshotBackend,
    F: FnMut(&Arc<Snapshot<B::Snapshot>>) -> anyhow::Result<()>,
{
    crate::runtime::policy::check_env_token_shape(
        &env.spec(),
        &backend.shape(),
        backend.token_shape(),
    )?;
    let mut learner = LossLearner::new(backend);
    run(env, &mut learner, explore, extra, cfg, iters, on_publish)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::Trainer;
    use crate::envs::hypergrid::HypergridEnv;
    use crate::reward::hypergrid::HypergridReward;
    use crate::runtime::{Backend, NativeBackend, NativeConfig};

    fn env(h: usize) -> HypergridEnv<HypergridReward> {
        HypergridEnv::new(2, h, HypergridReward::standard(h))
    }

    fn backend(
        e: &HypergridEnv<HypergridReward>,
        loss: &str,
        seed: u64,
    ) -> NativeBackend {
        NativeBackend::new(NativeConfig::for_env(e, 8, loss).with_hidden(16), seed).unwrap()
    }

    fn param_bits(b: &NativeBackend) -> Vec<Vec<u32>> {
        b.net()
            .leaves()
            .iter()
            .map(|l| l.tensor.data().iter().map(|v| v.to_bits()).collect())
            .collect()
    }

    /// The acceptance-criterion test: a sync-mode engine run is
    /// bitwise-identical to the serial `Trainer` from the same seed over
    /// 60 steps on hypergrid/tb — every per-step loss bit and every
    /// parameter leaf bit.
    #[test]
    fn sync_mode_is_bitwise_identical_to_serial_trainer() {
        let e = env(8);
        let iters = 60u64;
        let seed = 17u64;

        // Serial reference.
        let mut serial =
            Trainer::with_backend(&e, backend(&e, "tb", seed), seed, EpsSchedule::none())
                .unwrap();
        let mut serial_losses = Vec::new();
        for _ in 0..iters {
            let (s, _) = serial.train_iter(&ExtraSource::None).unwrap();
            serial_losses.push(s.loss.to_bits());
        }

        // Sync-mode engine from the same backend + rng seeds.
        let mut be = backend(&e, "tb", seed);
        let stats = train(
            &e,
            &mut be,
            EpsSchedule::none(),
            &ExtraSource::None,
            &EngineConfig::sync(seed),
            iters,
            |_| Ok(()),
        )
        .unwrap();

        let engine_losses: Vec<u32> = stats.losses.iter().map(|l| l.to_bits()).collect();
        assert_eq!(engine_losses, serial_losses, "loss traces must match bitwise");
        assert_eq!(param_bits(&serial.backend), param_bits(&be), "params must match bitwise");
        assert_eq!(stats.iters, iters);
        // Sync mode is staleness-free by construction.
        assert_eq!(stats.staleness_hist.keys().copied().collect::<Vec<_>>(), vec![0]);
        assert_eq!(stats.publishes, iters);
    }

    /// Acceptance criterion: instrumentation is timing-only and must not
    /// perturb RNG streams — the bitwise sync parity guarantee holds with
    /// telemetry *enabled*, and the hot-path spans actually record.
    #[test]
    fn sync_mode_parity_holds_with_telemetry_enabled() {
        let _guard = crate::telemetry::flag_test_lock();
        let was = crate::telemetry::enabled();
        crate::telemetry::set_enabled(true);

        let e = env(6);
        let iters = 40u64;
        let seed = 21u64;
        let mut serial =
            Trainer::with_backend(&e, backend(&e, "tb", seed), seed, EpsSchedule::none())
                .unwrap();
        let mut serial_losses = Vec::new();
        for _ in 0..iters {
            let (s, _) = serial.train_iter(&ExtraSource::None).unwrap();
            serial_losses.push(s.loss.to_bits());
        }
        let mut be = backend(&e, "tb", seed);
        let stats = train(
            &e,
            &mut be,
            EpsSchedule::none(),
            &ExtraSource::None,
            &EngineConfig::sync(seed),
            iters,
            |_| Ok(()),
        )
        .unwrap();
        crate::telemetry::set_enabled(was);

        assert_eq!(
            stats.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            serial_losses,
            "telemetry must not change the loss trace"
        );
        assert_eq!(param_bits(&serial.backend), param_bits(&be));
        let reg = crate::telemetry::global();
        for span in ["engine.rollout", "engine.learn", "engine.publish"] {
            assert!(reg.histogram(span).count() > 0, "span '{span}' did not record");
        }
        assert!(reg.value_histogram("engine.staleness").count() >= iters);
    }

    /// Step tracing at rate 1 records a full `engine_step` waterfall per
    /// learner step (rollout → push_wait → pop_wait → learn → publish at
    /// logical offsets) — without perturbing the bitwise sync parity,
    /// because the sampler is counter-based and instrumentation only reads
    /// clocks.
    #[test]
    fn step_traces_record_without_perturbing_sync_parity() {
        let _guard = crate::telemetry::flag_test_lock();
        trace::set_trace_rate(1.0);
        trace::reset_sampler();

        let e = env(6);
        let iters = 20u64;
        let seed = 13u64;
        let mut serial =
            Trainer::with_backend(&e, backend(&e, "tb", seed), seed, EpsSchedule::none())
                .unwrap();
        let mut serial_losses = Vec::new();
        for _ in 0..iters {
            let (s, _) = serial.train_iter(&ExtraSource::None).unwrap();
            serial_losses.push(s.loss.to_bits());
        }
        let mut be = backend(&e, "tb", seed);
        let stats = train(
            &e,
            &mut be,
            EpsSchedule::none(),
            &ExtraSource::None,
            &EngineConfig::sync(seed),
            iters,
            |_| Ok(()),
        )
        .unwrap();
        trace::set_trace_rate(0.0);

        assert_eq!(
            stats.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            serial_losses,
            "tracing must not change the loss trace"
        );
        assert_eq!(param_bits(&serial.backend), param_bits(&be));

        let steps: Vec<_> = trace::tracer()
            .recent(iters as usize)
            .into_iter()
            .filter(|r| r.kind == "engine_step")
            .collect();
        assert!(!steps.is_empty(), "rate-1 tracing must record step waterfalls");
        let rec = &steps[0];
        let names: Vec<&str> = rec.segments.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["rollout", "push_wait", "pop_wait", "learn", "publish"]);
        assert_eq!(
            rec.total_ns,
            rec.segments.iter().map(|s| s.dur_ns).sum::<u64>(),
            "logical offsets: total is the sum of the phases"
        );
        assert!(rec.ok);
        for key in ["step", "actor", "version", "staleness", "replayed"] {
            assert!(rec.meta.iter().any(|(k, _)| k == key), "missing meta {key}");
        }
        // Learner heartbeat gauge was touched on the global registry clock.
        let reg = crate::telemetry::global();
        assert!(reg.gauge("engine.learner_heartbeat_s").get() > 0.0);
    }

    /// Sync-mode parity extends to replay mixing and ε-exploration: the
    /// shared assembly path draws the same RNG stream as the serial
    /// trainer, replay decisions and buffer contents included.
    #[test]
    fn sync_mode_matches_serial_trainer_with_replay_and_eps() {
        let e = env(6);
        let iters = 50u64;
        let seed = 5u64;
        let explore = EpsSchedule::Linear { start: 0.3, end: 0.0, steps: 40 };
        let replay = ReplayConfig::new(16, 0.5);

        let mut serial = Trainer::with_backend(&e, backend(&e, "tb", seed), seed, explore)
            .unwrap()
            .with_replay(replay)
            .unwrap();
        let mut serial_losses = Vec::new();
        for _ in 0..iters {
            let (s, _) = serial.train_iter(&ExtraSource::None).unwrap();
            serial_losses.push(s.loss.to_bits());
        }

        let mut be = backend(&e, "tb", seed);
        let cfg = EngineConfig::sync(seed).with_replay(replay);
        let stats =
            train(&e, &mut be, explore, &ExtraSource::None, &cfg, iters, |_| Ok(())).unwrap();

        assert_eq!(
            stats.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            serial_losses,
            "replay + ε sync run must match the serial trainer bitwise"
        );
        assert_eq!(param_bits(&serial.backend), param_bits(&be));
        assert!(stats.replay_batches > 0, "frac 0.5 over 50 iters should replay");
    }

    /// Async smoke: 2 actors, publish every 4 — training stays finite, the
    /// loss trends down, and every consumed batch is accounted for in the
    /// staleness histogram.
    #[test]
    fn async_two_actors_trains_and_accounts_staleness() {
        let e = env(8);
        let mut be =
            NativeBackend::new(NativeConfig::for_env(&e, 16, "tb").with_hidden(32), 3).unwrap();
        let mut cfg = EngineConfig::new(2, 4, 3);
        cfg.queue_depth = 4;
        let iters = 300u64;
        let stats = train(
            &e,
            &mut be,
            EpsSchedule::none(),
            &ExtraSource::None,
            &cfg,
            iters,
            |_| Ok(()),
        )
        .unwrap();
        assert_eq!(stats.iters, iters);
        assert_eq!(stats.batches(), iters, "every consumed batch is accounted");
        assert_eq!(stats.batches_per_actor.iter().sum::<u64>(), iters);
        assert_eq!(be.steps(), iters);
        // No hard staleness bound is asserted: backpressure bounds *queue
        // residency* (≈ depth/K + 1 = 2 publishes here), but an actor
        // descheduled mid-rollout on a loaded box can be arbitrarily late —
        // asserting an OS-scheduling property would make the test flaky.
        // The accounting identities above are the real invariants.
        let head = stats.losses[..30].iter().map(|&x| x as f64).sum::<f64>() / 30.0;
        let tail = stats.losses[270..].iter().map(|&x| x as f64).sum::<f64>() / 30.0;
        assert!(tail < head, "async TB loss should trend down: {head:.3} -> {tail:.3}");
    }

    /// The sync engine is reproducible run-to-run (the weaker guarantee
    /// async mode deliberately gives up).
    #[test]
    fn sync_mode_is_deterministic_across_runs() {
        let e = env(6);
        let run = |seed: u64| {
            let mut be = backend(&e, "db", seed);
            let stats = train(
                &e,
                &mut be,
                EpsSchedule::none(),
                &ExtraSource::None,
                &EngineConfig::sync(seed),
                30,
                |_| Ok(()),
            )
            .unwrap();
            (stats.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(), param_bits(&be))
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9).0, run(10).0);
    }

    /// Config validation: zero actors, bad sync topologies and bad replay
    /// fractions are rejected before any thread spawns.
    #[test]
    fn config_validation_rejects_bad_topologies() {
        let e = env(6);
        let mut be = backend(&e, "tb", 0);
        let mut run_cfg = |cfg: EngineConfig| {
            train(&e, &mut be, EpsSchedule::none(), &ExtraSource::None, &cfg, 1, |_| Ok(()))
        };
        let mut bad = EngineConfig::new(0, 1, 0);
        assert!(run_cfg(bad.clone()).is_err());
        bad = EngineConfig::new(1, 0, 0);
        assert!(run_cfg(bad.clone()).is_err());
        bad = EngineConfig::new(2, 1, 0);
        bad.sync = true;
        assert!(run_cfg(bad.clone()).is_err());
        bad = EngineConfig::new(1, 1, 0).with_replay(ReplayConfig::new(8, 1.5));
        assert!(run_cfg(bad).is_err());
    }

    /// Publish cadence: `publish_every = K` publishes ⌈iters/K⌉ snapshots
    /// (the final partial window still publishes), and `on_publish` sees
    /// monotonically increasing versions with growing step counts.
    #[test]
    fn publish_cadence_and_hook_ordering() {
        let e = env(6);
        let mut be = backend(&e, "tb", 1);
        let seen = std::cell::RefCell::new(Vec::<(u64, u64)>::new());
        let stats = train(
            &e,
            &mut be,
            EpsSchedule::none(),
            &ExtraSource::None,
            &EngineConfig::new(1, 4, 1),
            10,
            |snap| {
                seen.borrow_mut().push((snap.version, snap.steps));
                Ok(())
            },
        )
        .unwrap();
        let seen = seen.into_inner();
        assert_eq!(stats.publishes, 3); // steps 4, 8, and the final 10
        assert_eq!(seen.iter().map(|&(v, _)| v).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(seen.iter().map(|&(_, s)| s).collect::<Vec<_>>(), vec![4, 8, 10]);
    }

    /// FLDB through the engine: extras-dependent objectives flow through
    /// actor-side assembly (the `Sync` extra source) and replay shards.
    #[test]
    fn async_fldb_with_replay_stays_finite() {
        let e = env(6);
        let mut be =
            NativeBackend::new(NativeConfig::for_env(&e, 8, "fldb").with_hidden(16), 7).unwrap();
        let energy = |s: &crate::envs::hypergrid::HypergridState, i: usize| {
            0.25 * s.coords_of(i).iter().map(|&c| c as f64).sum::<f64>()
        };
        let cfg = EngineConfig::new(2, 2, 7).with_replay(ReplayConfig::new(16, 0.4));
        let stats = train(
            &e,
            &mut be,
            EpsSchedule::none(),
            &ExtraSource::Energy(&energy),
            &cfg,
            120,
            |_| Ok(()),
        )
        .unwrap();
        assert_eq!(stats.iters, 120);
        assert!(stats.losses.iter().all(|l| l.is_finite()));
    }
}
