//! `gfnx` CLI — train, evaluate and benchmark GFlowNets (see README.md for
//! the full workflow).
//!
//! Subcommands:
//!   train        --env <family> | --config <name>   (all nine families —
//!                see `list-configs`, generated from the env registry)
//!                --loss <tb|db|subtb|fldb|mdb>   (fldb/mdb on the envs
//!                                                 that supply extras)
//!                --backend <native|xla>  [--iters N] [--hidden H]
//!                [--layers L] [--workers W]
//!                [--model <mlp|transformer>]   (transformer: token-grid
//!                                               envs, native backend)
//!                [--replay-cap N --replay-frac P]   off-policy replay
//!                [--actors N --publish-every K | --sync]   async engine
//!                [--serve [--serve-samples N]]   live hot-swapped serving
//!                [--save <ckpt> --resume <ckpt>]   checkpointed resume
//!                [--ebgfn [--sigma S] [--samples N]]   EB-GFN (ising only)
//!                [--telemetry | --telemetry-file <p.jsonl>]   hot-path spans
//!                [--telemetry-interval <secs>]   export cadence
//!                [--trace <on|rate> | --trace-file <p.jsonl>]   sampled
//!                                                engine-step waterfalls
//!                [--listen <addr>]   (with --serve: HTTP endpoint over the
//!                                     live hot-swapped policy)
//!   serve        --env <family> | --config <name>  --listen <addr>
//!                [--resume <ckpt>] [--model <mlp|transformer>]
//!                [--queue-cap N] [--deadline-ms D] [--addr-file <p>]
//!                [--serve-duration <secs>]
//!                [--trace <on|rate> | --trace-file <p.jsonl>]   sampled
//!                                                request waterfalls
//!                [--stall-window-ms D]   /healthz watchdog window
//!                (standalone HTTP sampling server; see README "Serving
//!                over HTTP")
//!   list-configs
//!   info         --config <name> --loss <l>   (print the artifact manifest)
//!   check-bench  <BENCH_*.json...>   (validate emitted bench documents)
//!   check-telemetry  <telemetry.jsonl> [required-span ...]   (validate a
//!                --telemetry-file export; used by the CI telemetry smoke)
//!   check-trace  <trace.jsonl> [required-segment ...]   (validate a
//!                --trace-file export; used by the CI observability smoke)
//!
//! The default `--backend native` trains end-to-end in pure Rust with no
//! AOT artifacts; `--backend xla` replays the fused AOT graphs (requires
//! `make artifacts` + the real xla-rs crate). `--env`/`--loss` coverage,
//! help strings and error messages all derive from
//! `coordinator::registry`, so adding an environment there updates every
//! CLI surface at once.

use gfnx::bench::harness::check_bench_json;
use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::ebgfn::{EbGfnLearner, EbGfnTrainer, SharedIsingReward};
use gfnx::coordinator::registry::{self, EnvDriver, EnvFamily, EnvParams};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::{ReplayConfig, Trainer};
use gfnx::data::ising_mcmc::generate_ising_dataset;
use gfnx::engine::{self, EngineConfig, EngineStats};
use gfnx::envs::ising::IsingEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::ising::torus_adjacency;
use gfnx::runtime::{Artifact, Backend, NativeBackend, NativeConfig, NativePolicy};
use gfnx::serve::{HttpServer, HttpServerConfig, ObjJson, SamplerService, ServeIdentity};
use gfnx::telemetry;
use gfnx::telemetry::trace;
use gfnx::util::cli::{Args, Cli};
use gfnx::util::linalg::Mat;
use gfnx::util::logging::MetricsLog;
use gfnx::util::rng::Rng;
use gfnx::util::threadpool::default_workers;
use gfnx::{log_error, log_info, log_warn};
use std::sync::Arc;

fn main() {
    let env_help = registry::env_usage();
    let loss_help = registry::loss_usage();
    let cli = Cli::new(
        "gfnx",
        "Rust+JAX+Pallas GFlowNet benchmark infrastructure (gfnx reproduction)",
    )
    .positional(
        "command",
        "train | serve | list-configs | info | check-bench <BENCH_*.json...>",
    )
    .flag(
        "config",
        "",
        "experiment config name (empty = the --env family's default, or \
         hypergrid_small; see list-configs)",
    )
    .flag("env", "", &env_help)
    .flag("loss", "tb", &loss_help)
    .flag("backend", "native", "training backend: native | xla")
    .flag("iters", "0", "iteration count (0 = preset default)")
    .flag("seed", "0", "rng seed (also seeds generated datasets)")
    .flag("batch", "16", "batch width (native backend)")
    .flag("hidden", "256", "MLP trunk width (native backend)")
    .flag("layers", "2", "MLP trunk depth / transformer block count (native backend)")
    .flag(
        "model",
        "mlp",
        "policy model: mlp | transformer (native backend; transformer uses the \
         per-family preset — embed 64, 4 heads, ff 128 — and needs an env with \
         a token grid)",
    )
    .flag("workers", "0", "dispatch worker threads, 0 = all cores (native backend)")
    .flag("replay-cap", "0", "off-policy replay buffer capacity (0 = on-policy only)")
    .flag("replay-frac", "0.5", "probability an iteration trains on replay batches")
    .flag(
        "actors",
        "0",
        "actor threads for async actor-learner training (0 = serial loop; \
         native backend only)",
    )
    .flag("publish-every", "1", "learner steps between policy snapshot publishes (engine)")
    .flag("queue-depth", "0", "bounded actor->learner channel depth (0 = 2x actors)")
    .switch(
        "sync",
        "deterministic synchronous engine mode (1 actor, publish-every-step, \
         bitwise-identical to the serial loop)",
    )
    .switch("serve", "serve the improving policy while training (engine hot-swap)")
    .flag("serve-samples", "64", "objects sampled from the served policy after training")
    .flag(
        "listen",
        "",
        "HTTP listen address (e.g. 127.0.0.1:8080; port 0 = ephemeral). With \
         the serve command: required. With train --serve: also expose the \
         live hot-swapped policy over HTTP",
    )
    .flag(
        "queue-cap",
        "256",
        "bounded admission-queue depth for the sampling service; over-capacity \
         requests are shed with 503 (0 = unbounded)",
    )
    .flag(
        "deadline-ms",
        "30000",
        "default per-request deadline for HTTP sampling (client deadline_ms \
         overrides, clamped to the server max)",
    )
    .flag(
        "addr-file",
        "",
        "write the bound HTTP address to this file (ephemeral-port discovery \
         for scripts/CI)",
    )
    .flag(
        "serve-duration",
        "0",
        "serve command: seconds to serve before exiting (0 = until killed)",
    )
    .flag("save", "", "checkpoint path (engine: saved on every publish; serial: at end)")
    .flag("resume", "", "resume training from a checkpoint file (native backend)")
    .switch("ebgfn", "EB-GFN joint EBM+GFN training (ising only; paper Table 8)")
    .flag("sigma", "0.2", "true Ising coupling strength (ebgfn / ising reward)")
    .flag("samples", "2000", "EB-GFN dataset size (paper Table 9)")
    .flag("log", "", "JSONL metrics path (empty = stdout only)")
    .switch(
        "telemetry",
        "enable hot-path telemetry (span histograms, counters; also via \
         GFNX_TELEMETRY=1) and print the registry at end of run",
    )
    .flag(
        "telemetry-file",
        "",
        "append periodic registry snapshots to this JSONL file (implies --telemetry)",
    )
    .flag("telemetry-interval", "1", "seconds between telemetry snapshots")
    .flag(
        "trace",
        "",
        "sampled per-request / per-step tracing: on (1/64) | off | <rate in \
         (0,1]> (also via GFNX_TRACE; recent waterfalls are served at \
         GET /trace)",
    )
    .flag(
        "trace-file",
        "",
        "append completed trace records to this JSONL file (implies tracing \
         at the default 1/64 rate when --trace is absent; validate with \
         check-trace)",
    )
    .flag(
        "stall-window-ms",
        "",
        "/healthz watchdog: worker-heartbeat age (ms) beyond which a worker \
         with pending work reports worker_stalled (default 10000; also via \
         GFNX_STALL_WINDOW_MS)",
    )
    .switch("quiet", "suppress progress lines");
    let args = cli.parse();
    let command = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "list-configs".to_string());

    let result = match command.as_str() {
        "list-configs" => {
            list_configs();
            Ok(())
        }
        "info" => {
            let config = match args.get("config") {
                "" => "hypergrid_small",
                c => c,
            };
            info(config, args.get("loss"))
        }
        "train" => (|| {
            let tel = telemetry_setup(&args)?;
            let out = train(&args);
            // Print/export the registry even on failure — a run that died
            // mid-training is exactly when the phase timings matter.
            tel.finish();
            out
        })(),
        "serve" => (|| {
            let tel = telemetry_setup(&args)?;
            let out = serve_cmd(&args);
            tel.finish();
            out
        })(),
        "check-bench" => check_bench(&args),
        "check-telemetry" => check_telemetry(&args),
        "check-trace" => check_trace(&args),
        other => Err(anyhow::anyhow!("unknown command {other:?}")),
    };
    if let Err(e) = result {
        log_error!("error: {e}");
        std::process::exit(1);
    }
}

/// Telemetry lifecycle of one `train`/`serve` run: resolve the enabled flag
/// from `GFNX_TELEMETRY` / `--telemetry` / `--telemetry-file`, spawn the
/// JSONL exporter when a file is given, configure sampled tracing from
/// `GFNX_TRACE` / `--trace` / `--trace-file`, and render the registry at
/// the end.
struct Telemetry {
    exporter: Option<telemetry::Exporter>,
    enabled: bool,
    /// A `--trace-file` sink is attached and must be detached (flushed) at
    /// the end of the run.
    trace_sink: bool,
}

fn telemetry_setup(args: &Args) -> anyhow::Result<Telemetry> {
    telemetry::init_from_env();
    let file = args.get("telemetry-file");
    if args.get_bool("telemetry") || !file.is_empty() {
        telemetry::set_enabled(true);
    }
    let enabled = telemetry::enabled();
    let exporter = if enabled && !file.is_empty() {
        let secs = args.get_f64("telemetry-interval");
        anyhow::ensure!(
            secs.is_finite() && secs > 0.0,
            "--telemetry-interval must be a positive number of seconds (got {secs})"
        );
        Some(telemetry::Exporter::spawn(
            "gfnx.train",
            std::path::Path::new(file),
            std::time::Duration::from_secs_f64(secs),
            Arc::clone(telemetry::global()),
        )?)
    } else {
        None
    };

    // Tracing: env first, then the flag (same grammar), then the sink.
    trace::init_from_env();
    match args.get("trace").to_ascii_lowercase().as_str() {
        "" => {}
        "on" | "true" => trace::set_trace_rate(trace::DEFAULT_RATE),
        "off" | "false" | "0" => trace::set_trace_rate(0.0),
        other => {
            let rate: f64 = other.parse().map_err(|_| {
                anyhow::anyhow!("--trace must be on | off | a rate in (0, 1] (got {other:?})")
            })?;
            anyhow::ensure!(
                rate > 0.0 && rate <= 1.0,
                "--trace rate {rate} outside (0, 1]"
            );
            trace::set_trace_rate(rate);
        }
    }
    let trace_file = args.get("trace-file");
    let trace_sink = if trace_file.is_empty() {
        false
    } else {
        if !trace::trace_enabled() {
            trace::set_trace_rate(trace::DEFAULT_RATE);
        }
        trace::tracer().set_sink("gfnx.trace", std::path::Path::new(trace_file))?;
        true
    };
    Ok(Telemetry { exporter, enabled, trace_sink })
}

impl Telemetry {
    /// Write the final snapshot (joining the exporter thread), detach the
    /// trace sink, and print the end-of-run span/counter table.
    fn finish(self) {
        if let Some(exp) = self.exporter {
            exp.stop();
        }
        if self.trace_sink {
            trace::tracer().clear_sink();
        }
        if self.enabled {
            print!("{}", telemetry::global().render());
        }
    }
}

/// Validate telemetry JSONL exports (CLI
/// `check-telemetry <file> [required-span ...]`; CI runs this after the
/// telemetry train smoke with the hot-path span names).
fn check_telemetry(args: &Args) -> anyhow::Result<()> {
    let pos = args.positional();
    anyhow::ensure!(
        pos.len() >= 2,
        "usage: gfnx check-telemetry <telemetry.jsonl> [required-span ...]"
    );
    let file = &pos[1];
    let spans: Vec<&str> = pos[2..].iter().map(|s| s.as_str()).collect();
    let text = std::fs::read_to_string(file)
        .map_err(|e| anyhow::anyhow!("reading {file}: {e}"))?;
    let summary = telemetry::check_telemetry_jsonl(&text, &spans)
        .map_err(|e| anyhow::anyhow!("{file}: {e}"))?;
    println!("ok {file} ({summary})");
    Ok(())
}

/// Validate trace JSONL exports (CLI
/// `check-trace <file> [required-segment ...]`; CI runs this after the
/// observability smoke with the request-waterfall segment names).
fn check_trace(args: &Args) -> anyhow::Result<()> {
    let pos = args.positional();
    anyhow::ensure!(
        pos.len() >= 2,
        "usage: gfnx check-trace <trace.jsonl> [required-segment ...]"
    );
    let file = &pos[1];
    let segments: Vec<&str> = pos[2..].iter().map(|s| s.as_str()).collect();
    let text = std::fs::read_to_string(file)
        .map_err(|e| anyhow::anyhow!("reading {file}: {e}"))?;
    let summary = telemetry::check_trace_jsonl(&text, &segments)
        .map_err(|e| anyhow::anyhow!("{file}: {e}"))?;
    println!("ok {file} ({summary})");
    Ok(())
}

/// Registry-generated config listing: families, sized configs, losses.
fn list_configs() {
    println!("environment registry (native backend needs nothing; xla needs `make artifacts`):");
    for f in registry::families() {
        println!("  {} — {}", f.name, f.about);
        println!("      configs: {}", f.configs.join(" | "));
        println!("      losses:  {}", f.losses.join(" | "));
    }
}

fn info(config: &str, loss: &str) -> anyhow::Result<()> {
    let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
    let m = &art.manifest;
    println!("artifact     {}", m.name);
    println!("obs_dim      {}", m.config.obs_dim);
    println!("n_actions    {}", m.config.n_actions);
    println!("n_bwd        {}", m.config.n_bwd_actions);
    println!("t_max        {}", m.config.t_max);
    println!("batch        {}", m.config.batch);
    println!("uniform_pb   {}", m.config.uniform_pb);
    println!("param leaves {}", m.n_params());
    let total: usize = m.params.iter().map(|p| p.element_count()).sum();
    println!("param count  {total}");
    Ok(())
}

/// Train any registered family; env construction and loss gating are
/// registry-driven.
fn train(args: &Args) -> anyhow::Result<()> {
    let (env_flag, mut config_flag) = (args.get("env"), args.get("config"));
    if env_flag.is_empty() && config_flag.is_empty() {
        config_flag = "hypergrid_small"; // bare `train` keeps its old default
    }
    let (fam, config) = registry::resolve(env_flag, config_flag)?;
    let loss = args.get("loss");
    // Satellite fix: GFNX_FASTMATH is resolved exactly once per process and
    // threaded through — the engine, the serve path and EB-GFN used to each
    // re-read the env var, so a mid-run setenv (or a future per-site
    // default drift) could leave them disagreeing about accumulation mode.
    let fastmath = gfnx::runtime::fastmath_from_env();
    if args.get_bool("ebgfn") {
        anyhow::ensure!(
            fam.name == "ising",
            "--ebgfn is the Ising Table 8 workload; pass --env ising"
        );
        return train_ebgfn(args, &config, registry::ising_side(&config)?, fastmath);
    }
    registry::check_loss(fam, loss)?;
    let params = EnvParams { seed: args.get_u64("seed"), sigma: args.get_f64("sigma") };
    registry::with_env(&config, params, TrainDriver { args, fastmath })
}

/// The CLI's [`EnvDriver`]: backend selection + replay wiring + the train
/// loop, generic over whatever env the registry built.
struct TrainDriver<'a> {
    args: &'a Args,
    /// `GFNX_FASTMATH`, resolved once at startup.
    fastmath: bool,
}

impl EnvDriver for TrainDriver<'_> {
    type Out = ();

    fn drive<E>(
        self,
        env: &E,
        extra: &ExtraSource<'_, E>,
        fam: &'static EnvFamily,
        config: &str,
    ) -> anyhow::Result<()>
    where
        E: VecEnv + Clone + Send + Sync + 'static,
        E::State: Clone,
        E::Obj: PartialEq + std::fmt::Debug + Send + 'static + ObjJson,
    {
        train_env(self.args, config, self.args.get("loss"), env, extra, fam, self.fastmath)
    }
}

/// Standalone HTTP sampling server (CLI `serve --listen <addr>`): load a
/// checkpoint (or stand up a fresh policy) for the resolved env and serve
/// it until `--serve-duration` elapses or the process is killed.
fn serve_cmd(args: &Args) -> anyhow::Result<()> {
    let (env_flag, mut config_flag) = (args.get("env"), args.get("config"));
    if env_flag.is_empty() && config_flag.is_empty() {
        config_flag = "hypergrid_small";
    }
    let (fam, config) = registry::resolve(env_flag, config_flag)?;
    registry::check_loss(fam, args.get("loss"))?;
    anyhow::ensure!(
        !args.get("listen").is_empty(),
        "serve needs --listen <addr> (e.g. --listen 127.0.0.1:8080)"
    );
    anyhow::ensure!(
        args.get("backend") == "native",
        "serve runs on the native backend (owned policies; xla's PJRT state \
         is thread-local)"
    );
    let fastmath = gfnx::runtime::fastmath_from_env();
    let params = EnvParams { seed: args.get_u64("seed"), sigma: args.get_f64("sigma") };
    registry::with_env(&config, params, ServeDriver { args, fastmath })
}

/// [`EnvDriver`] for the standalone `serve` command.
struct ServeDriver<'a> {
    args: &'a Args,
    fastmath: bool,
}

impl EnvDriver for ServeDriver<'_> {
    type Out = ();

    fn drive<E>(
        self,
        env: &E,
        _extra: &ExtraSource<'_, E>,
        fam: &'static EnvFamily,
        config: &str,
    ) -> anyhow::Result<()>
    where
        E: VecEnv + Clone + Send + Sync + 'static,
        E::State: Clone,
        E::Obj: PartialEq + std::fmt::Debug + Send + 'static + ObjJson,
    {
        serve_env(self.args, config, env, fam, self.fastmath)
    }
}

/// Stand up the sampling service + HTTP front end for one env and block.
fn serve_env<E>(
    args: &Args,
    config: &str,
    env: &E,
    fam: &'static EnvFamily,
    fastmath: bool,
) -> anyhow::Result<()>
where
    E: VecEnv + Clone + Send + Sync + 'static,
    E::Obj: ObjJson + Send + 'static,
{
    let loss = args.get("loss");
    let backend = native_backend_for(args, env, loss, fam)?;
    // Serving is pure inference: fastmath per GFNX_FASTMATH, KV cache on
    // (an O(T) decode win for causal-transformer checkpoints; a no-op for
    // MLPs and bidirectional models).
    let policy = backend.to_policy().with_fastmath(fastmath).with_kv_cache(true);
    let factory = move || Ok(Box::new(policy) as Box<dyn gfnx::runtime::BatchPolicy>);
    let reg = if telemetry::enabled() {
        Arc::clone(telemetry::global())
    } else {
        Arc::new(telemetry::Registry::new())
    };
    let cap = match args.get_usize("queue-cap") {
        0 => None,
        c => Some(c),
    };
    let svc = Arc::new(SamplerService::spawn_with(env.clone(), factory, reg, cap));
    let http = start_http(args, Arc::clone(&svc), fam.name, config)?;
    log_info!(
        "serving {config} ({}) at http://{} (queue cap {}, default deadline {} ms)",
        backend.net().cfg.describe_model(),
        http.local_addr(),
        cap.map(|c| c.to_string()).unwrap_or_else(|| "unbounded".to_string()),
        args.get_u64("deadline-ms"),
    );
    let dur = args.get_f64("serve-duration");
    if dur > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(dur));
        log_info!("serve duration elapsed; shutting down");
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    http.shutdown();
    let snap = svc.stats();
    log_info!(
        "served {} requests ({} completed, {} shed, {} timed out)",
        snap.requests_submitted,
        snap.requests_completed,
        snap.shed,
        snap.requests_timedout
    );
    drop(svc); // last Arc: closes the queue and joins the worker
    Ok(())
}

/// Bind the HTTP front end over a running service; writes `--addr-file`
/// for ephemeral-port discovery.
fn start_http<Obj: ObjJson + Send + 'static>(
    args: &Args,
    svc: Arc<SamplerService<Obj>>,
    family: &str,
    config: &str,
) -> anyhow::Result<HttpServer> {
    let mut cfg = HttpServerConfig::default();
    let dl = args.get_u64("deadline-ms");
    anyhow::ensure!(dl > 0, "--deadline-ms must be > 0");
    cfg.default_deadline = std::time::Duration::from_millis(dl);
    // The default already honors GFNX_STALL_WINDOW_MS; the flag, when
    // given, wins over both.
    let sw = args.get("stall-window-ms");
    if !sw.is_empty() {
        let ms: u64 = sw
            .parse()
            .map_err(|_| anyhow::anyhow!("--stall-window-ms must be an integer (got {sw:?})"))?;
        anyhow::ensure!(ms > 0, "--stall-window-ms must be > 0");
        cfg.stall_window = std::time::Duration::from_millis(ms);
    }
    let identity = ServeIdentity {
        family: family.to_string(),
        config: config.to_string(),
        model: args.get("model").to_string(),
    };
    let server = HttpServer::serve(args.get("listen"), svc, identity, cfg)?;
    let addr_file = args.get("addr-file");
    if !addr_file.is_empty() {
        std::fs::write(addr_file, server.local_addr().to_string())
            .map_err(|e| anyhow::anyhow!("writing --addr-file {addr_file}: {e}"))?;
    }
    Ok(server)
}

/// Engine topology from the CLI flags. `None` = the serial training loop
/// (`--actors 0`, the default, without `--sync`).
fn engine_config(args: &Args) -> anyhow::Result<Option<EngineConfig>> {
    let actors = args.get_usize("actors");
    let sync = args.get_bool("sync");
    if actors == 0 && !sync {
        return Ok(None);
    }
    let mut cfg = EngineConfig::new(
        if actors == 0 { 1 } else { actors },
        args.get_u64("publish-every"),
        args.get_u64("seed"),
    );
    cfg.queue_depth = args.get_usize("queue-depth");
    cfg.sync = sync;
    cfg.replay = replay_config(args)?;
    let save = args.get("save");
    if !save.is_empty() {
        cfg.checkpoint = Some(std::path::PathBuf::from(save));
    }
    Ok(Some(cfg))
}

/// Fresh (or `--resume`d) native backend shaped for `env`, running the
/// `--model` the CLI requested.
fn native_backend_for<E: VecEnv>(
    args: &Args,
    env: &E,
    loss: &str,
    fam: &'static EnvFamily,
) -> anyhow::Result<NativeBackend> {
    let want = native_config(args, env, loss, fam)?;
    let resume = args.get("resume");
    if resume.is_empty() {
        return NativeBackend::new(want, args.get_u64("seed"));
    }
    let backend = NativeBackend::load_checkpoint(std::path::Path::new(resume))?;
    let shape = backend.shape();
    gfnx::runtime::policy::check_env_token_shape(&env.spec(), &shape, backend.token_shape())
        .map_err(|e| anyhow::anyhow!("checkpoint {resume:?} was trained on a different env: {e}"))?;
    anyhow::ensure!(
        backend.loss_name() == loss,
        "checkpoint {resume:?} trains loss {:?}, but --loss {loss} was requested",
        backend.loss_name()
    );
    backend
        .ensure_model(&want)
        .map_err(|e| anyhow::anyhow!("cannot resume from {resume:?}: {e}"))?;
    let mut backend = backend;
    // Worker count is a property of the resuming host, not of the model:
    // a checkpoint from a 32-core box must not oversubscribe a 2-core one.
    // Model-state knobs (batch/hidden/lr/...) stay with the checkpoint.
    backend.config_mut().workers = match args.get_usize("workers") {
        0 => default_workers(),
        w => w,
    };
    log_info!(
        "resumed from {resume} at {} steps (Adam t = {}, batch {}, {})",
        backend.steps(),
        backend.adam_t(),
        shape.batch,
        backend.net().cfg.describe_model()
    );
    Ok(backend)
}

/// Backend selection + optional replay/engine wiring for one environment.
fn train_env<E>(
    args: &Args,
    config: &str,
    loss: &str,
    env: &E,
    extra: &ExtraSource<'_, E>,
    fam: &'static EnvFamily,
    fastmath: bool,
) -> anyhow::Result<()>
where
    E: VecEnv + Clone + Send + Sync + 'static,
    E::Obj: Send + 'static + ObjJson,
{
    let rc = run_config(config, loss);
    let iters = match args.get_u64("iters") {
        0 => rc.iters,
        n => n,
    };
    let seed = args.get_u64("seed");

    match args.get("backend") {
        "native" => {
            let backend = native_backend_for(args, env, loss, fam)?;
            if let Some(ecfg) = engine_config(args)? {
                return run_engine(
                    args, config, loss, env, extra, backend, rc.explore, iters, ecfg, fam.name,
                    fastmath,
                );
            }
            anyhow::ensure!(
                !args.get_bool("serve"),
                "--serve rides on the engine's snapshot publishes; pass --actors N (or --sync)"
            );
            let mut trainer = Trainer::with_backend(env, backend, seed, rc.explore)?;
            // Resume continues the exploration schedule where the
            // checkpoint stopped (a fresh backend reports 0 steps, so this
            // is a no-op for new runs); the engine path gets the same via
            // the hub's snapshot step counter.
            trainer.step = trainer.backend.steps();
            if let Some(cfg) = replay_config(args)? {
                trainer = trainer.with_replay(cfg)?;
            }
            run_train(&mut trainer, config, loss, iters, args, extra)?;
            let save = args.get("save");
            if !save.is_empty() {
                trainer.backend.save_checkpoint(std::path::Path::new(save))?;
                log_info!("saved checkpoint to {save}");
            }
            Ok(())
        }
        "xla" => {
            anyhow::ensure!(
                engine_config(args)?.is_none(),
                "--actors/--sync need owned policy snapshots; the xla backend's PJRT \
                 state is thread-local — use --backend native"
            );
            anyhow::ensure!(
                args.get("save").is_empty() && args.get("resume").is_empty(),
                "--save/--resume are native-backend checkpoints"
            );
            anyhow::ensure!(!args.get_bool("serve"), "--serve requires --backend native");
            anyhow::ensure!(
                args.get("model") == "mlp",
                "--model transformer is native-only; the xla artifacts bake in the MLP"
            );
            // The artifact manifest dictates batch/architecture; flag the
            // native-only knobs so a user doesn't misread the run.
            if args.get_usize("batch") != 16
                || args.get_usize("hidden") != 256
                || args.get_usize("layers") != 2
                || args.get_usize("workers") != 0
            {
                log_warn!(
                    "note: --batch/--hidden/--layers/--workers apply to the native \
                     backend only; the xla backend uses the artifact's baked-in shapes"
                );
            }
            let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
            let mut trainer = Trainer::new(env, &art, seed, rc.explore)?;
            if let Some(cfg) = replay_config(args)? {
                trainer = trainer.with_replay(cfg)?;
            }
            run_train(&mut trainer, config, loss, iters, args, extra)
        }
        other => anyhow::bail!("unknown backend {other:?} (native | xla)"),
    }
}

/// Asynchronous actor–learner training (CLI `--actors N [--serve] [--save]`).
#[allow(clippy::too_many_arguments)]
fn run_engine<E>(
    args: &Args,
    config: &str,
    loss: &str,
    env: &E,
    extra: &ExtraSource<'_, E>,
    mut backend: NativeBackend,
    explore: gfnx::coordinator::explore::EpsSchedule,
    iters: u64,
    cfg: EngineConfig,
    family: &str,
    fastmath: bool,
) -> anyhow::Result<()>
where
    E: VecEnv + Clone + Send + Sync + 'static,
    E::Obj: Send + 'static + ObjJson,
{
    let name = format!("{config}.{loss}");
    let svc = spawn_serve::<E>(args, env, backend.to_policy(), fastmath, family, config)?;
    log_info!(
        "training {name} on the async engine: {} actor(s), publish every {}, {}{}",
        cfg.actors,
        cfg.publish_every,
        if cfg.sync { "sync (deterministic)" } else { "async" },
        if svc.is_some() { ", serving live" } else { "" }
    );
    let stats = engine::train(env, &mut backend, explore, extra, &cfg, iters, |snap| {
        if let Some(svc) = &svc {
            svc.hot_swap(Box::new(snap.policy.clone().with_fastmath(fastmath)));
        }
        Ok(())
    })?;
    report_engine(&name, &stats, args)?;
    finish_serve(args, svc)
}

/// A live sampling service plus its (optional) HTTP front end, as spawned
/// for `train --serve [--listen]`.
struct ServeHandle<Obj: Send + 'static> {
    svc: Arc<SamplerService<Obj>>,
    http: Option<HttpServer>,
}

impl<Obj: Send + 'static> ServeHandle<Obj> {
    fn hot_swap(&self, policy: Box<dyn gfnx::runtime::BatchPolicy + Send>) {
        self.svc.hot_swap(policy);
    }
}

/// Spawn the live sampling service when `--serve` is set (the worker's env
/// is an owned clone; shared-reward envs share their `Arc`s, so EB-GFN's
/// improving J is visible to served rewards too). With `--listen` the
/// service additionally gets the HTTP front end, so network clients sample
/// from the improving policy while it trains.
fn spawn_serve<E>(
    args: &Args,
    env: &E,
    initial: NativePolicy,
    fastmath: bool,
    family: &str,
    config: &str,
) -> anyhow::Result<Option<ServeHandle<E::Obj>>>
where
    E: VecEnv + Clone + Send + Sync + 'static,
    E::Obj: Send + 'static + ObjJson,
{
    if !args.get_bool("serve") {
        anyhow::ensure!(
            args.get("listen").is_empty(),
            "--listen rides on the sampling service; pass --serve too"
        );
        return Ok(None);
    }
    // Serve-only fast accumulation: training dispatch above stays in the
    // deterministic f64 mode regardless of the env var.
    let initial = initial.with_fastmath(fastmath);
    let factory = move || Ok(Box::new(initial) as Box<dyn gfnx::runtime::BatchPolicy>);
    // Under --telemetry the service registers its serve.* metrics in the
    // global registry, so they ride the same export stream as the trainer's.
    let reg = if telemetry::enabled() {
        Arc::clone(telemetry::global())
    } else {
        Arc::new(telemetry::Registry::new())
    };
    let cap = match args.get_usize("queue-cap") {
        0 => None,
        c => Some(c),
    };
    let svc = Arc::new(SamplerService::spawn_with(env.clone(), factory, reg, cap));
    let http = if args.get("listen").is_empty() {
        None
    } else {
        Some(start_http(args, Arc::clone(&svc), family, config)?)
    };
    Ok(Some(ServeHandle { svc, http }))
}

/// Post-training serve probe: draw `--serve-samples` objects from the live
/// (hot-swapped) policy and print the service counters.
fn finish_serve<Obj: Send + 'static>(
    args: &Args,
    handle: Option<ServeHandle<Obj>>,
) -> anyhow::Result<()> {
    let Some(mut handle) = handle else { return Ok(()) };
    // Stop accepting network requests before the final probe; in-flight
    // HTTP requests resolve first because shutdown joins the handlers.
    if let Some(http) = handle.http.take() {
        let addr = http.local_addr();
        http.shutdown();
        log_info!("http front end at {addr} shut down");
    }
    let n = args.get_usize("serve-samples");
    let outs = handle.svc.sample(n, args.get_u64("seed") ^ 0x5EED_CAFE)?;
    let mean_lr =
        outs.iter().map(|o| o.log_reward).sum::<f64>() / outs.len().max(1) as f64;
    let snap = handle.svc.stats();
    log_info!(
        "served {} objects from the final policy: mean log-reward {mean_lr:.3}; \
         {} hot-swap(s) applied, {} rejected, occupancy {:.2}",
        outs.len(),
        snap.policy_swaps,
        snap.swaps_rejected,
        snap.occupancy()
    );
    // Swaps only apply at a policy dispatch, so a zero-sample probe cannot
    // have applied one — only treat "no swap" as a failure when the probe
    // actually dispatched.
    anyhow::ensure!(
        n == 0 || snap.policy_swaps > 0,
        "--serve ran but no snapshot was ever hot-swapped into the service"
    );
    drop(handle.svc); // last Arc: closes the queue and joins the worker
    Ok(())
}

/// Engine run summary: loss trajectory, staleness accounting, throughput.
fn report_engine(name: &str, stats: &EngineStats, args: &Args) -> anyhow::Result<()> {
    let mean = |v: &[f32]| {
        v.iter().map(|&x| x as f64).sum::<f64>() / v.len().max(1) as f64
    };
    let w = stats.losses.len().min(10);
    let head = mean(&stats.losses[..w]);
    let tail = mean(&stats.losses[stats.losses.len() - w..]);
    log_info!(
        "trained {name} for {} steps / {} publishes: loss {head:.4} (first {w}) -> \
         {tail:.4} (last {w}), logZ {:.3}",
        stats.iters, stats.publishes, stats.final_log_z
    );
    log_info!(
        "  throughput {:.1} batches/s; staleness mean {:.2} / max {} publishes; \
         batches per actor {:?}; {} replay batches",
        stats.batches_per_sec(),
        stats.mean_staleness(),
        stats.max_staleness(),
        stats.batches_per_actor,
        stats.replay_batches
    );
    if !args.get_bool("quiet") {
        let hist: Vec<String> = stats
            .staleness_hist
            .iter()
            .map(|(s, c)| format!("{s}:{c}"))
            .collect();
        log_info!("  staleness histogram [{}]", hist.join(" "));
    }
    Ok(())
}

/// Validate `BENCH_*.json` documents (CLI `check-bench f1.json f2.json …`;
/// CI runs this over every emitted bench file).
fn check_bench(args: &Args) -> anyhow::Result<()> {
    let pos = args.positional();
    let files = &pos[1..];
    anyhow::ensure!(
        !files.is_empty(),
        "usage: gfnx check-bench <BENCH_*.json> [more.json ...]"
    );
    for f in files {
        let text = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("reading {f}: {e}"))?;
        let name = check_bench_json(&text).map_err(|e| anyhow::anyhow!("{f}: {e}"))?;
        println!("ok {f} (bench {name:?}: parses, carries bench/meta/rows)");
    }
    Ok(())
}

fn native_config<E: VecEnv>(
    args: &Args,
    env: &E,
    loss: &str,
    fam: &'static EnvFamily,
) -> anyhow::Result<NativeConfig> {
    let workers = match args.get_usize("workers") {
        0 => default_workers(),
        w => w,
    };
    let cfg = NativeConfig::for_env(env, args.get_usize("batch"), loss)
        .with_hidden(args.get_usize("hidden"))
        .with_layers(args.get_usize("layers"))
        .with_workers(workers);
    match args.get("model") {
        "mlp" => Ok(cfg),
        "transformer" => {
            let arch = registry::transformer_arch(fam, &env.spec())?;
            Ok(cfg.with_model(gfnx::runtime::ModelSpec::Transformer(arch)))
        }
        other => anyhow::bail!("unknown model {other:?} (mlp | transformer)"),
    }
}

fn replay_config(args: &Args) -> anyhow::Result<Option<ReplayConfig>> {
    let cap = args.get_usize("replay-cap");
    if cap == 0 {
        return Ok(None);
    }
    let frac = args.get_f64("replay-frac");
    anyhow::ensure!(
        (0.0..=1.0).contains(&frac),
        "--replay-frac {frac} outside [0, 1]"
    );
    Ok(Some(ReplayConfig::new(cap, frac)))
}

/// The EB-GFN workload (paper §B.5, Table 8): joint CD learning of the
/// coupling matrix J_φ and TB training of the GFlowNet sampler, from an
/// MCMC dataset of the true model. Artifact-free on the native backend.
fn train_ebgfn(args: &Args, config: &str, n: usize, fastmath: bool) -> anyhow::Result<()> {
    let loss = args.get("loss");
    anyhow::ensure!(loss == "tb", "EB-GFN trains the GFlowNet with TB (got --loss {loss})");
    let sigma = args.get_f64("sigma");
    let seed = args.get_u64("seed");
    let iters = match args.get_u64("iters") {
        0 => run_config(config, "tb").iters,
        k => k,
    };
    let mut j_true = torus_adjacency(n);
    j_true.scale(sigma);
    let mut data_rng = Rng::new(seed);
    let dataset = generate_ising_dataset(n, sigma, args.get_usize("samples"), &mut data_rng);
    log_info!(
        "EB-GFN: {} MCMC samples from the {n}x{n} torus, sigma = {sigma}",
        dataset.len()
    );
    let reward = SharedIsingReward::zeros(n * n);
    let env = IsingEnv::lattice(n, reward.clone());

    anyhow::ensure!(
        args.get("save").is_empty() && args.get("resume").is_empty(),
        "--save/--resume are not supported with --ebgfn (J_φ is not serialized)"
    );
    anyhow::ensure!(
        args.get("model") == "mlp",
        "--ebgfn trains the MLP policy (ising has flat observations, no token \
         grid for --model transformer)"
    );
    match args.get("backend") {
        "native" => {
            let workers = match args.get_usize("workers") {
                0 => default_workers(),
                w => w,
            };
            let cfg = NativeConfig::for_env(&env, args.get_usize("batch"), "tb")
                .with_hidden(args.get_usize("hidden"))
                .with_layers(args.get_usize("layers"))
                .with_workers(workers);
            let backend = NativeBackend::new(cfg, seed)?;
            let mut trainer = EbGfnTrainer::with_backend(&env, backend, reward.clone(), dataset, seed)?;
            if let Some(ecfg) = engine_config(args)? {
                anyhow::ensure!(
                    ecfg.replay.is_none(),
                    "--replay-cap is not part of the EB-GFN Table 8 dynamics"
                );
                return run_ebgfn_engine(
                    args, config, iters, &j_true, &env, reward, &mut trainer, ecfg, fastmath,
                );
            }
            anyhow::ensure!(
                !args.get_bool("serve"),
                "--serve rides on the engine's snapshot publishes; pass --actors N"
            );
            run_ebgfn(trainer, config, iters, &j_true, args)
        }
        "xla" => {
            anyhow::ensure!(
                engine_config(args)?.is_none() && !args.get_bool("serve"),
                "--actors/--sync/--serve require --backend native"
            );
            let art = Artifact::load(&artifacts_dir(), &format!("{config}.tb"))?;
            let trainer = EbGfnTrainer::new(&env, &art, reward, dataset, seed)?;
            run_ebgfn(trainer, config, iters, &j_true, args)
        }
        other => anyhow::bail!("unknown backend {other:?} (native | xla)"),
    }
}

/// Asynchronous EB-GFN: actors stream forward rollouts from GFN snapshots;
/// the learner runs the alternating TB + CD update
/// ([`EbGfnLearner`]) and republishes. The J-recovery probe runs per
/// publish through the shared reward handle.
#[allow(clippy::too_many_arguments)]
fn run_ebgfn_engine(
    args: &Args,
    config: &str,
    iters: u64,
    j_true: &Mat,
    env: &IsingEnv<SharedIsingReward>,
    reward: SharedIsingReward,
    trainer: &mut EbGfnTrainer<'_, NativeBackend>,
    cfg: EngineConfig,
    fastmath: bool,
) -> anyhow::Result<()> {
    use gfnx::coordinator::ebgfn::neg_log_rmse_of;
    use gfnx::coordinator::explore::EpsSchedule;
    let name = format!("{config}.ebgfn");
    let init_nlr = neg_log_rmse_of(&reward, j_true);
    let svc = spawn_serve::<IsingEnv<SharedIsingReward>>(
        args,
        env,
        trainer.backend.to_policy(),
        fastmath,
        "ising",
        config,
    )?;
    log_info!(
        "training {name} on the async engine: {} actor(s), publish every {}{}",
        cfg.actors,
        cfg.publish_every,
        if svc.is_some() { ", serving live" } else { "" }
    );
    // The engine seeds actor 0 with `seed` verbatim, and the trainer was
    // built with Rng::new(seed) too — split the learner onto an
    // independent stream so the CD positive draws and MH uniforms are not
    // the very sequence that generated the actor's rollouts.
    trainer.rng = Rng::new(cfg.seed).split();
    let mut best_nlr = f64::NEG_INFINITY;
    let stats = {
        let mut learner = EbGfnLearner { tr: trainer };
        engine::run(
            env,
            &mut learner,
            EpsSchedule::none(),
            &ExtraSource::None,
            &cfg,
            iters,
            |snap| {
                best_nlr = best_nlr.max(neg_log_rmse_of(&reward, j_true));
                if let Some(svc) = &svc {
                    svc.hot_swap(Box::new(snap.policy.clone().with_fastmath(fastmath)));
                }
                Ok(())
            },
        )?
    };
    report_engine(&name, &stats, args)?;
    log_info!(
        "  -log RMSE(J) {init_nlr:.3} (init) -> {best_nlr:.3} (best); MH accept {:.2}",
        trainer.accept_rate
    );
    let w = (iters / 2).min(10) as usize;
    if w >= 1 && stats.losses.len() >= 2 * w {
        let mean = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        anyhow::ensure!(
            mean(&stats.losses[stats.losses.len() - w..]) < mean(&stats.losses[..w]),
            "GFN loss did not decrease"
        );
    }
    if iters > 0 {
        anyhow::ensure!(
            best_nlr > init_nlr,
            "J error did not decrease below its J = 0 starting point"
        );
    }
    finish_serve(args, svc)
}

fn run_ebgfn<B: Backend>(
    mut trainer: EbGfnTrainer<'_, B>,
    config: &str,
    iters: u64,
    j_true: &Mat,
    args: &Args,
) -> anyhow::Result<()> {
    let quiet = args.get_bool("quiet");
    let log_path = args.get("log");
    let name = format!("{config}.ebgfn");
    let mut log = if log_path.is_empty() {
        MetricsLog::stdout_only(&name)
    } else {
        MetricsLog::to_file(&name, std::path::Path::new(log_path))?
    };
    log_info!(
        "training {name} on the {} backend ({} iters, batch {})",
        trainer.backend.backend_name(),
        iters,
        trainer.backend.shape().batch
    );
    let init_nlr = trainer.neg_log_rmse(j_true);
    // Disjoint head/tail windows (≤ 10 iters each) so the loss-decrease
    // check below compares distinct phases even on short smoke runs.
    let w = (iters / 2).min(10);
    let (mut first_loss, mut last_loss) = (Vec::new(), Vec::new());
    let mut best_nlr = f64::NEG_INFINITY;
    for i in 0..iters {
        let stats = trainer.train_iter()?;
        anyhow::ensure!(stats.loss.is_finite(), "GFN loss diverged at iter {i}");
        let nlr = trainer.neg_log_rmse(j_true);
        best_nlr = best_nlr.max(nlr);
        if i < w {
            first_loss.push(stats.loss as f64);
        }
        if i + w >= iters {
            last_loss.push(stats.loss as f64);
        }
        if i % (iters / 8).max(1) == 0 {
            log.log(
                i,
                &[
                    ("loss", stats.loss as f64),
                    ("neg_log_rmse", nlr),
                    ("mh_accept", trainer.accept_rate),
                ],
            );
            if !quiet {
                log.progress(i, iters, &[("loss", stats.loss as f64), ("-logRMSE(J)", nlr)]);
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    log_info!(
        "trained {name} for {iters} iters on {}: GFN loss {:.3} (first {w}) -> {:.3} (last {w}); \
         -log RMSE(J) {init_nlr:.3} (init) -> {best_nlr:.3} (best)",
        trainer.backend.backend_name(),
        mean(&first_loss),
        mean(&last_loss)
    );
    if w >= 1 && iters >= 2 * w {
        anyhow::ensure!(
            mean(&last_loss) < mean(&first_loss),
            "GFN loss did not decrease"
        );
    }
    if iters > 0 {
        anyhow::ensure!(
            best_nlr > init_nlr,
            "J error did not decrease below its J = 0 starting point"
        );
    }
    Ok(())
}

fn run_train<E: VecEnv, B: Backend>(
    trainer: &mut Trainer<'_, E, B>,
    config: &str,
    loss: &str,
    iters: u64,
    args: &Args,
    extra: &ExtraSource<'_, E>,
) -> anyhow::Result<()> {
    let quiet = args.get_bool("quiet");
    let log_path = args.get("log");
    let name = format!("{config}.{loss}");
    let mut log = if log_path.is_empty() {
        MetricsLog::stdout_only(&name)
    } else {
        MetricsLog::to_file(&name, std::path::Path::new(log_path))?
    };
    log_info!(
        "training {name} on the {} backend ({} iters, batch {})",
        trainer.backend.backend_name(),
        iters,
        trainer.backend.shape().batch
    );
    let (mut first_window, mut last_window) = (Vec::new(), Vec::new());
    for i in 0..iters {
        let (stats, _objs) = trainer.train_iter(extra)?;
        anyhow::ensure!(stats.loss.is_finite(), "loss diverged at iter {i}");
        if i < 10 {
            first_window.push(stats.loss as f64);
        }
        if i + 10 >= iters {
            last_window.push(stats.loss as f64);
        }
        if i % 100 == 0 {
            log.log(i, &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)]);
            if !quiet {
                log.progress(
                    i,
                    iters,
                    &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)],
                );
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    log_info!(
        "trained {name} for {iters} iterations on {}: loss {:.4} (first 10 iters) -> {:.4} (last 10)",
        trainer.backend.backend_name(),
        mean(&first_window),
        mean(&last_window)
    );
    if trainer.replay_len() > 0 {
        log_info!("replay buffer holds {} high-reward objects", trainer.replay_len());
    }
    Ok(())
}
