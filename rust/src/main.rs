//! `gfnx` CLI — train, evaluate and benchmark GFlowNets against the AOT
//! artifacts (see README.md for the full workflow).
//!
//! Subcommands:
//!   train        --config <name> --loss <tb|db|subtb|fldb|mdb> [--iters N]
//!   list-configs
//!   info         --config <name> --loss <l>   (print the artifact manifest)

use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::VecEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::Artifact;
use gfnx::util::cli::Cli;
use gfnx::util::logging::MetricsLog;

fn main() {
    let cli = Cli::new(
        "gfnx",
        "Rust+JAX+Pallas GFlowNet benchmark infrastructure (gfnx reproduction)",
    )
    .positional("command", "train | list-configs | info")
    .flag("config", "hypergrid_small", "experiment config name")
    .flag("loss", "tb", "objective: tb | db | subtb | fldb | mdb")
    .flag("iters", "0", "iteration count (0 = preset default)")
    .flag("seed", "0", "rng seed")
    .flag("log", "", "JSONL metrics path (empty = stdout only)")
    .switch("quiet", "suppress progress lines");
    let args = cli.parse();
    let command = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "list-configs".to_string());

    let result = match command.as_str() {
        "list-configs" => {
            println!("configs (build artifacts via `make artifacts`):");
            for name in [
                "hypergrid_small",
                "hypergrid_2d_20",
                "hypergrid_4d_20",
                "hypergrid_8d_10",
                "bitseq_small",
                "bitseq_120_8",
                "tfbind8",
                "qm9",
                "amp_small",
                "amp",
                "phylo_small",
                "phylo_ds1..phylo_ds8",
                "bayesnet_d5",
                "ising_small",
                "ising_n9",
                "ising_n10",
            ] {
                println!("  {name}");
            }
            Ok(())
        }
        "info" => info(args.get("config"), args.get("loss")),
        "train" => train(
            args.get("config"),
            args.get("loss"),
            args.get_u64("iters"),
            args.get_u64("seed"),
            args.get("log"),
            args.get_bool("quiet"),
        ),
        other => Err(anyhow::anyhow!("unknown command {other:?}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn info(config: &str, loss: &str) -> anyhow::Result<()> {
    let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
    let m = &art.manifest;
    println!("artifact     {}", m.name);
    println!("obs_dim      {}", m.config.obs_dim);
    println!("n_actions    {}", m.config.n_actions);
    println!("n_bwd        {}", m.config.n_bwd_actions);
    println!("t_max        {}", m.config.t_max);
    println!("batch        {}", m.config.batch);
    println!("uniform_pb   {}", m.config.uniform_pb);
    println!("param leaves {}", m.n_params());
    let total: usize = m.params.iter().map(|p| p.element_count()).sum();
    println!("param count  {total}");
    Ok(())
}

/// Train the hypergrid family from the CLI (other families are exposed via
/// the examples and benches, which own their dataset generation).
fn train(
    config: &str,
    loss: &str,
    iters: u64,
    seed: u64,
    log_path: &str,
    quiet: bool,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        config.starts_with("hypergrid"),
        "the CLI trainer covers the hypergrid family; other environments \
         have dedicated example binaries (see examples/)"
    );
    let (d, h) = match config {
        "hypergrid_small" => (2, 8),
        "hypergrid_2d_20" => (2, 20),
        "hypergrid_4d_20" => (4, 20),
        "hypergrid_8d_10" => (8, 10),
        other => anyhow::bail!("unknown hypergrid config {other:?}"),
    };
    let env = gfnx::envs::hypergrid::HypergridEnv::new(d, h, HypergridReward::standard(h));
    let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
    let rc = run_config(config, loss);
    let iters = if iters == 0 { rc.iters } else { iters };
    let mut trainer = Trainer::new(&env, &art, seed, rc.explore)?;
    let mut log = if log_path.is_empty() {
        MetricsLog::stdout_only(&art.manifest.name)
    } else {
        MetricsLog::to_file(&art.manifest.name, std::path::Path::new(log_path))?
    };
    for i in 0..iters {
        let (stats, _objs) = trainer.train_iter(&ExtraSource::None)?;
        if i % 100 == 0 {
            log.log(i, &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)]);
            if !quiet {
                log.progress(
                    i,
                    iters,
                    &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)],
                );
            }
        }
    }
    println!("trained {} for {} iterations", art.manifest.name, iters);
    let _ = env.spec();
    Ok(())
}
