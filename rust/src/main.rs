//! `gfnx` CLI — train, evaluate and benchmark GFlowNets (see README.md for
//! the full workflow).
//!
//! Subcommands:
//!   train        --env <family> | --config <name>   (all nine families —
//!                see `list-configs`, generated from the env registry)
//!                --loss <tb|db|subtb|fldb|mdb>   (fldb/mdb on the envs
//!                                                 that supply extras)
//!                --backend <native|xla>  [--iters N] [--hidden H]
//!                [--layers L] [--workers W]
//!                [--replay-cap N --replay-frac P]   off-policy replay
//!                [--ebgfn [--sigma S] [--samples N]]   EB-GFN (ising only)
//!   list-configs
//!   info         --config <name> --loss <l>   (print the artifact manifest)
//!
//! The default `--backend native` trains end-to-end in pure Rust with no
//! AOT artifacts; `--backend xla` replays the fused AOT graphs (requires
//! `make artifacts` + the real xla-rs crate). `--env`/`--loss` coverage,
//! help strings and error messages all derive from
//! `coordinator::registry`, so adding an environment there updates every
//! CLI surface at once.

use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::ebgfn::{EbGfnTrainer, SharedIsingReward};
use gfnx::coordinator::registry::{self, EnvDriver, EnvFamily, EnvParams};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::{ReplayConfig, Trainer};
use gfnx::data::ising_mcmc::generate_ising_dataset;
use gfnx::envs::ising::IsingEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::ising::torus_adjacency;
use gfnx::runtime::{Artifact, Backend, NativeBackend, NativeConfig};
use gfnx::util::cli::{Args, Cli};
use gfnx::util::linalg::Mat;
use gfnx::util::logging::MetricsLog;
use gfnx::util::rng::Rng;
use gfnx::util::threadpool::default_workers;

fn main() {
    let env_help = registry::env_usage();
    let loss_help = registry::loss_usage();
    let cli = Cli::new(
        "gfnx",
        "Rust+JAX+Pallas GFlowNet benchmark infrastructure (gfnx reproduction)",
    )
    .positional("command", "train | list-configs | info")
    .flag(
        "config",
        "",
        "experiment config name (empty = the --env family's default, or \
         hypergrid_small; see list-configs)",
    )
    .flag("env", "", &env_help)
    .flag("loss", "tb", &loss_help)
    .flag("backend", "native", "training backend: native | xla")
    .flag("iters", "0", "iteration count (0 = preset default)")
    .flag("seed", "0", "rng seed (also seeds generated datasets)")
    .flag("batch", "16", "batch width (native backend)")
    .flag("hidden", "256", "MLP trunk width (native backend)")
    .flag("layers", "2", "MLP trunk depth (native backend)")
    .flag("workers", "0", "dispatch worker threads, 0 = all cores (native backend)")
    .flag("replay-cap", "0", "off-policy replay buffer capacity (0 = on-policy only)")
    .flag("replay-frac", "0.5", "probability an iteration trains on replay batches")
    .switch("ebgfn", "EB-GFN joint EBM+GFN training (ising only; paper Table 8)")
    .flag("sigma", "0.2", "true Ising coupling strength (ebgfn / ising reward)")
    .flag("samples", "2000", "EB-GFN dataset size (paper Table 9)")
    .flag("log", "", "JSONL metrics path (empty = stdout only)")
    .switch("quiet", "suppress progress lines");
    let args = cli.parse();
    let command = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "list-configs".to_string());

    let result = match command.as_str() {
        "list-configs" => {
            list_configs();
            Ok(())
        }
        "info" => {
            let config = match args.get("config") {
                "" => "hypergrid_small",
                c => c,
            };
            info(config, args.get("loss"))
        }
        "train" => train(&args),
        other => Err(anyhow::anyhow!("unknown command {other:?}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Registry-generated config listing: families, sized configs, losses.
fn list_configs() {
    println!("environment registry (native backend needs nothing; xla needs `make artifacts`):");
    for f in registry::families() {
        println!("  {} — {}", f.name, f.about);
        println!("      configs: {}", f.configs.join(" | "));
        println!("      losses:  {}", f.losses.join(" | "));
    }
}

fn info(config: &str, loss: &str) -> anyhow::Result<()> {
    let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
    let m = &art.manifest;
    println!("artifact     {}", m.name);
    println!("obs_dim      {}", m.config.obs_dim);
    println!("n_actions    {}", m.config.n_actions);
    println!("n_bwd        {}", m.config.n_bwd_actions);
    println!("t_max        {}", m.config.t_max);
    println!("batch        {}", m.config.batch);
    println!("uniform_pb   {}", m.config.uniform_pb);
    println!("param leaves {}", m.n_params());
    let total: usize = m.params.iter().map(|p| p.element_count()).sum();
    println!("param count  {total}");
    Ok(())
}

/// Train any registered family; env construction and loss gating are
/// registry-driven.
fn train(args: &Args) -> anyhow::Result<()> {
    let (env_flag, mut config_flag) = (args.get("env"), args.get("config"));
    if env_flag.is_empty() && config_flag.is_empty() {
        config_flag = "hypergrid_small"; // bare `train` keeps its old default
    }
    let (fam, config) = registry::resolve(env_flag, config_flag)?;
    let loss = args.get("loss");
    if args.get_bool("ebgfn") {
        anyhow::ensure!(
            fam.name == "ising",
            "--ebgfn is the Ising Table 8 workload; pass --env ising"
        );
        return train_ebgfn(args, &config, registry::ising_side(&config)?);
    }
    registry::check_loss(fam, loss)?;
    let params = EnvParams { seed: args.get_u64("seed"), sigma: args.get_f64("sigma") };
    registry::with_env(&config, params, TrainDriver { args })
}

/// The CLI's [`EnvDriver`]: backend selection + replay wiring + the train
/// loop, generic over whatever env the registry built.
struct TrainDriver<'a> {
    args: &'a Args,
}

impl EnvDriver for TrainDriver<'_> {
    type Out = ();

    fn drive<E>(
        self,
        env: &E,
        extra: &ExtraSource<'_, E>,
        _fam: &'static EnvFamily,
        config: &str,
    ) -> anyhow::Result<()>
    where
        E: VecEnv,
        E::State: Clone,
        E::Obj: PartialEq + std::fmt::Debug,
    {
        train_env(self.args, config, self.args.get("loss"), env, extra)
    }
}

/// Backend selection + optional replay wiring for one environment.
fn train_env<E: VecEnv>(
    args: &Args,
    config: &str,
    loss: &str,
    env: &E,
    extra: &ExtraSource<'_, E>,
) -> anyhow::Result<()> {
    let rc = run_config(config, loss);
    let iters = match args.get_u64("iters") {
        0 => rc.iters,
        n => n,
    };
    let seed = args.get_u64("seed");
    let replay = replay_config(args)?;

    match args.get("backend") {
        "native" => {
            let backend = NativeBackend::new(native_config(args, env, loss), seed)?;
            let mut trainer = Trainer::with_backend(env, backend, seed, rc.explore)?;
            if let Some(cfg) = replay {
                trainer = trainer.with_replay(cfg)?;
            }
            run_train(trainer, config, loss, iters, args, extra)
        }
        "xla" => {
            // The artifact manifest dictates batch/architecture; flag the
            // native-only knobs so a user doesn't misread the run.
            if args.get_usize("batch") != 16
                || args.get_usize("hidden") != 256
                || args.get_usize("layers") != 2
                || args.get_usize("workers") != 0
            {
                eprintln!(
                    "note: --batch/--hidden/--layers/--workers apply to the native \
                     backend only; the xla backend uses the artifact's baked-in shapes"
                );
            }
            let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
            let mut trainer = Trainer::new(env, &art, seed, rc.explore)?;
            if let Some(cfg) = replay {
                trainer = trainer.with_replay(cfg)?;
            }
            run_train(trainer, config, loss, iters, args, extra)
        }
        other => anyhow::bail!("unknown backend {other:?} (native | xla)"),
    }
}

fn native_config<E: VecEnv>(args: &Args, env: &E, loss: &str) -> NativeConfig {
    let workers = match args.get_usize("workers") {
        0 => default_workers(),
        w => w,
    };
    NativeConfig::for_env(env, args.get_usize("batch"), loss)
        .with_hidden(args.get_usize("hidden"))
        .with_layers(args.get_usize("layers"))
        .with_workers(workers)
}

fn replay_config(args: &Args) -> anyhow::Result<Option<ReplayConfig>> {
    let cap = args.get_usize("replay-cap");
    if cap == 0 {
        return Ok(None);
    }
    let frac = args.get_f64("replay-frac");
    anyhow::ensure!(
        (0.0..=1.0).contains(&frac),
        "--replay-frac {frac} outside [0, 1]"
    );
    Ok(Some(ReplayConfig::new(cap, frac)))
}

/// The EB-GFN workload (paper §B.5, Table 8): joint CD learning of the
/// coupling matrix J_φ and TB training of the GFlowNet sampler, from an
/// MCMC dataset of the true model. Artifact-free on the native backend.
fn train_ebgfn(args: &Args, config: &str, n: usize) -> anyhow::Result<()> {
    let loss = args.get("loss");
    anyhow::ensure!(loss == "tb", "EB-GFN trains the GFlowNet with TB (got --loss {loss})");
    let sigma = args.get_f64("sigma");
    let seed = args.get_u64("seed");
    let iters = match args.get_u64("iters") {
        0 => run_config(config, "tb").iters,
        k => k,
    };
    let mut j_true = torus_adjacency(n);
    j_true.scale(sigma);
    let mut data_rng = Rng::new(seed);
    let dataset = generate_ising_dataset(n, sigma, args.get_usize("samples"), &mut data_rng);
    println!(
        "EB-GFN: {} MCMC samples from the {n}x{n} torus, sigma = {sigma}",
        dataset.len()
    );
    let reward = SharedIsingReward::zeros(n * n);
    let env = IsingEnv::lattice(n, reward.clone());

    match args.get("backend") {
        "native" => {
            let backend = NativeBackend::new(native_config(args, &env, "tb"), seed)?;
            let trainer = EbGfnTrainer::with_backend(&env, backend, reward, dataset, seed)?;
            run_ebgfn(trainer, config, iters, &j_true, args)
        }
        "xla" => {
            let art = Artifact::load(&artifacts_dir(), &format!("{config}.tb"))?;
            let trainer = EbGfnTrainer::new(&env, &art, reward, dataset, seed)?;
            run_ebgfn(trainer, config, iters, &j_true, args)
        }
        other => anyhow::bail!("unknown backend {other:?} (native | xla)"),
    }
}

fn run_ebgfn<B: Backend>(
    mut trainer: EbGfnTrainer<'_, B>,
    config: &str,
    iters: u64,
    j_true: &Mat,
    args: &Args,
) -> anyhow::Result<()> {
    let quiet = args.get_bool("quiet");
    let log_path = args.get("log");
    let name = format!("{config}.ebgfn");
    let mut log = if log_path.is_empty() {
        MetricsLog::stdout_only(&name)
    } else {
        MetricsLog::to_file(&name, std::path::Path::new(log_path))?
    };
    println!(
        "training {name} on the {} backend ({} iters, batch {})",
        trainer.backend.backend_name(),
        iters,
        trainer.backend.shape().batch
    );
    let init_nlr = trainer.neg_log_rmse(j_true);
    // Disjoint head/tail windows (≤ 10 iters each) so the loss-decrease
    // check below compares distinct phases even on short smoke runs.
    let w = (iters / 2).min(10);
    let (mut first_loss, mut last_loss) = (Vec::new(), Vec::new());
    let mut best_nlr = f64::NEG_INFINITY;
    for i in 0..iters {
        let stats = trainer.train_iter()?;
        anyhow::ensure!(stats.loss.is_finite(), "GFN loss diverged at iter {i}");
        let nlr = trainer.neg_log_rmse(j_true);
        best_nlr = best_nlr.max(nlr);
        if i < w {
            first_loss.push(stats.loss as f64);
        }
        if i + w >= iters {
            last_loss.push(stats.loss as f64);
        }
        if i % (iters / 8).max(1) == 0 {
            log.log(
                i,
                &[
                    ("loss", stats.loss as f64),
                    ("neg_log_rmse", nlr),
                    ("mh_accept", trainer.accept_rate),
                ],
            );
            if !quiet {
                log.progress(i, iters, &[("loss", stats.loss as f64), ("-logRMSE(J)", nlr)]);
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "trained {name} for {iters} iters on {}: GFN loss {:.3} (first {w}) -> {:.3} (last {w}); \
         -log RMSE(J) {init_nlr:.3} (init) -> {best_nlr:.3} (best)",
        trainer.backend.backend_name(),
        mean(&first_loss),
        mean(&last_loss)
    );
    if w >= 1 && iters >= 2 * w {
        anyhow::ensure!(
            mean(&last_loss) < mean(&first_loss),
            "GFN loss did not decrease"
        );
    }
    if iters > 0 {
        anyhow::ensure!(
            best_nlr > init_nlr,
            "J error did not decrease below its J = 0 starting point"
        );
    }
    Ok(())
}

fn run_train<E: VecEnv, B: Backend>(
    mut trainer: Trainer<'_, E, B>,
    config: &str,
    loss: &str,
    iters: u64,
    args: &Args,
    extra: &ExtraSource<'_, E>,
) -> anyhow::Result<()> {
    let quiet = args.get_bool("quiet");
    let log_path = args.get("log");
    let name = format!("{config}.{loss}");
    let mut log = if log_path.is_empty() {
        MetricsLog::stdout_only(&name)
    } else {
        MetricsLog::to_file(&name, std::path::Path::new(log_path))?
    };
    println!(
        "training {name} on the {} backend ({} iters, batch {})",
        trainer.backend.backend_name(),
        iters,
        trainer.backend.shape().batch
    );
    let (mut first_window, mut last_window) = (Vec::new(), Vec::new());
    for i in 0..iters {
        let (stats, _objs) = trainer.train_iter(extra)?;
        anyhow::ensure!(stats.loss.is_finite(), "loss diverged at iter {i}");
        if i < 10 {
            first_window.push(stats.loss as f64);
        }
        if i + 10 >= iters {
            last_window.push(stats.loss as f64);
        }
        if i % 100 == 0 {
            log.log(i, &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)]);
            if !quiet {
                log.progress(
                    i,
                    iters,
                    &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)],
                );
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "trained {name} for {iters} iterations on {}: loss {:.4} (first 10 iters) -> {:.4} (last 10)",
        trainer.backend.backend_name(),
        mean(&first_window),
        mean(&last_window)
    );
    if trainer.replay_len() > 0 {
        println!("replay buffer holds {} high-reward objects", trainer.replay_len());
    }
    Ok(())
}
