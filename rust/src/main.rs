//! `gfnx` CLI — train, evaluate and benchmark GFlowNets (see README.md for
//! the full workflow).
//!
//! Subcommands:
//!   train        --env hypergrid | --config <name>
//!                --loss <tb|db|subtb|fldb|mdb>
//!                --backend <native|xla>  [--iters N] [--hidden H]
//!                [--layers L] [--workers W]
//!   list-configs
//!   info         --config <name> --loss <l>   (print the artifact manifest)
//!
//! The default `--backend native` trains end-to-end in pure Rust with no
//! AOT artifacts; `--backend xla` replays the fused AOT graphs (requires
//! `make artifacts` + the real xla-rs crate).

use gfnx::coordinator::config::{artifacts_dir, run_config};
use gfnx::coordinator::rollout::ExtraSource;
use gfnx::coordinator::trainer::Trainer;
use gfnx::envs::hypergrid::HypergridEnv;
use gfnx::envs::VecEnv;
use gfnx::reward::hypergrid::HypergridReward;
use gfnx::runtime::{Artifact, Backend, NativeBackend, NativeConfig};
use gfnx::util::cli::Cli;
use gfnx::util::logging::MetricsLog;
use gfnx::util::threadpool::default_workers;

fn main() {
    let cli = Cli::new(
        "gfnx",
        "Rust+JAX+Pallas GFlowNet benchmark infrastructure (gfnx reproduction)",
    )
    .positional("command", "train | list-configs | info")
    .flag("config", "hypergrid_small", "experiment config name")
    .flag("env", "", "environment family shorthand (hypergrid → hypergrid_small)")
    .flag("loss", "tb", "objective: tb | db | subtb | fldb | mdb")
    .flag("backend", "native", "training backend: native | xla")
    .flag("iters", "0", "iteration count (0 = preset default)")
    .flag("seed", "0", "rng seed")
    .flag("batch", "16", "batch width (native backend)")
    .flag("hidden", "256", "MLP trunk width (native backend)")
    .flag("layers", "2", "MLP trunk depth (native backend)")
    .flag("workers", "0", "dispatch worker threads, 0 = all cores (native backend)")
    .flag("log", "", "JSONL metrics path (empty = stdout only)")
    .switch("quiet", "suppress progress lines");
    let args = cli.parse();
    let command = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "list-configs".to_string());

    let result = match command.as_str() {
        "list-configs" => {
            println!("configs (xla backend needs `make artifacts`; native needs nothing):");
            for name in [
                "hypergrid_small",
                "hypergrid_2d_20",
                "hypergrid_4d_20",
                "hypergrid_8d_10",
                "bitseq_small",
                "bitseq_120_8",
                "tfbind8",
                "qm9",
                "amp_small",
                "amp",
                "phylo_small",
                "phylo_ds1..phylo_ds8",
                "bayesnet_d5",
                "ising_small",
                "ising_n9",
                "ising_n10",
            ] {
                println!("  {name}");
            }
            Ok(())
        }
        "info" => info(args.get("config"), args.get("loss")),
        "train" => train(&args),
        other => Err(anyhow::anyhow!("unknown command {other:?}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn info(config: &str, loss: &str) -> anyhow::Result<()> {
    let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
    let m = &art.manifest;
    println!("artifact     {}", m.name);
    println!("obs_dim      {}", m.config.obs_dim);
    println!("n_actions    {}", m.config.n_actions);
    println!("n_bwd        {}", m.config.n_bwd_actions);
    println!("t_max        {}", m.config.t_max);
    println!("batch        {}", m.config.batch);
    println!("uniform_pb   {}", m.config.uniform_pb);
    println!("param leaves {}", m.n_params());
    let total: usize = m.params.iter().map(|p| p.element_count()).sum();
    println!("param count  {total}");
    Ok(())
}

/// Resolve `--env`/`--config` into a concrete config name.
fn resolve_config(args: &gfnx::util::cli::Args) -> anyhow::Result<String> {
    let env = args.get("env");
    if env.is_empty() {
        return Ok(args.get("config").to_string());
    }
    Ok(match env {
        "hypergrid" => "hypergrid_small".to_string(),
        other if other.starts_with("hypergrid") => other.to_string(),
        other => anyhow::bail!(
            "the CLI trainer covers the hypergrid family (got --env {other:?}); \
             other environments have dedicated example binaries (see examples/)"
        ),
    })
}

/// Train the hypergrid family from the CLI (other families are exposed via
/// the examples and benches, which own their dataset generation).
fn train(args: &gfnx::util::cli::Args) -> anyhow::Result<()> {
    let config = resolve_config(args)?;
    let loss = args.get("loss");
    anyhow::ensure!(
        config.starts_with("hypergrid"),
        "the CLI trainer covers the hypergrid family; other environments \
         have dedicated example binaries (see examples/)"
    );
    let (d, h) = match config.as_str() {
        "hypergrid_small" => (2, 8),
        "hypergrid_2d_20" => (2, 20),
        "hypergrid_4d_20" => (4, 20),
        "hypergrid_8d_10" => (8, 10),
        other => anyhow::bail!("unknown hypergrid config {other:?}"),
    };
    let env = HypergridEnv::new(d, h, HypergridReward::standard(h));
    let rc = run_config(&config, loss);
    let iters = match args.get_u64("iters") {
        0 => rc.iters,
        n => n,
    };
    let seed = args.get_u64("seed");

    match args.get("backend") {
        "native" => {
            let workers = match args.get_usize("workers") {
                0 => default_workers(),
                w => w,
            };
            let cfg = NativeConfig::for_env(&env, args.get_usize("batch"), loss)
                .with_hidden(args.get_usize("hidden"))
                .with_layers(args.get_usize("layers"))
                .with_workers(workers);
            let backend = NativeBackend::new(cfg, seed)?;
            let trainer = Trainer::with_backend(&env, backend, seed, rc.explore)?;
            run_train(trainer, &config, loss, iters, args)
        }
        "xla" => {
            // The artifact manifest dictates batch/architecture; flag the
            // native-only knobs so a user doesn't misread the run.
            if args.get_usize("batch") != 16
                || args.get_usize("hidden") != 256
                || args.get_usize("layers") != 2
                || args.get_usize("workers") != 0
            {
                eprintln!(
                    "note: --batch/--hidden/--layers/--workers apply to the native \
                     backend only; the xla backend uses the artifact's baked-in shapes"
                );
            }
            let art = Artifact::load(&artifacts_dir(), &format!("{config}.{loss}"))?;
            let trainer = Trainer::new(&env, &art, seed, rc.explore)?;
            run_train(trainer, &config, loss, iters, args)
        }
        other => anyhow::bail!("unknown backend {other:?} (native | xla)"),
    }
}

fn run_train<E: VecEnv, B: Backend>(
    mut trainer: Trainer<'_, E, B>,
    config: &str,
    loss: &str,
    iters: u64,
    args: &gfnx::util::cli::Args,
) -> anyhow::Result<()> {
    let quiet = args.get_bool("quiet");
    let log_path = args.get("log");
    let name = format!("{config}.{loss}");
    let mut log = if log_path.is_empty() {
        MetricsLog::stdout_only(&name)
    } else {
        MetricsLog::to_file(&name, std::path::Path::new(log_path))?
    };
    println!(
        "training {name} on the {} backend ({} iters, batch {})",
        trainer.backend.backend_name(),
        iters,
        trainer.backend.shape().batch
    );
    let (mut first_window, mut last_window) = (Vec::new(), Vec::new());
    for i in 0..iters {
        let (stats, _objs) = trainer.train_iter(&ExtraSource::None)?;
        anyhow::ensure!(stats.loss.is_finite(), "loss diverged at iter {i}");
        if i < 10 {
            first_window.push(stats.loss as f64);
        }
        if i + 10 >= iters {
            last_window.push(stats.loss as f64);
        }
        if i % 100 == 0 {
            log.log(i, &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)]);
            if !quiet {
                log.progress(
                    i,
                    iters,
                    &[("loss", stats.loss as f64), ("logZ", stats.log_z as f64)],
                );
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "trained {name} for {iters} iterations on {}: loss {:.4} (first 10 iters) -> {:.4} (last 10)",
        trainer.backend.backend_name(),
        mean(&first_window),
        mean(&last_window)
    );
    Ok(())
}
