//! Fitch small-parsimony scoring for phylogenetic trees (gfnx env #6,
//! PhyloGFN setting): M(x) = minimum number of mutations needed to explain
//! the observed species sequences under tree topology x, computed by the
//! Fitch algorithm. The reward is the Gibbs form R(x) = exp((C − M(x))/α).

use super::RewardModule;

/// A rooted binary tree over species indices, with children canonically
/// ordered by minimum leaf index (so equal topologies compare equal).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum PhyloTree {
    Leaf(u16),
    Node(Box<PhyloTree>, Box<PhyloTree>),
}

impl PhyloTree {
    /// Canonicalizing constructor: orders children by min leaf.
    pub fn node(a: PhyloTree, b: PhyloTree) -> PhyloTree {
        if a.min_leaf() <= b.min_leaf() {
            PhyloTree::Node(Box::new(a), Box::new(b))
        } else {
            PhyloTree::Node(Box::new(b), Box::new(a))
        }
    }

    pub fn min_leaf(&self) -> u16 {
        match self {
            PhyloTree::Leaf(i) => *i,
            PhyloTree::Node(a, b) => a.min_leaf().min(b.min_leaf()),
        }
    }

    pub fn leaf_count(&self) -> usize {
        match self {
            PhyloTree::Leaf(_) => 1,
            PhyloTree::Node(a, b) => a.leaf_count() + b.leaf_count(),
        }
    }

    /// Bitmask of leaves under this tree (≤ 64 species).
    pub fn leaf_set(&self) -> u64 {
        match self {
            PhyloTree::Leaf(i) => 1u64 << i,
            PhyloTree::Node(a, b) => a.leaf_set() | b.leaf_set(),
        }
    }
}

/// Species alignment: `seqs[s][site] ∈ 0..4` (nucleotides).
#[derive(Clone, Debug)]
pub struct Alignment {
    pub seqs: Vec<Vec<u8>>,
    pub n_sites: usize,
}

impl Alignment {
    pub fn new(seqs: Vec<Vec<u8>>) -> Self {
        let n_sites = seqs.first().map_or(0, |s| s.len());
        assert!(seqs.iter().all(|s| s.len() == n_sites));
        assert!(seqs.iter().all(|s| s.iter().all(|&c| c < 4)));
        Alignment { seqs, n_sites }
    }

    pub fn n_species(&self) -> usize {
        self.seqs.len()
    }

    /// Fitch state set (4-bit mask) of species `s` at `site`.
    #[inline]
    pub fn leaf_mask(&self, s: usize, site: usize) -> u8 {
        1u8 << self.seqs[s][site]
    }
}

/// Fitch pass over one tree: returns (per-site state masks, mutations).
pub fn fitch(tree: &PhyloTree, aln: &Alignment) -> (Vec<u8>, u32) {
    match tree {
        PhyloTree::Leaf(i) => {
            let masks = (0..aln.n_sites).map(|s| aln.leaf_mask(*i as usize, s)).collect();
            (masks, 0)
        }
        PhyloTree::Node(a, b) => {
            let (ma, ca) = fitch(a, aln);
            let (mb, cb) = fitch(b, aln);
            let mut muts = ca + cb;
            let mut masks = Vec::with_capacity(aln.n_sites);
            for s in 0..aln.n_sites {
                let inter = ma[s] & mb[s];
                if inter == 0 {
                    masks.push(ma[s] | mb[s]);
                    muts += 1;
                } else {
                    masks.push(inter);
                }
            }
            (masks, muts)
        }
    }
}

/// Parsimony score M(x) of a complete tree.
pub fn parsimony_score(tree: &PhyloTree, aln: &Alignment) -> u32 {
    fitch(tree, aln).1
}

/// Gibbs parsimony reward: log R(x) = (C − M(x)) / α (paper §B.3).
#[derive(Clone, Debug)]
pub struct ParsimonyReward {
    pub alignment: Alignment,
    /// Stabilizing constant C.
    pub c: f64,
    /// Temperature α.
    pub alpha: f64,
}

impl RewardModule<PhyloTree> for ParsimonyReward {
    fn log_reward(&self, obj: &PhyloTree) -> f64 {
        (self.c - parsimony_score(obj, &self.alignment) as f64) / self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aln3() -> Alignment {
        // Species 0: AAAA, 1: AACC, 2: CCCC (A=0, C=1).
        Alignment::new(vec![
            vec![0, 0, 0, 0],
            vec![0, 0, 1, 1],
            vec![1, 1, 1, 1],
        ])
    }

    #[test]
    fn fitch_hand_case() {
        let aln = aln3();
        // ((0,1),2): join 0,1 → sites 2,3 disagree (2 muts), then with 2 →
        // sites 0,1 disagree (2 muts) but sites 2,3 intersect ⇒ total 4.
        let t = PhyloTree::node(
            PhyloTree::node(PhyloTree::Leaf(0), PhyloTree::Leaf(1)),
            PhyloTree::Leaf(2),
        );
        assert_eq!(parsimony_score(&t, &aln), 4);
        // ((1,2),0): join 1,2 → sites 0,1 disagree (2), with 0 → sites 2,3
        // disagree (2) ⇒ also 4.
        let t2 = PhyloTree::node(
            PhyloTree::node(PhyloTree::Leaf(1), PhyloTree::Leaf(2)),
            PhyloTree::Leaf(0),
        );
        assert_eq!(parsimony_score(&t2, &aln), 4);
    }

    #[test]
    fn identical_leaves_need_no_mutations() {
        let aln = Alignment::new(vec![vec![2, 3, 1], vec![2, 3, 1], vec![2, 3, 1]]);
        let t = PhyloTree::node(
            PhyloTree::node(PhyloTree::Leaf(0), PhyloTree::Leaf(1)),
            PhyloTree::Leaf(2),
        );
        assert_eq!(parsimony_score(&t, &aln), 0);
    }

    #[test]
    fn canonical_ordering_makes_topologies_equal() {
        let a = PhyloTree::node(PhyloTree::Leaf(1), PhyloTree::Leaf(0));
        let b = PhyloTree::node(PhyloTree::Leaf(0), PhyloTree::Leaf(1));
        assert_eq!(a, b);
        let t1 = PhyloTree::node(a, PhyloTree::Leaf(2));
        let t2 = PhyloTree::node(PhyloTree::Leaf(2), b);
        assert_eq!(t1, t2);
    }

    #[test]
    fn parsimony_invariant_under_child_order() {
        let aln = aln3();
        let t1 = PhyloTree::node(
            PhyloTree::node(PhyloTree::Leaf(0), PhyloTree::Leaf(2)),
            PhyloTree::Leaf(1),
        );
        let t2 = PhyloTree::node(
            PhyloTree::Leaf(1),
            PhyloTree::node(PhyloTree::Leaf(2), PhyloTree::Leaf(0)),
        );
        assert_eq!(parsimony_score(&t1, &aln), parsimony_score(&t2, &aln));
    }

    #[test]
    fn reward_is_gibbs_form() {
        let r = ParsimonyReward { alignment: aln3(), c: 10.0, alpha: 4.0 };
        let t = PhyloTree::node(
            PhyloTree::node(PhyloTree::Leaf(0), PhyloTree::Leaf(1)),
            PhyloTree::Leaf(2),
        );
        let lr = RewardModule::log_reward(&r, &t);
        assert!((lr - (10.0 - 4.0) / 4.0).abs() < 1e-12);
    }

    #[test]
    fn leaf_set_and_min_leaf() {
        let t = PhyloTree::node(
            PhyloTree::node(PhyloTree::Leaf(3), PhyloTree::Leaf(1)),
            PhyloTree::Leaf(5),
        );
        assert_eq!(t.leaf_set(), 0b101010);
        assert_eq!(t.min_leaf(), 1);
        assert_eq!(t.leaf_count(), 3);
    }
}
