//! Reward modules, decoupled from environment dynamics (gfnx §2).
//!
//! A reward module scores *completed objects* (the `Obj` type of a
//! [`crate::envs::VecEnv`]) in log-space. Decoupling rewards from dynamics
//! lets callers swap reward families — or learn the reward online, as the
//! EB-GFN trainer does for the Ising model — without touching env logic.

pub mod hypergrid;
pub mod hamming;
pub mod proxy;
pub mod parsimony;
pub mod bge;
pub mod lingauss;
pub mod ising;

/// Scores completed objects in log-space.
pub trait RewardModule<O>: Send + Sync {
    /// log R(x) of a completed object. Must be finite (gfnx rewards are
    /// strictly positive; use an `r_min` floor where the source reward can
    /// reach zero).
    fn log_reward(&self, obj: &O) -> f64;
}

/// Blanket impl so `&R` and boxes can be passed around freely.
impl<O, R: RewardModule<O> + ?Sized> RewardModule<O> for &R {
    fn log_reward(&self, obj: &O) -> f64 {
        (**self).log_reward(obj)
    }
}

impl<O, R: RewardModule<O> + ?Sized> RewardModule<O> for Box<R> {
    fn log_reward(&self, obj: &O) -> f64 {
        (**self).log_reward(obj)
    }
}
