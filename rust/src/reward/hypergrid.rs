//! Hypergrid reward (Bengio et al. 2021, eq. (8) of the gfnx paper):
//!
//! R(s) = R0 + R1·∏ᵢ 𝕀[0.25 < |sᵢ/(H−1) − 0.5|]
//!           + R2·∏ᵢ 𝕀[0.3 < |sᵢ/(H−1) − 0.5| < 0.4]
//!
//! High reward concentrates in 2^d regions near the corners of the grid.

use super::RewardModule;

/// Parameterized hypergrid reward over coordinate vectors.
#[derive(Clone, Copy, Debug)]
pub struct HypergridReward {
    pub r0: f64,
    pub r1: f64,
    pub r2: f64,
    /// Side length H (coordinates live in {0, …, H−1}).
    pub side: usize,
}

impl HypergridReward {
    /// The standard parameters used in the paper's experiments
    /// (R0 = 1e-3, R1 = 0.5, R2 = 2.0).
    pub fn standard(side: usize) -> Self {
        HypergridReward { r0: 1e-3, r1: 0.5, r2: 2.0, side }
    }

    /// The "easy" variant from the gfnx docs (larger base reward, flatter
    /// landscape — handy for quick tests).
    pub fn easy(side: usize) -> Self {
        HypergridReward { r0: 0.1, r1: 0.5, r2: 2.0, side }
    }

    /// Raw (non-log) reward.
    pub fn reward(&self, coords: &[i32]) -> f64 {
        let h1 = (self.side - 1) as f64;
        let mut in1 = true;
        let mut in2 = true;
        for &c in coords {
            let x = (c as f64 / h1 - 0.5).abs();
            in1 &= x > 0.25;
            in2 &= x > 0.3 && x < 0.4;
        }
        self.r0 + if in1 { self.r1 } else { 0.0 } + if in2 { self.r2 } else { 0.0 }
    }
}

impl RewardModule<Vec<i32>> for HypergridReward {
    fn log_reward(&self, obj: &Vec<i32>) -> f64 {
        self.reward(obj).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reward::RewardModule;

    #[test]
    fn corners_are_high_reward() {
        let r = HypergridReward::standard(20);
        // Corner (0, 0): |0/19 - 0.5| = 0.5 > 0.25 → R1 region but not R2.
        assert!((r.reward(&[0, 0]) - (1e-3 + 0.5)).abs() < 1e-12);
        // Center (10, 10): |10/19-0.5| ≈ 0.026 → base reward only.
        assert!((r.reward(&[10, 10]) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn r2_band() {
        let r = HypergridReward::standard(20);
        // s=3: |3/19 - 0.5| = 0.342 → in (0.3, 0.4) and > 0.25 → R1 + R2.
        let v = r.reward(&[3, 3]);
        assert!((v - (1e-3 + 0.5 + 2.0)).abs() < 1e-12, "{v}");
    }

    #[test]
    fn mixed_dims_break_products() {
        let r = HypergridReward::standard(20);
        // One coordinate in the center kills both products.
        assert!((r.reward(&[0, 10]) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn log_reward_is_ln() {
        let r = HypergridReward::standard(20);
        let c = vec![0, 0];
        assert!((RewardModule::log_reward(&r, &c) - r.reward(&c).ln()).abs() < 1e-12);
    }

    #[test]
    fn total_mass_matches_bruteforce_2d() {
        // Sanity: enumerate a 2-d grid and check the number of R2 cells is
        // symmetric and positive for H=20.
        let r = HypergridReward::standard(20);
        let mut n2 = 0;
        for a in 0..20 {
            for b in 0..20 {
                let v = r.reward(&[a, b]);
                if v > 2.0 {
                    n2 += 1;
                }
            }
        }
        // 0.3 < |s/19-0.5| < 0.4 holds for s ∈ {2,3,16,17} → 4 per dim → 16 cells.
        assert_eq!(n2, 16);
    }
}
