//! Ising energy reward (Zhang et al. 2022 EB-GFN setting; gfnx env #8):
//!
//!   E_J(x) = −xᵀ J x,   log R(x) = −E_J(x) = xᵀ J x
//!
//! with J ∈ R^{D×D} symmetric (the paper uses J = σ·A_N for a toroidal
//! lattice adjacency A_N). The module also exposes the energy on its own
//! for the EB-GFN trainer, which learns J.

use super::RewardModule;
use crate::util::linalg::Mat;

/// Toroidal N×N lattice adjacency matrix (D = N² sites; each site has 4
/// neighbours; for N = 2 parallel edges collapse, matching the paper's
/// definition of A_N as a 0/1 adjacency matrix).
pub fn torus_adjacency(n: usize) -> Mat {
    let d = n * n;
    let mut a = Mat::zeros(d, d);
    let idx = |r: usize, c: usize| (r % n) * n + (c % n);
    for r in 0..n {
        for c in 0..n {
            let i = idx(r, c);
            for (dr, dc) in [(0usize, 1usize), (1, 0)] {
                let j = idx(r + dr, c + dc);
                if i != j {
                    a.set(i, j, 1.0);
                    a.set(j, i, 1.0);
                }
            }
        }
    }
    a
}

/// Energy E_J(x) = −xᵀJx for spins x ∈ {−1,+1}^D.
pub fn ising_energy(j: &Mat, x: &[i8]) -> f64 {
    debug_assert_eq!(j.rows, x.len());
    let mut s = 0.0;
    for r in 0..j.rows {
        let xr = x[r] as f64;
        if xr == 0.0 {
            continue;
        }
        let row = j.row(r);
        let mut acc = 0.0;
        for c in 0..j.cols {
            acc += row[c] * x[c] as f64;
        }
        s += xr * acc;
    }
    -s
}

/// Fixed-J Ising reward over full spin configurations.
#[derive(Clone, Debug)]
pub struct IsingReward {
    pub j: Mat,
}

impl IsingReward {
    /// J = σ·A_N on the N×N torus.
    pub fn torus(n: usize, sigma: f64) -> Self {
        let mut j = torus_adjacency(n);
        j.scale(sigma);
        IsingReward { j }
    }

    pub fn energy(&self, x: &[i8]) -> f64 {
        ising_energy(&self.j, x)
    }
}

impl RewardModule<Vec<i8>> for IsingReward {
    fn log_reward(&self, obj: &Vec<i8>) -> f64 {
        -self.energy(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torus_has_degree_four() {
        for n in [3usize, 4, 5] {
            let a = torus_adjacency(n);
            for i in 0..n * n {
                let deg: f64 = a.row(i).iter().sum();
                assert_eq!(deg, 4.0, "site {i} of {n}x{n}");
            }
            // Symmetric.
            for i in 0..n * n {
                for j in 0..n * n {
                    assert_eq!(a.get(i, j), a.get(j, i));
                }
            }
        }
    }

    #[test]
    fn energy_hand_case() {
        // 3x3 torus, all spins +1: E = -Σ_ij J_ij = -(#directed neighbor
        // pairs) = -(9 sites × 4 neighbors) = -36σ with σ=1.
        let r = IsingReward::torus(3, 1.0);
        let x = vec![1i8; 9];
        assert_eq!(r.energy(&x), -36.0);
        // Flipping all spins leaves the energy invariant (Z2 symmetry).
        let y = vec![-1i8; 9];
        assert_eq!(r.energy(&y), -36.0);
    }

    #[test]
    fn antiferro_prefers_alternating() {
        // On a 4x4 torus with σ < 0, the checkerboard beats all-up.
        let r = IsingReward::torus(4, -0.5);
        let all_up = vec![1i8; 16];
        let mut check = vec![0i8; 16];
        for row in 0..4 {
            for c in 0..4 {
                check[row * 4 + c] = if (row + c) % 2 == 0 { 1 } else { -1 };
            }
        }
        assert!(r.energy(&check) < r.energy(&all_up));
    }

    #[test]
    fn log_reward_is_negative_energy() {
        use crate::reward::RewardModule;
        let r = IsingReward::torus(3, 0.3);
        let x: Vec<i8> = (0..9).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        assert_eq!(RewardModule::log_reward(&r, &x), -r.energy(&x));
    }
}
