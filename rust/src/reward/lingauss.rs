//! Linear-Gaussian marginal likelihood local scores (Nishikawa-Toomey et
//! al. 2022), with the modular decomposition of paper eq. (12):
//!
//!   log R(G) = Σ_j LocalScore(X_j | Pa_G(X_j))
//!
//! For node j with parent data matrix X (N×p), prior w ~ N(0, σ_w² I) and
//! noise ε ~ N(0, σ² I):
//!
//!   x_j | X ~ N(0, σ² I_N + σ_w² X Xᵀ)
//!
//! evaluated with the Woodbury/matrix-determinant identities in the p×p
//! form so the cost is O(N p² + p³) per family.

use crate::envs::bayesnet::BayesNetEnv;
use crate::reward::RewardModule;
use crate::util::linalg::{cholesky, solve_lower, Mat};

/// Precomputed-table DAG scorer: `log R(adj) = Σ_j table[j][parents(j)]`.
/// Both LG and BGe rewards are expressed as one of these; the delta-score
/// optimization of the MDB objective (paper eq. (13)) falls out as a pair
/// of table lookups.
#[derive(Clone, Debug)]
pub struct DagScoreTable {
    pub d: usize,
    /// `table[j * 2^d + parent_mask]`; entries with bit j set are unused.
    pub table: Vec<f64>,
}

impl DagScoreTable {
    /// Build from any local scorer.
    pub fn from_scorer(d: usize, mut local: impl FnMut(usize, u64) -> f64) -> Self {
        let masks = 1usize << d;
        let mut table = vec![f64::NEG_INFINITY; d * masks];
        for j in 0..d {
            for m in 0..masks as u64 {
                if m & (1 << j) != 0 {
                    continue;
                }
                table[j * masks + m as usize] = local(j, m);
            }
        }
        DagScoreTable { d, table }
    }

    #[inline]
    pub fn local(&self, j: usize, parent_mask: u64) -> f64 {
        self.table[j * (1 << self.d) + parent_mask as usize]
    }

    /// Full-graph log score (modularity, paper eq. (12)).
    pub fn log_score(&self, adj: u64) -> f64 {
        let mut s = 0.0;
        for j in 0..self.d {
            s += self.local(j, BayesNetEnv::<DagScoreTable>::parents_of(adj, self.d, j));
        }
        s
    }

    /// Delta score of adding u→v (paper eq. (13)): only v's family changes.
    pub fn delta_score(&self, adj: u64, u: usize, v: usize) -> f64 {
        let pa = BayesNetEnv::<DagScoreTable>::parents_of(adj, self.d, v);
        self.local(v, pa | (1 << u)) - self.local(v, pa)
    }
}

impl RewardModule<u64> for DagScoreTable {
    fn log_reward(&self, obj: &u64) -> f64 {
        self.log_score(*obj)
    }
}

/// Build the linear-Gaussian score table from data (rows = samples).
///
/// `sigma2` is the observation noise variance, `sigma_w2` the weight prior
/// variance. A uniform structure prior contributes nothing (constant).
pub fn lingauss_table(data: &Mat, sigma2: f64, sigma_w2: f64) -> DagScoreTable {
    let n = data.rows;
    let d = data.cols;
    // Gram matrix G = XᵀX over all columns, plus per-pair inner products.
    let mut gram = Mat::zeros(d, d);
    for a in 0..d {
        for b in 0..d {
            let mut s = 0.0;
            for r in 0..n {
                s += data.get(r, a) * data.get(r, b);
            }
            gram.set(a, b, s);
        }
    }
    let ln2pi = (2.0 * std::f64::consts::PI).ln();
    DagScoreTable::from_scorer(d, |j, mask| {
        let parents: Vec<usize> = (0..d).filter(|&u| mask & (1 << u) != 0).collect();
        let p = parents.len();
        let yty = gram.get(j, j);
        if p == 0 {
            // x_j ~ N(0, σ² I): log N = -N/2 ln(2πσ²) - yᵀy/(2σ²).
            return -0.5 * n as f64 * (ln2pi + sigma2.ln()) - 0.5 * yty / sigma2;
        }
        // Woodbury p×p form: A = I_p + (σ_w²/σ²) XᵀX (on parent columns).
        let mut a = Mat::zeros(p, p);
        for (ai, &u) in parents.iter().enumerate() {
            for (bi, &v) in parents.iter().enumerate() {
                a.set(ai, bi, sigma_w2 / sigma2 * gram.get(u, v));
            }
            a.add_at(ai, ai, 1.0);
        }
        let l = cholesky(&a).expect("LG score matrix not PD");
        let mut logdet = 0.0;
        for i in 0..p {
            logdet += l.get(i, i).ln();
        }
        let logdet = 2.0 * logdet;
        // bᵀ A⁻¹ b with b = Xᵀy (parent-column inner products with x_j).
        let b: Vec<f64> = parents.iter().map(|&u| gram.get(u, j)).collect();
        let y_ = solve_lower(&l, &b);
        let quad: f64 = y_.iter().map(|v| v * v).sum();
        // log det(Σ) = N ln σ² + ln det A;
        // yᵀΣ⁻¹y = (yᵀy − (σ_w²/σ²)·bᵀA⁻¹b)/σ².
        let log_det_sigma = n as f64 * sigma2.ln() + logdet;
        let quad_full = (yty - sigma_w2 / sigma2 * quad) / sigma2;
        -0.5 * (n as f64 * ln2pi + log_det_sigma + quad_full)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ancestral::ancestral_sample;
    use crate::data::erdos_renyi::{sample_er_dag, GroundTruthDag};
    use crate::util::rng::Rng;

    /// Direct O(N³) evaluation of the marginal likelihood for verification.
    fn direct_score(data: &Mat, j: usize, parents: &[usize], sigma2: f64, sigma_w2: f64) -> f64 {
        let n = data.rows;
        // Σ = σ² I + σ_w² X Xᵀ.
        let mut cov = Mat::zeros(n, n);
        for r in 0..n {
            cov.add_at(r, r, sigma2);
            for c in 0..n {
                let mut s = 0.0;
                for &u in parents {
                    s += data.get(r, u) * data.get(c, u);
                }
                cov.add_at(r, c, sigma_w2 * s);
            }
        }
        let y: Vec<f64> = (0..n).map(|r| data.get(r, j)).collect();
        let l = cholesky(&cov).unwrap();
        let mut logdet = 0.0;
        for i in 0..n {
            logdet += l.get(i, i).ln();
        }
        let z = solve_lower(&l, &y);
        let quad: f64 = z.iter().map(|v| v * v).sum();
        -0.5 * (n as f64 * (2.0 * std::f64::consts::PI).ln() + 2.0 * logdet + quad)
    }

    fn toy_data(seed: u64, d: usize, n: usize) -> Mat {
        let mut rng = Rng::new(seed);
        let g = sample_er_dag(d, 1.0, &mut rng);
        ancestral_sample(&g, n, 0.1, &mut rng)
    }

    #[test]
    fn woodbury_matches_direct() {
        let data = toy_data(0, 4, 30);
        let t = lingauss_table(&data, 0.1, 1.0);
        for j in 0..4 {
            for mask in 0u64..16 {
                if mask & (1 << j) != 0 {
                    continue;
                }
                let parents: Vec<usize> =
                    (0..4).filter(|&u| mask & (1 << u) != 0).collect();
                let direct = direct_score(&data, j, &parents, 0.1, 1.0);
                let fast = t.local(j, mask);
                assert!(
                    (direct - fast).abs() < 1e-8,
                    "j={j} mask={mask:#b}: {direct} vs {fast}"
                );
            }
        }
    }

    #[test]
    fn delta_score_consistent_with_full() {
        let data = toy_data(1, 5, 50);
        let t = lingauss_table(&data, 0.1, 1.0);
        let d = 5;
        // adj: 0→2, 1→2.
        let adj = (1u64 << (0 * d + 2)) | (1u64 << (1 * d + 2));
        let with_edge = adj | (1u64 << (3 * d + 2));
        let delta = t.delta_score(adj, 3, 2);
        assert!(
            (t.log_score(with_edge) - t.log_score(adj) - delta).abs() < 1e-10,
            "delta score inconsistent"
        );
    }

    #[test]
    fn true_graph_likely_beats_reversed_chain() {
        // Strong chain 0→1→2: LG score should prefer the true orientation
        // family scores in aggregate over the empty graph.
        let mut rng = Rng::new(2);
        let d = 3;
        let mut weights = vec![0.0; 9];
        weights[0 * d + 1] = 2.0;
        weights[1 * d + 2] = 2.0;
        let g = GroundTruthDag {
            d,
            adj: (1u64 << (0 * d + 1)) | (1u64 << (1 * d + 2)),
            weights,
            order: vec![0, 1, 2],
        };
        let data = ancestral_sample(&g, 100, 0.1, &mut rng);
        let t = lingauss_table(&data, 0.1, 1.0);
        assert!(t.log_score(g.adj) > t.log_score(0), "true graph should beat empty");
    }
}
