//! BGe (Bayesian Gaussian equivalent) local scores (Geiger & Heckerman
//! 1994; Kuipers, Moffa & Heckerman 2014 addendum), the score-equivalent
//! marginal likelihood used in the paper's structure-learning experiments.
//!
//! With prior mean ν = 0, precision scale T = t·I (t = α_μ(α_w − d − 1) /
//! (α_μ + 1)) and posterior matrix
//!
//!   R = T + S_N + (N·α_μ/(N+α_μ)) x̄ x̄ᵀ,
//!
//! the local score of node j with parent set Pa (|Pa| = p) is
//!
//!   log Γ((N+α_w−d+p+1)/2) − log Γ((α_w−d+p+1)/2) − (N/2)·log π
//!   + ½ log(α_μ/(N+α_μ)) + ½ (α_w−d+2p+1)·log t
//!   + ½ (N+α_w−d+p)·log det R_[Pa] − ½ (N+α_w−d+p+1)·log det R_[Pa∪{j}].
//!
//! Score equivalence (Markov-equivalent DAGs receive equal scores) is the
//! defining property and is property-tested below.

use super::lingauss::DagScoreTable;
use crate::util::linalg::{ln_gamma, logdet_pd, Mat};

/// BGe hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct BgeParams {
    /// Equivalent sample size of the mean prior (α_μ).
    pub alpha_mu: f64,
    /// Degrees of freedom of the Wishart prior (α_w > d − 1).
    pub alpha_w: f64,
}

impl BgeParams {
    /// Common default: α_μ = 1, α_w = d + 2.
    pub fn default_for(d: usize) -> Self {
        BgeParams { alpha_mu: 1.0, alpha_w: d as f64 + 2.0 }
    }
}

/// Build the BGe score table from data (rows = samples, cols = variables).
pub fn bge_table(data: &Mat, params: BgeParams) -> DagScoreTable {
    let n = data.rows as f64;
    let d = data.cols;
    let BgeParams { alpha_mu, alpha_w } = params;
    assert!(alpha_w > d as f64 - 1.0, "alpha_w must exceed d-1");
    let t = alpha_mu * (alpha_w - d as f64 - 1.0) / (alpha_mu + 1.0);

    // Column means.
    let mean: Vec<f64> = (0..d)
        .map(|c| (0..data.rows).map(|r| data.get(r, c)).sum::<f64>() / n)
        .collect();
    // R = t·I + S_N + (N α_μ / (N + α_μ)) x̄ x̄ᵀ  (ν = 0).
    let mut r = Mat::zeros(d, d);
    for a in 0..d {
        r.add_at(a, a, t);
        for b in 0..d {
            let mut s = 0.0;
            for row in 0..data.rows {
                s += (data.get(row, a) - mean[a]) * (data.get(row, b) - mean[b]);
            }
            r.add_at(a, b, s + n * alpha_mu / (n + alpha_mu) * mean[a] * mean[b]);
        }
    }

    let log_pi = std::f64::consts::PI.ln();
    DagScoreTable::from_scorer(d, |j, mask| {
        let parents: Vec<usize> = (0..d).filter(|&u| mask & (1 << u) != 0).collect();
        let p = parents.len() as f64;
        let mut fam = parents.clone();
        fam.push(j);
        let logdet_pa = logdet_pd(&r.submatrix(&parents)).expect("R[Pa] not PD");
        let logdet_fam = logdet_pd(&r.submatrix(&fam)).expect("R[fam] not PD");
        ln_gamma(0.5 * (n + alpha_w - d as f64 + p + 1.0))
            - ln_gamma(0.5 * (alpha_w - d as f64 + p + 1.0))
            - 0.5 * n * log_pi
            + 0.5 * (alpha_mu / (n + alpha_mu)).ln()
            + 0.5 * (alpha_w - d as f64 + 2.0 * p + 1.0) * t.ln()
            + 0.5 * (n + alpha_w - d as f64 + p) * logdet_pa
            - 0.5 * (n + alpha_w - d as f64 + p + 1.0) * logdet_fam
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ancestral::ancestral_sample;
    use crate::data::erdos_renyi::sample_er_dag;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    fn toy_table(seed: u64, d: usize, n: usize) -> DagScoreTable {
        let mut rng = Rng::new(seed);
        let g = sample_er_dag(d, 1.0, &mut rng);
        let data = ancestral_sample(&g, n, 0.1, &mut rng);
        bge_table(&data, BgeParams::default_for(d))
    }

    #[test]
    fn score_equivalence_two_nodes() {
        // X→Y and Y→X are Markov equivalent: identical BGe scores.
        let t = toy_table(0, 2, 60);
        let d = 2;
        let fwd = 1u64 << (0 * d + 1);
        let rev = 1u64 << (1 * d + 0);
        assert!(
            (t.log_score(fwd) - t.log_score(rev)).abs() < 1e-9,
            "{} vs {}",
            t.log_score(fwd),
            t.log_score(rev)
        );
    }

    #[test]
    fn score_equivalence_chains_vs_forks() {
        // Chains 0→1→2, 2→1→0 and fork 1→0,1→2 are Markov equivalent
        // (same skeleton, no v-structure); the collider 0→1←2 is NOT.
        let t = toy_table(1, 3, 80);
        let d = 3;
        let chain = (1u64 << (0 * d + 1)) | (1u64 << (1 * d + 2)); // 0→1→2
        let rchain = (1u64 << (2 * d + 1)) | (1u64 << (1 * d + 0)); // 2→1→0
        let fork = (1u64 << (1 * d + 0)) | (1u64 << (1 * d + 2)); // 0←1→2
        let collider = (1u64 << (0 * d + 1)) | (1u64 << (2 * d + 1)); // 0→1←2
        let s = t.log_score(chain);
        assert!((s - t.log_score(rchain)).abs() < 1e-9);
        assert!((s - t.log_score(fork)).abs() < 1e-9);
        assert!(
            (s - t.log_score(collider)).abs() > 1e-6,
            "collider should differ from the chain class"
        );
    }

    #[test]
    fn score_equivalence_random_covered_edge_reversals() {
        // Reversing a covered edge (Pa(v) = Pa(u) ∪ {u}) preserves the
        // Markov equivalence class, hence the BGe score (Chickering 1995).
        forall("bge covered edge reversal", 20, |rng| {
            let d = 4;
            let g = sample_er_dag(d, 1.0, rng);
            let data = ancestral_sample(&g, 40, 0.1, rng);
            let t = bge_table(&data, BgeParams::default_for(d));
            // Find a covered edge in a random DAG.
            let adj = g.adj;
            for u in 0..d {
                for v in 0..d {
                    if adj & (1u64 << (u * d + v)) == 0 {
                        continue;
                    }
                    let pa_u = crate::envs::bayesnet::BayesNetEnv::<DagScoreTable>::parents_of(
                        adj, d, u,
                    );
                    let pa_v = crate::envs::bayesnet::BayesNetEnv::<DagScoreTable>::parents_of(
                        adj, d, v,
                    );
                    if pa_v == pa_u | (1 << u) {
                        // Covered: reverse it.
                        let rev =
                            (adj & !(1u64 << (u * d + v))) | (1u64 << (v * d + u));
                        if crate::envs::bayesnet::is_acyclic(rev, d) {
                            let a = t.log_score(adj);
                            let b = t.log_score(rev);
                            assert!(
                                (a - b).abs() < 1e-8,
                                "covered reversal changed score: {a} vs {b}"
                            );
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn true_structure_scores_well() {
        // With strong signal, the true graph's equivalence class should beat
        // the empty graph.
        let mut rng = Rng::new(3);
        let g = sample_er_dag(5, 1.0, &mut rng);
        if g.adj == 0 {
            return; // degenerate draw
        }
        let data = ancestral_sample(&g, 100, 0.1, &mut rng);
        let t = bge_table(&data, BgeParams::default_for(5));
        assert!(t.log_score(g.adj) > t.log_score(0));
    }

    #[test]
    fn delta_score_matches_full_difference() {
        let t = toy_table(4, 5, 50);
        let d = 5;
        let adj = 1u64 << (0 * d + 1);
        let delta = t.delta_score(adj, 2, 1);
        let full = t.log_score(adj | (1u64 << (2 * d + 1))) - t.log_score(adj);
        assert!((delta - full).abs() < 1e-10);
    }
}
