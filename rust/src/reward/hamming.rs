//! Bit-sequence reward (Malkin et al. 2022; gfnx env #2):
//!
//! R(x) = exp(−β · min_{x'∈M} d(x, x') / n)
//!
//! where d is Hamming distance over the n-bit strings and M is a hidden
//! mode set. Sequences are stored as k-bit tokens; distances are computed
//! over packed u64 words with XOR + popcount.

use super::RewardModule;

/// Pack a token sequence (each token is a k-bit word) into u64 words.
pub fn pack_tokens(tokens: &[i16], k: usize) -> Vec<u64> {
    let n_bits = tokens.len() * k;
    let mut words = vec![0u64; n_bits.div_ceil(64)];
    for (p, &t) in tokens.iter().enumerate() {
        debug_assert!(t >= 0 && (t as usize) < (1usize << k));
        let base = p * k;
        for j in 0..k {
            if (t as usize >> j) & 1 == 1 {
                words[(base + j) / 64] |= 1u64 << ((base + j) % 64);
            }
        }
    }
    words
}

/// Hamming distance between two packed bit strings.
#[inline]
pub fn hamming_packed(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones()).sum()
}

/// Mode-set Hamming reward over k-bit token sequences.
#[derive(Clone, Debug)]
pub struct HammingReward {
    /// Packed modes, each `n_bits` long.
    modes: Vec<Vec<u64>>,
    /// Total bit length n.
    pub n_bits: usize,
    /// Bits per token k.
    pub k: usize,
    /// Reward exponent β.
    pub beta: f64,
}

impl HammingReward {
    pub fn new(modes_bits: &[Vec<u8>], k: usize, beta: f64) -> Self {
        let n_bits = modes_bits.first().map_or(0, |m| m.len());
        assert!(n_bits > 0 && n_bits % k == 0);
        let modes = modes_bits
            .iter()
            .map(|bits| {
                assert_eq!(bits.len(), n_bits);
                let mut words = vec![0u64; n_bits.div_ceil(64)];
                for (i, &b) in bits.iter().enumerate() {
                    if b != 0 {
                        words[i / 64] |= 1u64 << (i % 64);
                    }
                }
                words
            })
            .collect();
        HammingReward { modes, n_bits, k, beta }
    }

    /// Minimum Hamming distance from a token sequence to the mode set.
    pub fn min_distance(&self, tokens: &[i16]) -> u32 {
        let packed = pack_tokens(tokens, self.k);
        self.modes
            .iter()
            .map(|m| hamming_packed(m, &packed))
            .min()
            .expect("empty mode set")
    }

    pub fn num_modes(&self) -> usize {
        self.modes.len()
    }
}

impl RewardModule<Vec<i16>> for HammingReward {
    fn log_reward(&self, obj: &Vec<i16>) -> f64 {
        -self.beta * self.min_distance(obj) as f64 / self.n_bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_single_token() {
        // k=4, token 0b1010 = 10.
        let w = pack_tokens(&[10], 4);
        assert_eq!(w[0], 0b1010);
    }

    #[test]
    fn pack_crosses_words() {
        // 17 tokens of k=4 → 68 bits → 2 words.
        let tokens = vec![0xF; 17];
        let w = pack_tokens(&tokens, 4);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], u64::MAX);
        assert_eq!(w[1], 0xF);
    }

    #[test]
    fn hamming_identity_and_flip() {
        let a = pack_tokens(&[3, 5], 4);
        let b = pack_tokens(&[3, 5], 4);
        assert_eq!(hamming_packed(&a, &b), 0);
        let c = pack_tokens(&[3, 4], 4); // 5=0101 vs 4=0100 → 1 bit
        assert_eq!(hamming_packed(&a, &c), 1);
    }

    #[test]
    fn reward_at_mode_is_zero_log() {
        // Mode = all-zero 8 bits; token seq of two k=4 zero tokens.
        let r = HammingReward::new(&[vec![0u8; 8]], 4, 3.0);
        let lr = RewardModule::log_reward(&r, &vec![0i16, 0]);
        assert_eq!(lr, 0.0);
        // One bit set → d=1 → log R = -3/8.
        let lr1 = RewardModule::log_reward(&r, &vec![1i16, 0]);
        assert!((lr1 + 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn min_over_modes() {
        let m0 = vec![0u8; 8];
        let m1 = vec![1u8; 8];
        let r = HammingReward::new(&[m0, m1], 4, 1.0);
        // All-ones tokens (0xF, 0xF) = 8 set bits: d(m0)=8, d(m1)=0.
        assert_eq!(r.min_distance(&[0xF, 0xF]), 0);
        // Zero sequence: d(m0)=0.
        assert_eq!(r.min_distance(&[0, 0]), 0);
    }
}
