//! Frozen proxy reward models.
//!
//! The paper's TFBind8 / QM9 / AMP environments score sequences with
//! pretrained proxy models (wet-lab landscape tables and neural proxies
//! trained on QM9 / DBAASP data). Those assets are not available here, so we
//! substitute *deterministic synthetic proxies with the same functional
//! form* (DESIGN.md §3): a fixed landscape table for TFBind8 and frozen
//! random-but-seeded MLPs for QM9 and AMP. All compute paths (terminal-state
//! proxy forward, reward exponents, r_min floors) match the originals.

use super::RewardModule;
use crate::util::linalg::Mat;
use crate::util::rng::Rng;

/// A frozen multi-layer perceptron with tanh hidden activations, used as a
/// synthetic stand-in for pretrained proxy networks.
#[derive(Clone, Debug)]
pub struct FrozenMlp {
    layers: Vec<(Mat, Vec<f64>)>,
}

impl FrozenMlp {
    /// Build from a seed with the given layer sizes (e.g. `[in, 64, 64, 1]`).
    pub fn seeded(seed: u64, sizes: &[usize]) -> Self {
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        for w in sizes.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let std = (2.0 / (fan_in + fan_out) as f64).sqrt();
            let mut m = Mat::zeros(fan_out, fan_in);
            for v in m.data.iter_mut() {
                *v = rng.normal() * std;
            }
            let b: Vec<f64> = (0..fan_out).map(|_| rng.normal() * 0.1).collect();
            layers.push((m, b));
        }
        FrozenMlp { layers }
    }

    /// Forward pass; tanh on hidden layers, identity on the output layer.
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut h = x.to_vec();
        let last = self.layers.len() - 1;
        for (li, (w, b)) in self.layers.iter().enumerate() {
            assert_eq!(w.cols, h.len(), "proxy input dim mismatch");
            let mut out = b.clone();
            for i in 0..w.rows {
                let mut s = 0.0;
                let row = w.row(i);
                for (j, &hj) in h.iter().enumerate() {
                    s += row[j] * hj;
                }
                out[i] += s;
            }
            if li != last {
                out.iter_mut().for_each(|v| *v = v.tanh());
            }
            h = out;
        }
        h
    }

    /// Scalar output helper.
    pub fn forward_scalar(&self, x: &[f64]) -> f64 {
        let out = self.forward(x);
        debug_assert_eq!(out.len(), 1);
        out[0]
    }
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// One-hot encode a token sequence (padded with an empty class when
/// `tokens.len() < max_len`).
fn one_hot_seq(tokens: &[i16], vocab: usize, max_len: usize) -> Vec<f64> {
    let w = vocab + 1;
    let mut x = vec![0.0; max_len * w];
    for p in 0..max_len {
        let cls = match tokens.get(p) {
            Some(&t) if t >= 0 => t as usize,
            _ => vocab,
        };
        x[p * w + cls] = 1.0;
    }
    x
}

// ---------------------------------------------------------------------------
// TFBind8: synthetic binding landscape over all 4^8 sequences.
// ---------------------------------------------------------------------------

/// Synthetic TFBind8 landscape: motif-match score plus a smooth epistatic
/// term, squashed into (0, 1), with reward exponent β (Shen et al. 2023 use
/// R(x) = r(x)^β; log R = β·ln r).
#[derive(Clone, Debug)]
pub struct TfBindReward {
    /// r(x) ∈ (0, 1] for every flattened sequence index.
    table: Vec<f32>,
    pub beta: f64,
}

impl TfBindReward {
    pub const LEN: usize = 8;
    pub const VOCAB: usize = 4;
    pub const SPACE: usize = 65_536; // 4^8

    pub fn synthetic(seed: u64, beta: f64) -> Self {
        let mut rng = Rng::new(seed);
        // Hidden motifs with per-position weights.
        let n_motifs = 4;
        let motifs: Vec<(Vec<i16>, f64)> = (0..n_motifs)
            .map(|_| {
                let m: Vec<i16> = (0..Self::LEN).map(|_| rng.below(Self::VOCAB) as i16).collect();
                (m, 0.5 + rng.uniform())
            })
            .collect();
        // Pairwise epistatic couplings.
        let mut pair = vec![0.0f64; Self::LEN * Self::LEN * Self::VOCAB * Self::VOCAB];
        for v in pair.iter_mut() {
            *v = rng.normal() * 0.15;
        }
        let mut table = Vec::with_capacity(Self::SPACE);
        let mut raw = Vec::with_capacity(Self::SPACE);
        for idx in 0..Self::SPACE {
            let seq = Self::unflatten(idx);
            let mut s = 0.0;
            for (m, w) in &motifs {
                let matches = seq.iter().zip(m).filter(|(a, b)| a == b).count();
                s += w * matches as f64 / Self::LEN as f64;
            }
            for i in 0..Self::LEN {
                for j in (i + 1)..Self::LEN {
                    s += pair[((i * Self::LEN + j) * Self::VOCAB + seq[i] as usize) * Self::VOCAB
                        + seq[j] as usize];
                }
            }
            raw.push(s);
        }
        // Normalize to (0, 1] with a sigmoid around the mean.
        let mean = raw.iter().sum::<f64>() / raw.len() as f64;
        let std = (raw.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>()
            / raw.len() as f64)
            .sqrt()
            .max(1e-9);
        for &x in &raw {
            table.push(sigmoid((x - mean) / std) as f32);
        }
        TfBindReward { table, beta }
    }

    pub fn flatten(seq: &[i16]) -> usize {
        let mut idx = 0usize;
        for &t in seq {
            idx = idx * Self::VOCAB + t as usize;
        }
        idx
    }

    pub fn unflatten(mut idx: usize) -> Vec<i16> {
        let mut seq = vec![0i16; Self::LEN];
        for p in (0..Self::LEN).rev() {
            seq[p] = (idx % Self::VOCAB) as i16;
            idx /= Self::VOCAB;
        }
        seq
    }

    /// Raw proxy value r(x) ∈ (0, 1].
    pub fn raw(&self, seq: &[i16]) -> f64 {
        self.table[Self::flatten(seq)] as f64
    }
}

impl RewardModule<Vec<i16>> for TfBindReward {
    fn log_reward(&self, obj: &Vec<i16>) -> f64 {
        self.beta * self.raw(obj).max(1e-9).ln()
    }
}

// ---------------------------------------------------------------------------
// QM9: frozen MLP proxy over block one-hots (prepend/append formulation).
// ---------------------------------------------------------------------------

/// Synthetic QM9 HOMO-LUMO-gap proxy: frozen MLP → sigmoid → r ∈ (0,1),
/// with reward exponent β.
#[derive(Clone, Debug)]
pub struct Qm9Reward {
    mlp: FrozenMlp,
    pub beta: f64,
}

impl Qm9Reward {
    pub const LEN: usize = 5;
    pub const VOCAB: usize = 11; // building blocks

    pub fn synthetic(seed: u64, beta: f64) -> Self {
        let in_dim = Self::LEN * (Self::VOCAB + 1);
        Qm9Reward { mlp: FrozenMlp::seeded(seed, &[in_dim, 32, 32, 1]), beta }
    }

    /// Raw proxy value r(x) ∈ (0, 1).
    pub fn raw(&self, tokens: &[i16]) -> f64 {
        let x = one_hot_seq(tokens, Self::VOCAB, Self::LEN);
        sigmoid(self.mlp.forward_scalar(&x))
    }
}

impl RewardModule<Vec<i16>> for Qm9Reward {
    fn log_reward(&self, obj: &Vec<i16>) -> f64 {
        self.beta * self.raw(obj).max(1e-9).ln()
    }
}

// ---------------------------------------------------------------------------
// AMP: frozen classifier over variable-length peptides.
// ---------------------------------------------------------------------------

/// Synthetic antimicrobial-peptide classifier: R(x) = max(σ(f(x)), r_min)
/// with f a frozen MLP over sequence composition features (Jain et al. 2022
/// functional form).
#[derive(Clone, Debug)]
pub struct AmpReward {
    mlp: FrozenMlp,
    pub r_min: f64,
    pub max_len: usize,
    pub vocab: usize,
}

impl AmpReward {
    pub fn synthetic(seed: u64, max_len: usize, vocab: usize, r_min: f64) -> Self {
        // Features: per-amino-acid frequencies, bigram class features,
        // normalized length → vocab + vocab + 1 inputs.
        let in_dim = 2 * vocab + 1;
        AmpReward {
            mlp: FrozenMlp::seeded(seed, &[in_dim, 48, 48, 1]),
            r_min,
            max_len,
            vocab,
        }
    }

    fn features(&self, tokens: &[i16]) -> Vec<f64> {
        let mut x = vec![0.0; 2 * self.vocab + 1];
        let len = tokens.len().max(1);
        for &t in tokens {
            x[t as usize] += 1.0 / len as f64;
        }
        // "Bigram class": frequency of same-class consecutive pairs per class.
        for w in tokens.windows(2) {
            if w[0] == w[1] {
                x[self.vocab + w[0] as usize] += 1.0 / len as f64;
            }
        }
        x[2 * self.vocab] = tokens.len() as f64 / self.max_len as f64;
        x
    }

    /// Classifier probability σ(f(x)).
    pub fn prob(&self, tokens: &[i16]) -> f64 {
        sigmoid(self.mlp.forward_scalar(&self.features(tokens)) * 4.0)
    }
}

impl RewardModule<Vec<i16>> for AmpReward {
    fn log_reward(&self, obj: &Vec<i16>) -> f64 {
        self.prob(obj).max(self.r_min).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reward::RewardModule;

    #[test]
    fn frozen_mlp_deterministic() {
        let a = FrozenMlp::seeded(42, &[4, 8, 1]);
        let b = FrozenMlp::seeded(42, &[4, 8, 1]);
        let x = [0.5, -1.0, 2.0, 0.0];
        assert_eq!(a.forward_scalar(&x), b.forward_scalar(&x));
        let c = FrozenMlp::seeded(43, &[4, 8, 1]);
        assert_ne!(a.forward_scalar(&x), c.forward_scalar(&x));
    }

    #[test]
    fn tfbind_flatten_roundtrip() {
        for idx in [0usize, 1, 255, 65_535, 12_345] {
            assert_eq!(TfBindReward::flatten(&TfBindReward::unflatten(idx)), idx);
        }
    }

    #[test]
    fn tfbind_table_in_unit_interval() {
        let r = TfBindReward::synthetic(0, 10.0);
        assert_eq!(r.table.len(), 65_536);
        assert!(r.table.iter().all(|&v| v > 0.0 && v < 1.0));
        // Landscape is non-degenerate.
        let lo = r.table.iter().cloned().fold(f32::MAX, f32::min);
        let hi = r.table.iter().cloned().fold(f32::MIN, f32::max);
        assert!(hi - lo > 0.2, "landscape too flat: {lo}..{hi}");
    }

    #[test]
    fn tfbind_beta_scales_log_reward() {
        let r1 = TfBindReward::synthetic(0, 1.0);
        let r10 = TfBindReward::synthetic(0, 10.0);
        let seq = vec![0i16, 1, 2, 3, 0, 1, 2, 3];
        let a = RewardModule::log_reward(&r1, &seq);
        let b = RewardModule::log_reward(&r10, &seq);
        assert!((b - 10.0 * a).abs() < 1e-9);
    }

    #[test]
    fn qm9_raw_in_unit_interval() {
        let r = Qm9Reward::synthetic(7, 10.0);
        for seq in [[0i16, 1, 2, 3, 4], [10, 10, 10, 10, 10], [5, 0, 9, 2, 7]] {
            let v = r.raw(&seq);
            assert!(v > 0.0 && v < 1.0);
        }
    }

    #[test]
    fn amp_floor_applies() {
        let r = AmpReward::synthetic(3, 60, 20, 1e-3);
        // log reward is always ≥ ln(r_min).
        for seq in [vec![0i16], vec![1i16; 60], (0..20).map(|i| i as i16).collect()] {
            let lr = RewardModule::log_reward(&r, &seq);
            assert!(lr >= (1e-3f64).ln() - 1e-12);
            assert!(lr <= 0.0);
        }
    }

    #[test]
    fn amp_varies_with_sequence() {
        let r = AmpReward::synthetic(3, 60, 20, 1e-6);
        let a = r.prob(&[0, 1, 2, 3, 4, 5]);
        let b = r.prob(&[19, 19, 19, 19]);
        assert_ne!(a, b);
    }
}
