//! Exact enumeration of all DAGs on d ≤ 5 nodes and the exact posterior
//! P(G | D) ∝ exp(log R(G)) over them (paper §B.4: 29 281 DAGs at d = 5,
//! "all probabilities can be computed exactly by enumeration").

use crate::envs::bayesnet::is_acyclic;
use crate::reward::lingauss::DagScoreTable;
use crate::util::stats::softmax_from_logs;

/// All DAG adjacency bitmasks on `d` nodes, sorted ascending.
pub fn enumerate_dags(d: usize) -> Vec<u64> {
    assert!(d <= 5, "enumeration over 2^(d(d-1)) graphs; d ≤ 5 supported");
    // Enumerate subsets of the d(d−1) ordered off-diagonal pairs.
    let pairs: Vec<(usize, usize)> = (0..d)
        .flat_map(|u| (0..d).filter(move |&v| v != u).map(move |v| (u, v)))
        .collect();
    let m = pairs.len();
    let mut out = Vec::new();
    for mask in 0u64..(1u64 << m) {
        let mut adj = 0u64;
        for (k, &(u, v)) in pairs.iter().enumerate() {
            if mask >> k & 1 == 1 {
                adj |= 1u64 << (u * d + v);
            }
        }
        if is_acyclic(adj, d) {
            out.push(adj);
        }
    }
    out.sort_unstable();
    out
}

/// Exact posterior over an enumerated DAG list under a modular score table.
pub fn exact_posterior(dags: &[u64], table: &DagScoreTable) -> Vec<f64> {
    let logs: Vec<f64> = dags.iter().map(|&g| table.log_score(g)).collect();
    softmax_from_logs(&logs)
}

/// Index lookup: position of each DAG in the enumeration (for counting
/// sampled graphs). Returns a sorted-slice binary-search closure.
pub fn dag_index(dags: &[u64], adj: u64) -> Option<usize> {
    dags.binary_search(&adj).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Known DAG counts (OEIS A003024): 1, 1, 3, 25, 543, 29281.
    #[test]
    fn dag_counts_match_oeis() {
        assert_eq!(enumerate_dags(1).len(), 1);
        assert_eq!(enumerate_dags(2).len(), 3);
        assert_eq!(enumerate_dags(3).len(), 25);
        assert_eq!(enumerate_dags(4).len(), 543);
    }

    /// The paper's headline count for d = 5.
    #[test]
    fn dag_count_d5_is_29281() {
        assert_eq!(enumerate_dags(5).len(), 29_281);
    }

    /// d = 2 enumerates exactly the hand-listable set {∅, 0→1, 1→0}
    /// (sorted ascending by bitmask).
    #[test]
    fn d2_enumeration_matches_hand_listing() {
        let d = 2;
        let g01 = 1u64 << (0 * d + 1);
        let g10 = 1u64 << (1 * d + 0);
        let mut want = vec![0u64, g01, g10];
        want.sort_unstable();
        assert_eq!(enumerate_dags(2), want);
    }

    #[test]
    fn posterior_normalizes() {
        use crate::data::ancestral::ancestral_sample;
        use crate::data::erdos_renyi::sample_er_dag;
        use crate::reward::lingauss::lingauss_table;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0);
        let g = sample_er_dag(3, 1.0, &mut rng);
        let data = ancestral_sample(&g, 50, 0.1, &mut rng);
        let table = lingauss_table(&data, 0.1, 1.0);
        let dags = enumerate_dags(3);
        let post = exact_posterior(&dags, &table);
        assert_eq!(post.len(), 25);
        assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(post.iter().all(|&p| p >= 0.0));
    }

    #[test]
    fn index_lookup() {
        let dags = enumerate_dags(3);
        for (i, &g) in dags.iter().enumerate() {
            assert_eq!(dag_index(&dags, g), Some(i));
        }
        // A cyclic mask is absent.
        let d = 3;
        let cyc = (1u64 << (0 * d + 1)) | (1u64 << (1 * d + 0));
        assert_eq!(dag_index(&dags, cyc), None);
    }
}
