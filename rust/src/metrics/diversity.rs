//! Top-k reward and diversity tracking (paper Fig. 5, AMP experiment):
//! keep the best k distinct sequences seen so far; report their mean reward
//! and mean pairwise edit distance.

use std::collections::HashSet;

/// Levenshtein edit distance between two token sequences.
pub fn edit_distance(a: &[i16], b: &[i16]) -> usize {
    let (la, lb) = (a.len(), b.len());
    if la == 0 {
        return lb;
    }
    let mut prev: Vec<usize> = (0..=lb).collect();
    let mut cur = vec![0usize; lb + 1];
    for i in 1..=la {
        cur[0] = i;
        for j in 1..=lb {
            let cost = if a[i - 1] == b[j - 1] { 0 } else { 1 };
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[lb]
}

/// Tracks the top-k *distinct* sequences by reward.
pub struct TopK {
    k: usize,
    /// (reward, sequence), kept sorted descending by reward.
    items: Vec<(f64, Vec<i16>)>,
    seen: HashSet<Vec<i16>>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        TopK { k, items: Vec::new(), seen: HashSet::new() }
    }

    pub fn push(&mut self, reward: f64, seq: &[i16]) {
        if self.seen.contains(seq) {
            return;
        }
        if self.items.len() == self.k
            && reward <= self.items.last().map(|(r, _)| *r).unwrap_or(f64::NEG_INFINITY)
        {
            return;
        }
        self.seen.insert(seq.to_vec());
        let pos = self
            .items
            .partition_point(|(r, _)| *r > reward);
        self.items.insert(pos, (reward, seq.to_vec()));
        if self.items.len() > self.k {
            let (_, dropped) = self.items.pop().unwrap();
            self.seen.remove(&dropped);
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Mean reward over the current top-k.
    pub fn mean_reward(&self) -> f64 {
        if self.items.is_empty() {
            return 0.0;
        }
        self.items.iter().map(|(r, _)| r).sum::<f64>() / self.items.len() as f64
    }

    /// Mean pairwise edit distance (the paper's diversity score).
    pub fn diversity(&self) -> f64 {
        let n = self.items.len();
        if n < 2 {
            return 0.0;
        }
        let mut total = 0.0;
        let mut count = 0usize;
        for i in 0..n {
            for j in (i + 1)..n {
                total += edit_distance(&self.items[i].1, &self.items[j].1) as f64;
                count += 1;
            }
        }
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edit_distance_cases() {
        assert_eq!(edit_distance(&[], &[1, 2]), 2);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 2], &[2, 1]), 2); // two substitutions
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
    }

    /// The classic hand-computed case: kitten → sitting needs exactly 3
    /// edits (two substitutions + one insertion), encoded as a–z indices.
    #[test]
    fn edit_distance_kitten_sitting() {
        let enc = |s: &str| -> Vec<i16> {
            s.bytes().map(|b| (b - b'a') as i16).collect()
        };
        assert_eq!(edit_distance(&enc("kitten"), &enc("sitting")), 3);
        assert_eq!(edit_distance(&enc("sitting"), &enc("kitten")), 3, "symmetric");
        assert_eq!(edit_distance(&enc("flaw"), &enc("lawn")), 2);
    }

    #[test]
    fn topk_keeps_best_distinct() {
        let mut t = TopK::new(2);
        t.push(1.0, &[1]);
        t.push(3.0, &[3]);
        t.push(2.0, &[2]);
        assert_eq!(t.len(), 2);
        assert!((t.mean_reward() - 2.5).abs() < 1e-12);
        // Duplicates ignored.
        t.push(10.0, &[3]);
        assert!((t.mean_reward() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn topk_diversity() {
        let mut t = TopK::new(3);
        t.push(1.0, &[1, 1, 1]);
        t.push(2.0, &[2, 2, 2]);
        assert!((t.diversity() - 3.0).abs() < 1e-12);
        t.push(3.0, &[1, 1, 2]);
        // Pairs: (111,222)=3, (111,112)=1, (222,112)=2 → mean 2.
        assert!((t.diversity() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn evicted_sequences_can_reenter() {
        let mut t = TopK::new(1);
        t.push(1.0, &[1]);
        t.push(2.0, &[2]); // evicts [1]
        t.push(3.0, &[1]); // re-enter with higher reward
        assert_eq!(t.len(), 1);
        assert!((t.mean_reward() - 3.0).abs() < 1e-12);
    }
}
