//! Structural feature marginals over DAG posteriors (paper eqs. (16)–(18)):
//! edge, directed-path, and Markov-blanket membership probabilities, plus
//! the correlation between marginals under two distributions.

use crate::envs::bayesnet::closure_of;
use crate::util::stats::pearson;

#[inline]
fn has_edge(adj: u64, d: usize, u: usize, v: usize) -> bool {
    adj & (1u64 << (u * d + v)) != 0
}

/// P(X_u → X_v) for all ordered pairs under a distribution over DAGs.
/// Returns a d×d row-major matrix (diagonal zero).
pub fn edge_marginals(dags: &[u64], probs: &[f64], d: usize) -> Vec<f64> {
    let mut out = vec![0.0; d * d];
    for (&g, &p) in dags.iter().zip(probs) {
        for u in 0..d {
            for v in 0..d {
                if has_edge(g, d, u, v) {
                    out[u * d + v] += p;
                }
            }
        }
    }
    out
}

/// P(X_u ⇝ X_v) (directed path) marginals.
pub fn path_marginals(dags: &[u64], probs: &[f64], d: usize) -> Vec<f64> {
    let mut out = vec![0.0; d * d];
    for (&g, &p) in dags.iter().zip(probs) {
        let reach = closure_of(g, d);
        for u in 0..d {
            for v in 0..d {
                if u != v && reach & (1u64 << (u * d + v)) != 0 {
                    out[u * d + v] += p;
                }
            }
        }
    }
    out
}

/// Markov-blanket membership: X_u ∈ MB(X_v) iff u is a parent, child, or
/// co-parent of v.
pub fn markov_blanket_marginals(dags: &[u64], probs: &[f64], d: usize) -> Vec<f64> {
    let mut out = vec![0.0; d * d];
    for (&g, &p) in dags.iter().zip(probs) {
        for u in 0..d {
            for v in 0..d {
                if u == v {
                    continue;
                }
                let mut in_mb = has_edge(g, d, u, v) || has_edge(g, d, v, u);
                if !in_mb {
                    // Co-parent: ∃ w with u→w and v→w.
                    for w in 0..d {
                        if has_edge(g, d, u, w) && has_edge(g, d, v, w) {
                            in_mb = true;
                            break;
                        }
                    }
                }
                if in_mb {
                    out[u * d + v] += p;
                }
            }
        }
    }
    out
}

/// Pearson correlation between the off-diagonal entries of two marginal
/// matrices (the paper's "correlation scores over … marginals").
pub fn marginal_correlation(a: &[f64], b: &[f64], d: usize) -> f64 {
    let mut xs = Vec::with_capacity(d * d - d);
    let mut ys = Vec::with_capacity(d * d - d);
    for u in 0..d {
        for v in 0..d {
            if u != v {
                xs.push(a[u * d + v]);
                ys.push(b[u * d + v]);
            }
        }
    }
    pearson(&xs, &ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_mass_edge_marginals() {
        // Single DAG 0→1 on d=2 with probability 1.
        let d = 2;
        let g = 1u64 << (0 * d + 1);
        let m = edge_marginals(&[g], &[1.0], d);
        assert_eq!(m, vec![0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn path_includes_transitivity() {
        // Chain 0→1→2: path marginal includes 0⇝2.
        let d = 3;
        let g = (1u64 << (0 * d + 1)) | (1u64 << (1 * d + 2));
        let m = path_marginals(&[g], &[1.0], d);
        assert_eq!(m[0 * d + 2], 1.0);
        assert_eq!(m[2 * d + 0], 0.0);
    }

    #[test]
    fn markov_blanket_coparents() {
        // Collider 0→2←1: 0 and 1 are co-parents ⇒ in each other's MB.
        let d = 3;
        let g = (1u64 << (0 * d + 2)) | (1u64 << (1 * d + 2));
        let m = markov_blanket_marginals(&[g], &[1.0], d);
        assert_eq!(m[0 * d + 1], 1.0);
        assert_eq!(m[1 * d + 0], 1.0);
        // Chain 0→1→2: 0 and 2 are not in each other's MB.
        let chain = (1u64 << (0 * d + 1)) | (1u64 << (1 * d + 2));
        let mc = markov_blanket_marginals(&[chain], &[1.0], d);
        assert_eq!(mc[0 * d + 2], 0.0);
    }

    #[test]
    fn mixture_averages_probabilities() {
        let d = 2;
        let g01 = 1u64 << (0 * d + 1);
        let g10 = 1u64 << (1 * d + 0);
        let m = edge_marginals(&[g01, g10], &[0.25, 0.75], d);
        assert!((m[0 * d + 1] - 0.25).abs() < 1e-12);
        assert!((m[1 * d + 0] - 0.75).abs() < 1e-12);
    }

    /// Opposed point masses give perfectly anti-correlated off-diagonal
    /// marginals: ρ = −1 by hand.
    #[test]
    fn correlation_of_opposed_marginals_is_minus_one() {
        let d = 2;
        let a = edge_marginals(&[1u64 << 1], &[1.0], d); // 0→1
        let b = edge_marginals(&[1u64 << d], &[1.0], d); // 1→0
        assert!((marginal_correlation(&a, &b, d) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn correlation_of_identical_marginals_is_one() {
        let d = 3;
        let g = (1u64 << (0 * d + 1)) | (1u64 << (1 * d + 2));
        let dags = vec![g, 0];
        let probs = vec![0.7, 0.3];
        let m = edge_marginals(&dags, &probs, d);
        assert!((marginal_correlation(&m, &m, d) - 1.0).abs() < 1e-12);
    }
}
