//! Total variation distance between the exact target distribution and the
//! empirical distribution of sampled terminal states (paper Figs. 2 & 4).

/// TV between two probability vectors: ½ Σ |p − q|.
pub fn tv_dist(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    0.5 * p.iter().zip(q).map(|(&a, &b)| (a - b).abs()).sum::<f64>()
}

/// TV between an exact distribution and empirical counts over the same
/// index space.
pub fn tv_from_counts(exact: &[f64], counts: &[u64]) -> f64 {
    assert_eq!(exact.len(), counts.len());
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let t = total as f64;
    0.5 * exact
        .iter()
        .zip(counts)
        .map(|(&p, &c)| (p - c as f64 / t).abs())
        .sum::<f64>()
}

/// The TV a *perfect sampler* attains with `n_samples` draws (the floor the
/// paper plots in Figs. 2 and 4): estimated by drawing from the exact
/// distribution itself.
pub fn perfect_sampler_tv(exact: &[f64], n_samples: usize, rng: &mut crate::util::rng::Rng) -> f64 {
    // Draw n samples from `exact` via the alias-free CDF walk (fine at this
    // scale) and measure the empirical TV.
    let mut counts = vec![0u64; exact.len()];
    // Precompute CDF.
    let mut cdf = Vec::with_capacity(exact.len());
    let mut acc = 0.0;
    for &p in exact {
        acc += p;
        cdf.push(acc);
    }
    for _ in 0..n_samples {
        let u = rng.uniform();
        // Binary search the CDF.
        let idx = cdf.partition_point(|&c| c < u).min(exact.len() - 1);
        counts[idx] += 1;
    }
    tv_from_counts(exact, &counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_distributions_have_zero_tv() {
        let p = [0.25, 0.25, 0.5];
        assert_eq!(tv_dist(&p, &p), 0.0);
    }

    #[test]
    fn disjoint_distributions_have_tv_one() {
        assert_eq!(tv_dist(&[1.0, 0.0], &[0.0, 1.0]), 1.0);
    }

    #[test]
    fn counts_version_matches_dist_version() {
        let exact = [0.5, 0.3, 0.2];
        let counts = [50u64, 30, 20];
        assert!(tv_from_counts(&exact, &counts) < 1e-12);
        assert_eq!(tv_from_counts(&exact, &[0, 0, 0]), 1.0);
    }

    /// Hand-computed mid-range values (not just the 0/1 extremes).
    #[test]
    fn tv_hand_computed_values() {
        // ½(|0.7−0.4| + |0.3−0.6|) = 0.3
        assert!((tv_dist(&[0.7, 0.3], &[0.4, 0.6]) - 0.3).abs() < 1e-12);
        // counts [3, 1] ⇒ empirical [0.75, 0.25]; ½(0.25 + 0.25) = 0.25
        assert!((tv_from_counts(&[0.5, 0.5], &[3, 1]) - 0.25).abs() < 1e-12);
        // TV is symmetric.
        assert_eq!(tv_dist(&[0.7, 0.3], &[0.4, 0.6]), tv_dist(&[0.4, 0.6], &[0.7, 0.3]));
    }

    #[test]
    fn perfect_sampler_floor_shrinks_with_samples() {
        let mut rng = Rng::new(0);
        let exact: Vec<f64> = {
            let mut v: Vec<f64> = (1..=50).map(|i| i as f64).collect();
            let s: f64 = v.iter().sum();
            v.iter_mut().for_each(|x| *x /= s);
            v
        };
        let small = perfect_sampler_tv(&exact, 200, &mut rng);
        let large = perfect_sampler_tv(&exact, 50_000, &mut rng);
        assert!(large < small, "floor should shrink: {small} -> {large}");
        assert!(large < 0.05);
        assert!(small > 0.0, "finite-sample TV is biased above zero");
    }
}
