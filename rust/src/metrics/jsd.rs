//! Jensen–Shannon divergence (paper eq. (15)) between the learned and exact
//! distributions over DAGs (structure-learning experiment, Fig. 7).

/// KL(P‖Q) with the 0·log(0/·) = 0 convention. Q must dominate P.
pub fn kl(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let mut s = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            s += pi * (pi / qi.max(1e-300)).ln();
        }
    }
    s
}

/// JSD(P‖Q) = ½ KL(P‖M) + ½ KL(Q‖M), M = (P+Q)/2. Bounded by ln 2.
pub fn jsd(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let m: Vec<f64> = p.iter().zip(q).map(|(&a, &b)| 0.5 * (a + b)).collect();
    0.5 * kl(p, &m) + 0.5 * kl(q, &m)
}

/// JSD between an exact distribution and empirical counts.
pub fn jsd_from_counts(exact: &[f64], counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return (2f64).ln();
    }
    let emp: Vec<f64> = counts.iter().map(|&c| c as f64 / total as f64).collect();
    jsd(exact, &emp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsd_zero_for_identical() {
        let p = [0.1, 0.2, 0.7];
        assert!(jsd(&p, &p).abs() < 1e-15);
    }

    #[test]
    fn jsd_is_symmetric() {
        let p = [0.1, 0.9, 0.0];
        let q = [0.5, 0.25, 0.25];
        assert!((jsd(&p, &q) - jsd(&q, &p)).abs() < 1e-15);
    }

    #[test]
    fn jsd_bounded_by_ln2() {
        let p = [1.0, 0.0];
        let q = [0.0, 1.0];
        assert!((jsd(&p, &q) - (2f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn kl_hand_case() {
        let p = [0.5, 0.5];
        let q = [0.25, 0.75];
        let expect = 0.5 * (0.5f64 / 0.25).ln() + 0.5 * (0.5f64 / 0.75).ln();
        assert!((kl(&p, &q) - expect).abs() < 1e-12);
    }

    /// Hand-computed non-degenerate value: P = [1, 0], Q = [½, ½] ⇒
    /// M = [¾, ¼], JSD = ½·ln(4/3) + ½·(½·ln(2/3) + ½·ln 2)
    ///               = 0.21576155433883568…
    #[test]
    fn jsd_hand_computed_value() {
        let got = jsd(&[1.0, 0.0], &[0.5, 0.5]);
        let want = 0.5 * (4f64 / 3.0).ln()
            + 0.5 * (0.5 * (2f64 / 3.0).ln() + 0.5 * (2f64).ln());
        assert!((want - 0.215_761_554_338_835_68).abs() < 1e-15, "formula sanity");
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn counts_version() {
        let exact = [0.5, 0.5];
        assert!(jsd_from_counts(&exact, &[500, 500]) < 1e-12);
        assert_eq!(jsd_from_counts(&exact, &[0, 0]), (2f64).ln());
    }
}
