//! Success metrics for GFlowNet sampling quality (gfnx `metrics/` module).
//!
//! GFlowNet evaluation differs from standard RL — raw return is *not* the
//! score; instead we compare the sampler's terminal-state distribution to
//! the target π(x) ∝ R(x):
//!
//! - [`tv`] — total variation against the exactly enumerated target
//!   (hypergrid, TFBind8, QM9).
//! - [`jsd`] — Jensen–Shannon divergence against the exact DAG posterior
//!   (structure learning).
//! - [`marginals`] — edge / path / Markov-blanket feature marginals.
//! - [`diversity`] — top-k mean reward and diversity (AMP).
//! - [`dag_enum`] — exact enumeration of all DAGs on d ≤ 5 nodes.
//!
//! The Pearson-correlation protocol (reward vs Monte-Carlo P̂_θ estimates)
//! lives in `coordinator::eval` because it needs policy rollouts.

pub mod tv;
pub mod jsd;
pub mod diversity;
pub mod marginals;
pub mod dag_enum;
