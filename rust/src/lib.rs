//! # gfnx-rs
//!
//! A Rust + JAX + Pallas reproduction of **gfnx: Fast and Scalable Library
//! for Generative Flow Networks in JAX** (Tiapkin et al., 2025).
//!
//! The stack has three layers:
//!
//! - **L3 (this crate)** — the coordinator: vectorized GFlowNet environments,
//!   decoupled reward modules, dataset generators, success metrics, rollout /
//!   training orchestration, the asynchronous actor–learner engine
//!   ([`engine`]: versioned policy snapshots, bounded actor→learner
//!   channel, live serve hot-swap, checkpointed resume), the
//!   continuous-batching sampling service ([`serve`]), and the throughput
//!   benchmark harness.
//! - **L2 (`python/compile`, build-time only, xla backend)** — policy
//!   networks and the TB/DB/SubTB/FLDB/MDB objectives in pure JAX,
//!   AOT-lowered to HLO text.
//! - **L1 (`python/compile/kernels`)** — Pallas kernels for the per-step
//!   hot-spot (fused masked log-softmax, fused dense layers).
//!
//! Training runs through the [`runtime::Backend`] abstraction: the
//! **native** backend ([`runtime::NativeBackend`]) is a pure-Rust MLP with
//! manual backward, the full TB/DB/SubTB/FLDB/MDB objective set and Adam —
//! the whole train → sample → metric loop with no artifacts — while the
//! **xla**
//! backend ([`runtime::XlaBackend`]) replays the AOT artifacts through the
//! PJRT CPU client (`xla` crate). Either way the coordinator drives
//! everything from Rust; Python never executes on the training path.
//!
//! Policy evaluation is abstracted behind
//! [`runtime::policy::BatchPolicy`] — one *fixed-shape* batched dispatch.
//! Training uses padded `[B, T+1]` rollouts
//! ([`coordinator::rollout::forward_rollout`]); sampling-as-a-service uses
//! the [`serve`] subsystem, which keeps the same fixed-shape dispatch
//! saturated by refilling a slot with the next queued trajectory the moment
//! its current one terminates (see `serve`'s module docs for the API and
//! determinism guarantees).

pub mod util {
    pub mod cli;
    pub mod json;
    pub mod linalg;
    pub mod logging;
    pub mod rng;
    pub mod stats;
    pub mod tensor;
    pub mod threadpool;
}

pub mod testing;

pub mod envs;
pub mod reward;
pub mod data;
pub mod metrics;
pub mod runtime;
pub mod coordinator;
pub mod engine;
pub mod serve;
pub mod telemetry;
pub mod bench;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::util::rng::Rng;
    pub use crate::util::stats::{pearson, ItPerSec, Welford};
    pub use crate::util::tensor::{TensorF32, TensorI32};
}
