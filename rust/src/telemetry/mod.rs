//! Unified metrics registry: counters, gauges, log-bucketed histograms, and
//! RAII span timers for the trainer / engine / serve hot paths.
//!
//! Design goals, in order:
//!
//! 1. **Near-zero cost when off.** Every instrumentation macro
//!    ([`span!`], [`count!`], [`record!`]) starts with one `Relaxed` load of
//!    a global [`AtomicBool`]; when telemetry is disabled that is the entire
//!    cost — no `Instant::now()`, no allocation, no lock. The
//!    `telemetry_overhead` bench measures this.
//! 2. **Lock-free hot path when on.** Metric handles are `Arc`s of plain
//!    atomics. Each macro call site caches its handle in a local
//!    `OnceLock`, so after first use a span is two `Instant::now()` calls
//!    plus a few `fetch_add`s. The only mutex in the subsystem guards the
//!    name → handle registration map, touched once per call site.
//! 3. **Determinism-safe.** Instrumentation only reads clocks and bumps
//!    atomics; it never draws randomness or changes control flow, so the
//!    `--sync` engine parity and serve bit-reproducibility guarantees hold
//!    with telemetry enabled.
//!
//! A [`Registry`] is either *scoped* (one per [`SamplerService`], so tests
//! and multiple services do not share counters) or the process-wide
//! [`global()`] registry that the macros feed. [`Registry::to_json`] is the
//! exact payload a future `/stats` endpoint serves; [`Exporter`] appends it
//! periodically to a [`MetricsLog`] JSONL stream.
//!
//! Histograms are power-of-two bucketed (the engine's staleness histogram,
//! generalized): bucket 0 holds values `0..=1`, bucket `i` holds
//! `[2^i, 2^(i+1))`, bucket 63 holds `>= 2^63`. A percentile is the upper
//! bound of the first bucket whose cumulative count reaches
//! `ceil(q * n)` — exact on hand-built contents, conservative (never
//! under-reports) on real ones. Span histograms record **nanoseconds**.
//!
//! [`SamplerService`]: crate::serve::SamplerService
//! [`MetricsLog`]: crate::util::logging::MetricsLog

pub mod exporter;
pub mod trace;

pub use exporter::{check_telemetry_jsonl, Exporter};
pub use trace::{check_trace_jsonl, trace_enabled, ActiveTrace, TraceRecord, Tracer};

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Global enabled flag + global registry
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();

/// Fast-path check used by the instrumentation macros.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn hot-path instrumentation on or off (process-wide).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Enable telemetry if the `GFNX_TELEMETRY` env var is truthy (`1`, `true`,
/// `on`). Returns the resulting enabled state.
pub fn init_from_env() -> bool {
    if let Ok(v) = std::env::var("GFNX_TELEMETRY") {
        let v = v.to_ascii_lowercase();
        if v == "1" || v == "true" || v == "on" {
            set_enabled(true);
        }
    }
    enabled()
}

/// The process-wide registry fed by [`span!`], [`count!`], [`record!`].
pub fn global() -> &'static Arc<Registry> {
    GLOBAL.get_or_init(|| Arc::new(Registry::new()))
}

/// Serializes tests that toggle the process-wide enabled flag (the flag is
/// global state; concurrent toggling tests would race). Test support only.
#[doc(hidden)]
pub fn flag_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Metric primitives
// ---------------------------------------------------------------------------

/// A monotonically increasing counter.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// A last-write-wins `f64` gauge (stored as bits in an `AtomicU64`).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn set(&self, x: f64) {
        self.bits.store(x.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.set(0.0);
    }
}

/// Number of power-of-two buckets (covers the full `u64` range).
pub const HIST_BUCKETS: usize = 64;

/// Bucket index for a recorded value: 0 for `0..=1`, else `floor(log2 v)`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        63 - v.leading_zeros() as usize
    }
}

/// Inclusive upper bound of bucket `i` (the value percentiles report).
pub fn bucket_upper(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

/// A lock-free log₂-bucketed histogram. Span histograms record nanoseconds;
/// value histograms (e.g. `engine.staleness`) record raw magnitudes.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
    /// `"ns"` for duration histograms, `""` for raw values. Display only.
    unit: &'static str,
}

impl Histogram {
    fn new(unit: &'static str) -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            unit,
        }
    }

    /// Record one observation.
    #[inline]
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn unit(&self) -> &'static str {
        self.unit
    }

    /// Consistent point-in-time copy (bucket counts are authoritative).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (i, b) in self.buckets.iter().enumerate() {
            buckets[i] = b.load(Ordering::Relaxed);
        }
        HistSnapshot {
            count: buckets.iter().sum(),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets,
            unit: self.unit,
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total of all recorded values (ns for span histograms).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn percentile(&self, q: f64) -> u64 {
        self.snapshot().percentile(q)
    }

    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }
}

/// Point-in-time copy of a [`Histogram`]; percentile math runs here so the
/// three quantiles of one snapshot are mutually consistent.
#[derive(Clone)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub buckets: [u64; HIST_BUCKETS],
    pub unit: &'static str,
}

impl HistSnapshot {
    /// The upper bound of the first bucket whose cumulative count reaches
    /// `ceil(q * count)`; 0 on an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if c > 0 && cum >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(HIST_BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Occupied buckets as `[index, upper_bound, count]` triples: the
    /// explicit upper bound makes exported histograms reconstructable by
    /// consumers (Prometheus `le` mapping, external dashboards) without
    /// knowledge of the internal log₂ bucketing.
    pub fn to_json(&self) -> Json {
        let mut nonzero = Vec::new();
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                nonzero.push(Json::Arr(vec![
                    Json::Num(i as f64),
                    Json::Num(bucket_upper(i) as f64),
                    Json::Num(c as f64),
                ]));
            }
        }
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum as f64)),
            ("max", Json::Num(self.max as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.percentile(0.50) as f64)),
            ("p90", Json::Num(self.percentile(0.90) as f64)),
            ("p99", Json::Num(self.percentile(0.99) as f64)),
            ("unit", Json::Str(self.unit.to_string())),
            ("buckets", Json::Arr(nonzero)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A named collection of metrics. Registration (name lookup) takes a mutex;
/// handle updates are pure atomics. Create scoped registries with
/// `Registry::new()` or use the process-wide [`global()`].
pub struct Registry {
    start: Instant,
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { start: Instant::now(), metrics: Mutex::new(BTreeMap::new()) }
    }

    /// Get-or-register a counter. Panics if `name` is already a different
    /// metric kind (a programming error, not a runtime condition).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => c.clone(),
            _ => panic!("telemetry metric '{name}' is not a counter"),
        }
    }

    /// Get-or-register a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())))
        {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("telemetry metric '{name}' is not a gauge"),
        }
    }

    /// Get-or-register a duration histogram (records nanoseconds).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_with_unit(name, "ns")
    }

    /// Get-or-register a raw-value histogram (e.g. staleness in versions).
    pub fn value_histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_with_unit(name, "")
    }

    fn histogram_with_unit(&self, name: &str, unit: &'static str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(unit))))
        {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("telemetry metric '{name}' is not a histogram"),
        }
    }

    /// Zero every metric's value. Registrations (and cached call-site
    /// handles) stay valid, so benches can reset between phases.
    pub fn reset(&self) {
        let m = self.metrics.lock().unwrap();
        for v in m.values() {
            match v {
                Metric::Counter(c) => c.reset(),
                Metric::Gauge(g) => g.reset(),
                Metric::Histogram(h) => h.reset(),
            }
        }
    }

    /// Seconds since the registry was created.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Full snapshot: `{elapsed_s, counters, gauges, histograms}`.
    ///
    /// Derived metrics: for every counter `X.flops` with a sibling span
    /// histogram `X` (sum in ns), a gauge `X.gflops` is added —
    /// FLOPs/ns happens to equal GFLOP/s numerically.
    pub fn to_json(&self) -> Json {
        // Clone handles under the lock, read values outside it.
        let handles: Vec<(String, Metric)> = {
            let m = self.metrics.lock().unwrap();
            m.iter()
                .map(|(k, v)| {
                    let h = match v {
                        Metric::Counter(c) => Metric::Counter(c.clone()),
                        Metric::Gauge(g) => Metric::Gauge(g.clone()),
                        Metric::Histogram(h) => Metric::Histogram(h.clone()),
                    };
                    (k.clone(), h)
                })
                .collect()
        };
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut hists = BTreeMap::new();
        let mut hist_sums: BTreeMap<String, u64> = BTreeMap::new();
        for (name, metric) in &handles {
            match metric {
                Metric::Counter(c) => {
                    counters.insert(name.clone(), Json::Num(c.get() as f64));
                }
                Metric::Gauge(g) => {
                    gauges.insert(name.clone(), Json::Num(g.get()));
                }
                Metric::Histogram(h) => {
                    let snap = h.snapshot();
                    hist_sums.insert(name.clone(), snap.sum);
                    hists.insert(name.clone(), snap.to_json());
                }
            }
        }
        for (name, metric) in &handles {
            if let (Metric::Counter(c), Some(stem)) = (metric, name.strip_suffix(".flops")) {
                if let Some(&sum_ns) = hist_sums.get(stem) {
                    if sum_ns > 0 {
                        gauges.insert(
                            format!("{stem}.gflops"),
                            Json::Num(c.get() as f64 / sum_ns as f64),
                        );
                    }
                }
            }
        }
        Json::obj(vec![
            ("elapsed_s", Json::Num(self.elapsed_s())),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
        ])
    }

    /// Phase-timing breakdown only (histograms), for `BenchJson` rows.
    pub fn phases_json(&self) -> Json {
        match self.to_json().get("histograms") {
            Some(h) => h.clone(),
            None => Json::Obj(BTreeMap::new()),
        }
    }

    /// Human-readable end-of-run table (sorted by name; ns histograms are
    /// shown as total ms / per-event µs).
    pub fn render(&self) -> String {
        let j = self.to_json();
        let mut s = format!("telemetry (elapsed {:.1}s)\n", self.elapsed_s());
        if let Some(h) = j.get("histograms").and_then(Json::as_obj) {
            if !h.is_empty() {
                s.push_str(&format!(
                    "  {:<28} {:>10} {:>12} {:>10} {:>10} {:>10} {:>10}\n",
                    "span/hist", "count", "total", "mean", "p50", "p90", "p99"
                ));
                for (name, v) in h {
                    let count = v.get("count").and_then(Json::as_f64).unwrap_or(0.0);
                    let sum = v.get("sum").and_then(Json::as_f64).unwrap_or(0.0);
                    let mean = v.get("mean").and_then(Json::as_f64).unwrap_or(0.0);
                    let p50 = v.get("p50").and_then(Json::as_f64).unwrap_or(0.0);
                    let p90 = v.get("p90").and_then(Json::as_f64).unwrap_or(0.0);
                    let p99 = v.get("p99").and_then(Json::as_f64).unwrap_or(0.0);
                    let ns = v.get("unit").and_then(Json::as_str) == Some("ns");
                    if ns {
                        s.push_str(&format!(
                            "  {:<28} {:>10} {:>10.1}ms {:>8.1}µs {:>8.1}µs {:>8.1}µs {:>8.1}µs\n",
                            name,
                            count,
                            sum / 1e6,
                            mean / 1e3,
                            p50 / 1e3,
                            p90 / 1e3,
                            p99 / 1e3,
                        ));
                    } else {
                        s.push_str(&format!(
                            "  {:<28} {:>10} {:>12} {:>10.1} {:>10} {:>10} {:>10}\n",
                            name, count, sum, mean, p50, p90, p99,
                        ));
                    }
                }
            }
        }
        if let Some(c) = j.get("counters").and_then(Json::as_obj) {
            for (name, v) in c {
                s.push_str(&format!(
                    "  counter {name} = {}\n",
                    v.as_f64().unwrap_or(0.0)
                ));
            }
        }
        if let Some(g) = j.get("gauges").and_then(Json::as_obj) {
            for (name, v) in g {
                s.push_str(&format!(
                    "  gauge   {name} = {:.4}\n",
                    v.as_f64().unwrap_or(0.0)
                ));
            }
        }
        s
    }

    /// Render the registry in Prometheus text exposition format (served by
    /// `GET /metrics` with `Content-Type: text/plain; version=0.0.4`).
    ///
    /// Dotted metric names are sanitized to `[a-zA-Z0-9_:]`. Each log₂
    /// histogram maps to a cumulative `le`-bucketed Prometheus histogram:
    /// every occupied bucket emits one line keyed by its inclusive upper
    /// bound ([`bucket_upper`]), closed by `le="+Inf"`, `_sum`, and
    /// `_count`. The series reads the same atomics as [`Registry::to_json`],
    /// so `/metrics` and `/stats` agree.
    pub fn render_prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut s: String = name
                .chars()
                .map(|c| {
                    if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                        c
                    } else {
                        '_'
                    }
                })
                .collect();
            if s.chars().next().is_some_and(|c| c.is_ascii_digit()) {
                s.insert(0, '_');
            }
            s
        }
        // Clone handles under the lock, read values outside it (the same
        // discipline as to_json: exposition must not stall the hot path).
        let handles: Vec<(String, Metric)> = {
            let m = self.metrics.lock().unwrap();
            m.iter()
                .map(|(k, v)| {
                    let h = match v {
                        Metric::Counter(c) => Metric::Counter(c.clone()),
                        Metric::Gauge(g) => Metric::Gauge(g.clone()),
                        Metric::Histogram(h) => Metric::Histogram(h.clone()),
                    };
                    (k.clone(), h)
                })
                .collect()
        };
        let mut out = String::new();
        for (name, metric) in &handles {
            let n = sanitize(name);
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
                }
                Metric::Histogram(h) => {
                    let s = h.snapshot();
                    out.push_str(&format!("# TYPE {n} histogram\n"));
                    let mut cum = 0u64;
                    for (i, &c) in s.buckets.iter().enumerate() {
                        if c > 0 {
                            cum += c;
                            out.push_str(&format!(
                                "{n}_bucket{{le=\"{}\"}} {cum}\n",
                                bucket_upper(i)
                            ));
                        }
                    }
                    out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", s.count));
                    out.push_str(&format!("{n}_sum {}\n", s.sum));
                    out.push_str(&format!("{n}_count {}\n", s.count));
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// RAII span timer
// ---------------------------------------------------------------------------

/// RAII guard recording elapsed nanoseconds into a histogram on drop.
/// Construct via the [`span!`] macro (which handles the enabled fast path).
pub struct SpanGuard {
    inner: Option<(Arc<Histogram>, Instant)>,
}

impl SpanGuard {
    /// An active guard: starts timing now, records on drop.
    pub fn active(h: Arc<Histogram>) -> SpanGuard {
        SpanGuard { inner: Some((h, Instant::now())) }
    }

    /// A disabled guard: drop is a no-op.
    pub fn off() -> SpanGuard {
        SpanGuard { inner: None }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((h, t0)) = self.inner.take() {
            h.record(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// Time a scope into a global-registry span histogram (nanoseconds):
/// `let _t = crate::span!("native.dispatch");` — near-zero cost when
/// telemetry is disabled.
#[macro_export]
macro_rules! span {
    ($name:expr) => {{
        if $crate::telemetry::enabled() {
            static __H: std::sync::OnceLock<std::sync::Arc<$crate::telemetry::Histogram>> =
                std::sync::OnceLock::new();
            $crate::telemetry::SpanGuard::active(
                __H.get_or_init(|| $crate::telemetry::global().histogram($name)).clone(),
            )
        } else {
            $crate::telemetry::SpanGuard::off()
        }
    }};
}

/// Bump a global-registry counter by `n` when telemetry is enabled:
/// `crate::count!("native.gemm.dense.flops", flops);`
#[macro_export]
macro_rules! count {
    ($name:expr, $n:expr) => {{
        if $crate::telemetry::enabled() {
            static __C: std::sync::OnceLock<std::sync::Arc<$crate::telemetry::Counter>> =
                std::sync::OnceLock::new();
            __C.get_or_init(|| $crate::telemetry::global().counter($name))
                .add(($n) as u64);
        }
    }};
}

/// Record a raw value into a global-registry value histogram when telemetry
/// is enabled: `crate::record!("engine.staleness", staleness);`
#[macro_export]
macro_rules! record {
    ($name:expr, $v:expr) => {{
        if $crate::telemetry::enabled() {
            static __H: std::sync::OnceLock<std::sync::Arc<$crate::telemetry::Histogram>> =
                std::sync::OnceLock::new();
            __H.get_or_init(|| $crate::telemetry::global().value_histogram($name))
                .record(($v) as u64);
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let reg = Registry::new();
        let c = reg.counter("a.b");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        assert_eq!(reg.counter("a.b").get(), 5, "get-or-register returns the same atom");
        let g = reg.gauge("occ");
        g.set(0.75);
        assert!((reg.gauge("occ").get() - 0.75).abs() < 1e-12);
        reg.reset();
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0.0);
    }

    #[test]
    #[should_panic(expected = "not a gauge")]
    fn kind_mismatch_panics() {
        let reg = Registry::new();
        let _ = reg.counter("x");
        let _ = reg.gauge("x");
    }

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(7), 2);
        assert_eq!(bucket_index(8), 3);
        assert_eq!(bucket_index(1 << 20), 20);
        assert_eq!(bucket_index((1 << 21) - 1), 20);
        assert_eq!(bucket_index(u64::MAX), 63);
        assert_eq!(bucket_upper(0), 1);
        assert_eq!(bucket_upper(2), 7);
        assert_eq!(bucket_upper(63), u64::MAX);
    }

    /// Satellite: percentile math exact on hand-built bucket contents.
    #[test]
    fn percentiles_exact_on_hand_built_buckets() {
        let h = Histogram::new("ns");
        // 50 values in bucket 0 (v=1), 45 in bucket 6 (v=100: 64..127),
        // 5 in bucket 13 (v=10_000: 8192..16383). n = 100.
        for _ in 0..50 {
            h.record(1);
        }
        for _ in 0..45 {
            h.record(100);
        }
        for _ in 0..5 {
            h.record(10_000);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.sum, 50 + 45 * 100 + 5 * 10_000);
        assert_eq!(s.max, 10_000);
        // p50: rank ceil(0.5*100)=50, cum(bucket 0)=50 >= 50 → upper(0)=1.
        assert_eq!(s.percentile(0.50), 1);
        // p90: rank 90, cum(bucket 6)=95 >= 90 → upper(6)=127.
        assert_eq!(s.percentile(0.90), 127);
        // p99: rank 99, cum(bucket 13)=100 >= 99 → upper(13)=16383.
        assert_eq!(s.percentile(0.99), 16383);
        // p100 and p0 edge cases.
        assert_eq!(s.percentile(1.0), 16383);
        assert_eq!(s.percentile(0.0), 1);
        assert_eq!(Histogram::new("ns").snapshot().percentile(0.5), 0);
    }

    #[test]
    fn percentile_rank_uses_first_covering_bucket() {
        let h = Histogram::new("");
        // 1..=100 → bucket 0 holds {1}, bucket i holds [2^i, 2^{i+1}) ∩ [1,100].
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.snapshot();
        // cum by bucket: b0=1, b1=3, b2=7, b3=15, b4=31, b5=63, b6=100.
        assert_eq!(s.percentile(0.50), 63); // rank 50 lands in bucket 5
        assert_eq!(s.percentile(0.90), 127); // rank 90 lands in bucket 6
        assert_eq!(s.percentile(0.99), 127);
    }

    #[test]
    fn histogram_reset_clears_everything() {
        let h = Histogram::new("ns");
        h.record(5);
        h.record(500);
        h.reset();
        let s = h.snapshot();
        assert_eq!((s.count, s.sum, s.max), (0, 0, 0));
        assert!(s.buckets.iter().all(|&c| c == 0));
    }

    #[test]
    fn to_json_shape_and_derived_gflops() {
        let reg = Registry::new();
        reg.counter("native.gemm.dense.flops").add(2_000);
        let h = reg.histogram("native.gemm.dense");
        h.record(500);
        h.record(500); // sum = 1000 ns → 2000 flops / 1000 ns = 2.0 GFLOP/s
        reg.gauge("serve.occupancy").set(0.5);
        let j = reg.to_json();
        assert!(j.get("elapsed_s").and_then(Json::as_f64).unwrap() >= 0.0);
        let c = j.get("counters").unwrap();
        assert_eq!(c.get("native.gemm.dense.flops").unwrap().as_usize(), Some(2000));
        let g = j.get("gauges").unwrap();
        assert_eq!(g.get("serve.occupancy").unwrap().as_f64(), Some(0.5));
        assert_eq!(g.get("native.gemm.dense.gflops").unwrap().as_f64(), Some(2.0));
        let hist = j.get("histograms").unwrap().get("native.gemm.dense").unwrap();
        assert_eq!(hist.get("count").unwrap().as_usize(), Some(2));
        assert_eq!(hist.get("sum").unwrap().as_usize(), Some(1000));
        assert_eq!(hist.get("unit").unwrap().as_str(), Some("ns"));
        // Round-trips through the project's JSON writer/parser.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!(parsed.get("histograms").is_some());
        // Render mentions the span and doesn't panic.
        assert!(reg.render().contains("native.gemm.dense"));
    }

    /// Satellite: exported buckets carry explicit `[index, upper, count]`
    /// triples, exact on hand-built contents.
    #[test]
    fn hist_json_buckets_carry_explicit_upper_bounds() {
        let h = Histogram::new("ns");
        for _ in 0..3 {
            h.record(1); // bucket 0, upper 1
        }
        for _ in 0..2 {
            h.record(100); // bucket 6 (64..=127), upper 127
        }
        h.record(u64::MAX); // bucket 63, upper u64::MAX
        let j = h.snapshot().to_json();
        // Existing fields are unchanged.
        assert_eq!(j.get("count").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("p50").unwrap().as_usize(), Some(127));
        let buckets = j.get("buckets").and_then(Json::as_arr).unwrap();
        let triple = |b: &Json| {
            let t = b.as_arr().unwrap();
            (
                t[0].as_f64().unwrap(),
                t[1].as_f64().unwrap(),
                t[2].as_f64().unwrap(),
            )
        };
        assert_eq!(buckets.len(), 3, "only occupied buckets exported");
        assert_eq!(triple(&buckets[0]), (0.0, 1.0, 3.0));
        assert_eq!(triple(&buckets[1]), (6.0, 127.0, 2.0));
        assert_eq!(triple(&buckets[2]), (63.0, u64::MAX as f64, 1.0));
        // Round-trips through the project's JSON writer/parser.
        let parsed = Json::parse(&j.to_string()).unwrap();
        let rt = parsed.get("buckets").and_then(Json::as_arr).unwrap();
        assert_eq!(triple(&rt[1]), (6.0, 127.0, 2.0));
    }

    #[test]
    fn prometheus_rendering_is_cumulative_and_consistent() {
        let reg = Registry::new();
        reg.counter("serve.requests_completed").add(7);
        reg.gauge("serve.occupancy").set(0.25);
        let h = reg.histogram("serve.request_latency");
        for _ in 0..3 {
            h.record(1);
        }
        for _ in 0..2 {
            h.record(100);
        }
        h.record(10_000);
        let text = reg.render_prometheus();
        // Names are sanitized and typed.
        assert!(text.contains("# TYPE serve_requests_completed counter\n"));
        assert!(text.contains("serve_requests_completed 7\n"));
        assert!(text.contains("# TYPE serve_occupancy gauge\n"));
        assert!(text.contains("serve_occupancy 0.25\n"));
        assert!(text.contains("# TYPE serve_request_latency histogram\n"));
        // Buckets are cumulative: 3 @ le=1, 5 @ le=127, 6 @ le=16383, +Inf.
        assert!(text.contains("serve_request_latency_bucket{le=\"1\"} 3\n"));
        assert!(text.contains("serve_request_latency_bucket{le=\"127\"} 5\n"));
        assert!(text.contains("serve_request_latency_bucket{le=\"16383\"} 6\n"));
        assert!(text.contains("serve_request_latency_bucket{le=\"+Inf\"} 6\n"));
        assert!(text.contains("serve_request_latency_sum 10203\n"));
        assert!(text.contains("serve_request_latency_count 6\n"));
        // Cumulative counts are monotone non-decreasing in le order.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("serve_request_latency_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket counts must be cumulative: {line}");
            last = v;
        }
        assert_eq!(last, 6);
    }

    #[test]
    fn span_macro_times_into_global_registry() {
        let _guard = flag_test_lock();
        let was = enabled();
        set_enabled(true);
        let h = global().histogram("test.span.unit");
        let before = h.count();
        {
            let _t = crate::span!("test.span.unit");
            std::hint::black_box(1 + 1);
        }
        assert!(h.count() > before, "enabled span must record");
        set_enabled(false);
        let at_off = h.count();
        {
            let _t = crate::span!("test.span.unit");
        }
        assert_eq!(h.count(), at_off, "disabled span must not record");
        crate::count!("test.span.counter", 3); // disabled → no-op
        assert_eq!(global().counter("test.span.counter").get(), 0);
        set_enabled(was);
    }

    #[test]
    fn value_record_macro_feeds_value_histogram() {
        let _guard = flag_test_lock();
        let was = enabled();
        set_enabled(true);
        crate::record!("test.record.unit", 9usize);
        let h = global().value_histogram("test.record.unit");
        assert!(h.count() >= 1);
        assert_eq!(h.unit(), "");
        set_enabled(was);
    }
}
