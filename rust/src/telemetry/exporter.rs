//! Periodic JSONL export of registry snapshots, plus the schema validator
//! behind the `check-telemetry` CLI subcommand.
//!
//! The exporter reuses the [`MetricsLog`] JSONL stream: each line is
//! `{"run": ..., "step": k, "t": secs, "telemetry": <Registry::to_json()>}`
//! where `step` counts snapshots. A final snapshot is always written on
//! [`Exporter::stop`] (or drop), so even runs shorter than the export
//! interval produce at least one line.

use crate::telemetry::Registry;
use crate::util::json::Json;
use crate::util::logging::MetricsLog;
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

struct Shared {
    stop: Mutex<bool>,
    cv: Condvar,
}

/// Background thread appending registry snapshots to a JSONL file every
/// `interval`. Stop (or drop) flushes one last snapshot and joins.
pub struct Exporter {
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl Exporter {
    /// Spawn the exporter thread. The file is opened (append mode) in the
    /// caller's thread so setup errors surface immediately.
    pub fn spawn(
        run: &str,
        path: &Path,
        interval: Duration,
        registry: Arc<Registry>,
    ) -> anyhow::Result<Exporter> {
        let mut log = MetricsLog::to_file(run, path)?;
        let shared = Arc::new(Shared { stop: Mutex::new(false), cv: Condvar::new() });
        let thread_shared = shared.clone();
        let interval = interval.max(Duration::from_millis(10));
        let handle = std::thread::Builder::new()
            .name("gfnx-telemetry".to_string())
            .spawn(move || {
                let mut step = 0u64;
                loop {
                    let stopped = {
                        let guard = thread_shared.stop.lock().unwrap();
                        if *guard {
                            true
                        } else {
                            let (guard, _) =
                                thread_shared.cv.wait_timeout(guard, interval).unwrap();
                            *guard
                        }
                    };
                    step += 1;
                    log.log_values(step, &[("telemetry", registry.to_json())]);
                    if stopped {
                        break;
                    }
                }
                log.flush();
            })?;
        Ok(Exporter { shared, handle: Some(handle) })
    }

    /// Write a final snapshot, flush, and join the exporter thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        if let Some(h) = self.handle.take() {
            *self.shared.stop.lock().unwrap() = true;
            self.shared.cv.notify_all();
            let _ = h.join();
        }
    }
}

impl Drop for Exporter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Validate a telemetry JSONL file (the `check-telemetry` subcommand).
///
/// Every line must be a JSON object with `run`/`step`/`t` and a `telemetry`
/// object holding `counters`/`gauges`/`histograms`; each histogram needs
/// numeric `count`/`sum`/`max`/`mean`/`p50`/`p90`/`p99` with monotone
/// percentiles. Each name in `required_spans` must appear in the **final**
/// snapshot's histograms with a nonzero count. Returns a summary line.
pub fn check_telemetry_jsonl(text: &str, required_spans: &[&str]) -> anyhow::Result<String> {
    let mut snapshots = 0usize;
    let mut last: Option<Json> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        j.req_str("run")?;
        for key in ["step", "t"] {
            j.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("line {}: '{key}' is not a number", lineno + 1))?;
        }
        let tel = j.req("telemetry")?;
        tel.req("elapsed_s")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("line {}: 'elapsed_s' is not a number", lineno + 1))?;
        for section in ["counters", "gauges", "histograms"] {
            tel.req(section)?
                .as_obj()
                .ok_or_else(|| {
                    anyhow::anyhow!("line {}: '{section}' is not an object", lineno + 1)
                })?;
        }
        let hists = tel.get("histograms").unwrap().as_obj().unwrap();
        for (name, h) in hists {
            let field = |key: &str| -> anyhow::Result<f64> {
                h.req(key)?.as_f64().ok_or_else(|| {
                    anyhow::anyhow!(
                        "line {}: histogram '{name}' field '{key}' is not a number",
                        lineno + 1
                    )
                })
            };
            let count = field("count")?;
            let sum = field("sum")?;
            field("max")?;
            field("mean")?;
            let p50 = field("p50")?;
            let p90 = field("p90")?;
            let p99 = field("p99")?;
            anyhow::ensure!(
                count >= 0.0 && sum >= 0.0,
                "line {}: histogram '{name}' has negative count/sum",
                lineno + 1
            );
            anyhow::ensure!(
                p50 <= p90 && p90 <= p99,
                "line {}: histogram '{name}' percentiles not monotone ({p50} / {p90} / {p99})",
                lineno + 1
            );
        }
        snapshots += 1;
        last = Some(j);
    }
    anyhow::ensure!(snapshots > 0, "no telemetry snapshots found");
    let last = last.unwrap();
    let hists = last.get("telemetry").unwrap().get("histograms").unwrap();
    for span in required_spans {
        let h = hists
            .get(span)
            .ok_or_else(|| anyhow::anyhow!("required span '{span}' missing from final snapshot"))?;
        let count = h.get("count").and_then(Json::as_f64).unwrap_or(0.0);
        anyhow::ensure!(count > 0.0, "required span '{span}' has zero count in final snapshot");
    }
    let n_hists = hists.as_obj().map(|m| m.len()).unwrap_or(0);
    Ok(format!(
        "ok: {snapshots} snapshots, {n_hists} histograms in final snapshot, {} required spans nonzero",
        required_spans.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exporter_writes_final_snapshot_on_stop() {
        let dir = std::env::temp_dir().join("gfnx_telemetry_test");
        let path = dir.join("export.jsonl");
        let _ = std::fs::remove_file(&path);
        let reg = Arc::new(Registry::new());
        reg.histogram("trainer.rollout").record(1_000);
        reg.counter("engine.batches").add(7);
        let exp = Exporter::spawn("unit", &path, Duration::from_secs(3600), reg.clone())
            .unwrap();
        reg.histogram("trainer.rollout").record(2_000);
        // Stop long before the first interval elapses: the final snapshot
        // must still be written.
        exp.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let summary = check_telemetry_jsonl(&text, &["trainer.rollout"]).unwrap();
        assert!(summary.starts_with("ok:"), "{summary}");
        let last = Json::parse(text.lines().last().unwrap()).unwrap();
        assert_eq!(last.get("run").unwrap().as_str(), Some("unit"));
        let h = last
            .get("telemetry")
            .unwrap()
            .get("histograms")
            .unwrap()
            .get("trainer.rollout")
            .unwrap();
        assert_eq!(h.get("count").unwrap().as_usize(), Some(2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn exporter_dropped_before_first_interval_writes_exactly_one_line() {
        let dir = std::env::temp_dir().join("gfnx_telemetry_test");
        let path = dir.join("dropped.jsonl");
        let _ = std::fs::remove_file(&path);
        let reg = Arc::new(Registry::new());
        reg.counter("c").add(1);
        let exp = Exporter::spawn("unit", &path, Duration::from_secs(3600), reg).unwrap();
        drop(exp);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines = text.lines().filter(|l| !l.trim().is_empty()).count();
        assert_eq!(lines, 1, "drop before the first interval must write exactly one snapshot");
        check_telemetry_jsonl(&text, &[]).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn zero_interval_is_clamped() {
        let dir = std::env::temp_dir().join("gfnx_telemetry_test");
        let path = dir.join("clamped.jsonl");
        let _ = std::fs::remove_file(&path);
        let reg = Arc::new(Registry::new());
        reg.counter("c").add(1);
        let exp = Exporter::spawn("unit", &path, Duration::ZERO, reg).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        exp.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines = text.lines().filter(|l| !l.trim().is_empty()).count();
        // The 10 ms floor bounds a zero interval: ~5-6 snapshots in 50 ms
        // plus the final one, not a busy loop's thousands.
        assert!((1..=25).contains(&lines), "zero interval not clamped: {lines} lines in 50ms");
        check_telemetry_jsonl(&text, &[]).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unwritable_path_surfaces_spawn_error() {
        let dir = std::env::temp_dir().join("gfnx_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        // The directory itself is not openable as an append-mode file: the
        // error must surface from spawn(), not die inside the thread.
        let err = Exporter::spawn("unit", &dir, Duration::from_millis(20), Arc::new(Registry::new()));
        assert!(err.is_err(), "spawning onto a directory path must fail");
    }

    #[test]
    fn exporter_emits_periodic_snapshots() {
        let dir = std::env::temp_dir().join("gfnx_telemetry_test");
        let path = dir.join("periodic.jsonl");
        let _ = std::fs::remove_file(&path);
        let reg = Arc::new(Registry::new());
        reg.counter("c").add(1);
        let exp =
            Exporter::spawn("unit", &path, Duration::from_millis(20), reg.clone()).unwrap();
        std::thread::sleep(Duration::from_millis(120));
        exp.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines = text.lines().filter(|l| !l.trim().is_empty()).count();
        assert!(lines >= 3, "expected several periodic snapshots, got {lines}");
        check_telemetry_jsonl(&text, &[]).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn validator_rejects_bad_input() {
        assert!(check_telemetry_jsonl("", &[]).is_err());
        assert!(check_telemetry_jsonl("not json\n", &[]).is_err());
        // Valid shell but missing the telemetry payload.
        let line = r#"{"run":"x","step":1,"t":0.5}"#;
        assert!(check_telemetry_jsonl(line, &[]).is_err());
        // Monotone-percentile violation.
        let bad = r#"{"run":"x","step":1,"t":0.5,"telemetry":{"elapsed_s":1,"counters":{},"gauges":{},"histograms":{"s":{"count":1,"sum":5,"max":5,"mean":5,"p50":7,"p90":3,"p99":7,"unit":"ns","buckets":[[2,1]]}}}}"#;
        assert!(check_telemetry_jsonl(bad, &[]).is_err());
        // Required span missing or zero.
        let reg = Registry::new();
        reg.histogram("present").record(5);
        let good = Json::obj(vec![
            ("run", Json::Str("x".into())),
            ("step", Json::Num(1.0)),
            ("t", Json::Num(0.1)),
            ("telemetry", reg.to_json()),
        ])
        .to_string();
        check_telemetry_jsonl(&good, &["present"]).unwrap();
        assert!(check_telemetry_jsonl(&good, &["absent"]).is_err());
    }
}
