//! Sampled per-request / per-step tracing: the "why was *this* one slow"
//! layer over the aggregate [`Registry`](super::Registry) histograms.
//!
//! A [`TraceRecord`] is a waterfall of named [`TraceSegment`]s (offsets in
//! nanoseconds from the trace's start) for one sampled unit of work — an
//! HTTP sample request (`parse → queue_wait → dispatch×N → drain → write`)
//! or one engine learner step (`rollout → push_wait → learn → publish`).
//! Completed records land in a fixed-capacity ring ([`Tracer`]) served by
//! `GET /trace`, and optionally in a JSONL sink validated by the
//! `check-trace` CLI subcommand ([`check_trace_jsonl`]).
//!
//! Design rules, matching the parent module's:
//!
//! 1. **One relaxed load when off.** Every instrumentation site starts with
//!    [`trace_enabled`]; with `GFNX_TRACE` unset that load is the entire
//!    cost (the `telemetry_overhead` bench enforces `< 100 ns`).
//! 2. **Determinism-safe sampling.** The sampler is a shared counter
//!    (`every Nth` unit traces), never an RNG draw — tracing cannot perturb
//!    the `--sync` parity or serve bit-reproducibility guarantees.
//! 3. **Kill-safe export.** The JSONL sink flushes after every record, so a
//!    SIGTERM'd server (the CI smoke kills `serve` mid-run) loses nothing.
//!
//! Sampling is controlled by `GFNX_TRACE` (`off` by default): `0`/`off`/
//! `false` disable, `on`/`true` sample at [`DEFAULT_RATE`], a number in
//! `(0, 1]` samples that fraction (`1` = every request). The first unit
//! after enabling is always sampled (counter 0 matches every period), so
//! even a two-request smoke run produces a trace.

use super::Registry;
use crate::util::json::Json;
use crate::util::logging::MetricsLog;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Completed-trace ring capacity (what `GET /trace?n=K` can look back over).
pub const TRACE_RING: usize = 256;

/// Sampling rate used for `GFNX_TRACE=on` (one traced unit per 64).
pub const DEFAULT_RATE: f64 = 1.0 / 64.0;

/// Per-trace segment cap; excess dispatch slices merge into one overflow
/// segment so a 10k-dispatch drain cannot balloon a record.
pub const MAX_SEGMENTS: usize = 64;

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACER: OnceLock<Arc<Tracer>> = OnceLock::new();

/// Fast-path gate for every tracing site. One `Relaxed` atomic load.
#[inline(always)]
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Set the sampling rate (fraction of units traced, clamped to `(0, 1]`).
/// `rate <= 0` (or non-finite) disables tracing entirely.
pub fn set_trace_rate(rate: f64) {
    if !rate.is_finite() || rate <= 0.0 {
        TRACE_ON.store(false, Ordering::Relaxed);
        return;
    }
    let period = (1.0 / rate.min(1.0)).round().max(1.0) as u64;
    tracer().period.store(period, Ordering::Relaxed);
    TRACE_ON.store(true, Ordering::Relaxed);
}

/// The configured sampling rate (`0.0` when tracing is off).
pub fn trace_rate() -> f64 {
    if !trace_enabled() {
        return 0.0;
    }
    1.0 / tracer().period.load(Ordering::Relaxed).max(1) as f64
}

/// Configure tracing from `GFNX_TRACE` (see the module docs for the
/// grammar). Returns the resulting enabled state.
pub fn init_from_env() -> bool {
    if let Ok(v) = std::env::var("GFNX_TRACE") {
        match v.to_ascii_lowercase().as_str() {
            "" | "0" | "off" | "false" => TRACE_ON.store(false, Ordering::Relaxed),
            "on" | "true" => set_trace_rate(DEFAULT_RATE),
            other => {
                if let Ok(rate) = other.parse::<f64>() {
                    set_trace_rate(rate);
                }
            }
        }
    }
    trace_enabled()
}

/// The process-wide tracer (ring + sampler + optional JSONL sink).
pub fn tracer() -> &'static Arc<Tracer> {
    TRACER.get_or_init(|| Arc::new(Tracer::new()))
}

/// Deterministic sampling decision: true for every `period`-th unit
/// (counter-based — no RNG, so instrumentation cannot perturb seeded
/// streams). One relaxed load when tracing is off.
#[inline]
pub fn sampled() -> bool {
    if !trace_enabled() {
        return false;
    }
    let t = tracer();
    let n = t.sample_ctr.fetch_add(1, Ordering::Relaxed);
    n % t.period.load(Ordering::Relaxed).max(1) == 0
}

/// Start a trace for one unit of work if tracing is on *and* the sampler
/// picks it. The returned handle is shared (`Arc`) across the threads that
/// contribute segments; exactly one site should call
/// [`ActiveTrace::finish`].
pub fn try_start(kind: &'static str) -> Option<Arc<ActiveTrace>> {
    if !sampled() {
        return None;
    }
    Some(Arc::new(ActiveTrace {
        id: tracer().mint_id(),
        kind,
        t0: Instant::now(),
        inner: Mutex::new(Waterfall::default()),
    }))
}

/// Reset the sampling counter (tests pin "every Nth" phase). Test support.
#[doc(hidden)]
pub fn reset_sampler() {
    tracer().sample_ctr.store(0, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------------

/// One timed phase of a trace, offset-encoded against the trace start.
#[derive(Clone, Debug)]
pub struct TraceSegment {
    pub name: String,
    /// Nanoseconds from the trace start to this segment's start.
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// A completed trace: the unit's identity, total latency, and waterfall.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    /// Process-unique trace id (minted at start).
    pub id: u64,
    /// `"http_request"` or `"engine_step"`.
    pub kind: String,
    /// Start-to-finish nanoseconds. Every segment satisfies
    /// `start_ns + dur_ns <= total_ns`.
    pub total_ns: u64,
    /// Whether the unit succeeded (HTTP 200 / finite loss).
    pub ok: bool,
    pub segments: Vec<TraceSegment>,
    /// Small numeric annotations (status, n, version, staleness, …).
    pub meta: Vec<(String, f64)>,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        let segs: Vec<Json> = self
            .segments
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::Str(s.name.clone())),
                    ("start_ns", Json::Num(s.start_ns as f64)),
                    ("dur_ns", Json::Num(s.dur_ns as f64)),
                ])
            })
            .collect();
        let meta: Vec<(&str, Json)> =
            self.meta.iter().map(|(k, v)| (k.as_str(), Json::Num(*v))).collect();
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("kind", Json::Str(self.kind.clone())),
            ("total_ns", Json::Num(self.total_ns as f64)),
            ("ok", Json::Bool(self.ok)),
            ("segments", Json::Arr(segs)),
            ("meta", Json::obj(meta)),
        ])
    }
}

#[derive(Default)]
struct Waterfall {
    segments: Vec<TraceSegment>,
    meta: Vec<(String, f64)>,
    finished: bool,
}

/// An in-progress trace. Segment offsets are measured against `t0` (the
/// mint time), so contributors on other threads just hand in `Instant`s.
pub struct ActiveTrace {
    id: u64,
    kind: &'static str,
    t0: Instant,
    inner: Mutex<Waterfall>,
}

impl ActiveTrace {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Nanoseconds from the trace start to `t` (0 if `t` predates it).
    pub fn offset_ns(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.t0)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
    }

    /// Record a `[start, end)` segment. Beyond [`MAX_SEGMENTS`] the
    /// overflow merges into the final segment's duration (dispatch slices
    /// are disjoint and in-order, so the merged segment still satisfies
    /// `start + dur <= total`).
    pub fn segment(&self, name: &str, start: Instant, end: Instant) {
        let start_ns = self.offset_ns(start);
        let dur_ns = end
            .checked_duration_since(start)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let mut w = self.inner.lock().unwrap();
        if w.segments.len() < MAX_SEGMENTS {
            w.segments.push(TraceSegment { name: name.to_string(), start_ns, dur_ns });
        } else if let Some(last) = w.segments.last_mut() {
            last.dur_ns += dur_ns;
        }
    }

    /// Attach a numeric annotation.
    pub fn meta(&self, key: &str, value: f64) {
        self.inner.lock().unwrap().meta.push((key.to_string(), value));
    }

    /// Close the trace (idempotent: only the first call emits a record)
    /// and push it into the global ring + sink.
    pub fn finish(&self, ok: bool) {
        let rec = {
            let mut w = self.inner.lock().unwrap();
            if w.finished {
                return;
            }
            w.finished = true;
            TraceRecord {
                id: self.id,
                kind: self.kind.to_string(),
                total_ns: self.t0.elapsed().as_nanos() as u64,
                ok,
                segments: std::mem::take(&mut w.segments),
                meta: std::mem::take(&mut w.meta),
            }
        };
        tracer().push_record(rec);
    }
}

// ---------------------------------------------------------------------------
// Tracer: ring + sampler + sink
// ---------------------------------------------------------------------------

/// The process-wide trace collector: a fixed ring of the most recent
/// completed records (each slot independently locked, so readers never
/// stall the hot path for long), the sampling counter, and an optional
/// flush-per-record JSONL sink.
pub struct Tracer {
    next_id: AtomicU64,
    sample_ctr: AtomicU64,
    /// Sample every `period`-th unit (1 = all).
    period: AtomicU64,
    /// Completed-record sequence counter (ring cursor).
    cursor: AtomicU64,
    ring: Vec<Mutex<Option<(u64, TraceRecord)>>>,
    sink: Mutex<Option<MetricsLog>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer {
            next_id: AtomicU64::new(1),
            sample_ctr: AtomicU64::new(0),
            period: AtomicU64::new(1),
            cursor: AtomicU64::new(0),
            ring: (0..TRACE_RING).map(|_| Mutex::new(None)).collect(),
            sink: Mutex::new(None),
        }
    }

    /// A fresh process-unique trace id.
    pub fn mint_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Store a completed record (ring + sink). Also the entry point for
    /// records assembled manually (the engine builds its step waterfall
    /// from timings measured across actor and learner threads).
    pub fn push_record(&self, rec: TraceRecord) {
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        if let Ok(mut sink) = self.sink.lock() {
            if let Some(log) = sink.as_mut() {
                log.log_values(seq, &[("trace", rec.to_json())]);
                // Flush per record: the serve process is routinely killed
                // (CI smoke, operator SIGTERM) and a buffered tail would
                // silently vanish.
                log.flush();
            }
        }
        *self.ring[(seq as usize) % self.ring.len()].lock().unwrap() = Some((seq, rec));
    }

    /// The most recent `n` completed records, newest first.
    pub fn recent(&self, n: usize) -> Vec<TraceRecord> {
        let mut tagged: Vec<(u64, TraceRecord)> = self
            .ring
            .iter()
            .filter_map(|slot| slot.lock().unwrap().clone())
            .collect();
        tagged.sort_by(|a, b| b.0.cmp(&a.0));
        tagged.truncate(n);
        tagged.into_iter().map(|(_, r)| r).collect()
    }

    /// [`Tracer::recent`] as the `GET /trace` JSON payload.
    pub fn recent_json(&self, n: usize) -> Json {
        Json::obj(vec![
            ("rate", Json::Num(trace_rate())),
            (
                "traces",
                Json::Arr(self.recent(n).iter().map(TraceRecord::to_json).collect()),
            ),
        ])
    }

    /// Attach (or replace) the JSONL sink. The file is opened here so
    /// setup errors surface at configuration time, mirroring
    /// [`Exporter::spawn`](super::Exporter::spawn).
    pub fn set_sink(&self, run: &str, path: &Path) -> anyhow::Result<()> {
        let log = MetricsLog::to_file(run, path)?;
        *self.sink.lock().unwrap() = Some(log);
        Ok(())
    }

    /// Detach the sink, flushing buffered lines (drop flushes).
    pub fn clear_sink(&self) {
        *self.sink.lock().unwrap() = None;
    }
}

/// Touch the watchdog heartbeat gauge `name` in `registry`: stores the
/// registry's own elapsed-seconds clock, so a reader computes the age as
/// `registry.elapsed_s() - gauge` without any cross-clock skew. Heartbeats
/// are plain registry gauges — they work (and `/healthz` stays honest)
/// whether or not the `--telemetry` flag is on.
pub fn beat(registry: &Registry, name: &str) {
    registry.gauge(name).set(registry.elapsed_s());
}

// ---------------------------------------------------------------------------
// JSONL validation (the `check-trace` subcommand)
// ---------------------------------------------------------------------------

/// Validate a trace JSONL export. Every line must be
/// `{"run", "step", "t", "trace": {...}}` where the trace object carries a
/// numeric `id`, string `kind`, numeric `total_ns >= 0`, boolean `ok`, a
/// `segments` array of `{name, start_ns, dur_ns}` objects each contained in
/// `[0, total_ns]`, and an object `meta`. Each name in `required_segments`
/// must appear in at least one record. Returns a summary line.
pub fn check_trace_jsonl(text: &str, required_segments: &[&str]) -> anyhow::Result<String> {
    let mut traces = 0usize;
    let mut seen_segments = std::collections::BTreeSet::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let at = |msg: String| anyhow::anyhow!("line {}: {msg}", lineno + 1);
        let j = Json::parse(line).map_err(|e| at(e.to_string()))?;
        j.req_str("run")?;
        for key in ["step", "t"] {
            j.req(key)?
                .as_f64()
                .ok_or_else(|| at(format!("'{key}' is not a number")))?;
        }
        let tr = j.req("trace")?;
        tr.req("id")?
            .as_f64()
            .ok_or_else(|| at("'id' is not a number".to_string()))?;
        let kind = tr.req_str("kind")?;
        anyhow::ensure!(!kind.is_empty(), "line {}: empty 'kind'", lineno + 1);
        let total = tr
            .req("total_ns")?
            .as_f64()
            .ok_or_else(|| at("'total_ns' is not a number".to_string()))?;
        anyhow::ensure!(total >= 0.0, "line {}: negative total_ns", lineno + 1);
        tr.req("ok")?
            .as_bool()
            .ok_or_else(|| at("'ok' is not a boolean".to_string()))?;
        tr.req("meta")?
            .as_obj()
            .ok_or_else(|| at("'meta' is not an object".to_string()))?;
        let segments = tr.req_arr("segments")?;
        for seg in segments {
            let name = seg.req_str("name")?;
            let start = seg
                .req("start_ns")?
                .as_f64()
                .ok_or_else(|| at(format!("segment '{name}' start_ns not a number")))?;
            let dur = seg
                .req("dur_ns")?
                .as_f64()
                .ok_or_else(|| at(format!("segment '{name}' dur_ns not a number")))?;
            anyhow::ensure!(
                start >= 0.0 && dur >= 0.0,
                "line {}: segment '{name}' has negative start/dur",
                lineno + 1
            );
            anyhow::ensure!(
                start + dur <= total,
                "line {}: segment '{name}' ({start} + {dur} ns) escapes its \
                 trace ({total} ns)",
                lineno + 1
            );
            seen_segments.insert(name.to_string());
        }
        traces += 1;
    }
    anyhow::ensure!(traces > 0, "no trace records found");
    for want in required_segments {
        anyhow::ensure!(
            seen_segments.contains(*want),
            "required segment '{want}' appears in no trace record"
        );
    }
    Ok(format!(
        "ok: {traces} traces, {} distinct segments, {} required segments present",
        seen_segments.len(),
        required_segments.len()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Serializes tests that toggle the process-wide trace flag (shared
    /// with the telemetry-flag tests — both are global state).
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        crate::telemetry::flag_test_lock()
    }

    #[test]
    fn disabled_tracing_yields_no_traces() {
        let _g = lock();
        set_trace_rate(0.0);
        assert!(!trace_enabled());
        assert!(try_start("unit_off").is_none());
        assert!(!sampled());
        assert_eq!(trace_rate(), 0.0);
    }

    #[test]
    fn rate_maps_to_every_nth_unit() {
        let _g = lock();
        set_trace_rate(0.5);
        reset_sampler();
        let picks: Vec<bool> = (0..6).map(|_| sampled()).collect();
        assert_eq!(picks, vec![true, false, true, false, true, false]);
        assert!((trace_rate() - 0.5).abs() < 1e-12);
        // Rates above 1 clamp to every unit; the first unit after a reset
        // always samples (period-0 alignment).
        set_trace_rate(7.0);
        reset_sampler();
        assert!(sampled() && sampled());
        set_trace_rate(0.0);
    }

    #[test]
    fn finish_builds_a_contained_waterfall() {
        let _g = lock();
        set_trace_rate(1.0);
        reset_sampler();
        let tr = try_start("unit_waterfall").expect("rate 1.0 samples everything");
        let id = tr.id();
        let a = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let b = Instant::now();
        tr.segment("phase_a", a, b);
        tr.segment("phase_b", b, Instant::now());
        tr.meta("n", 5.0);
        tr.finish(true);
        tr.finish(true); // idempotent: no duplicate record
        set_trace_rate(0.0);

        let recs: Vec<TraceRecord> = tracer()
            .recent(TRACE_RING)
            .into_iter()
            .filter(|r| r.kind == "unit_waterfall")
            .collect();
        let rec = recs.iter().find(|r| r.id == id).expect("record in ring");
        assert_eq!(recs.iter().filter(|r| r.id == id).count(), 1);
        assert!(rec.ok);
        assert_eq!(rec.segments.len(), 2);
        assert_eq!(rec.segments[0].name, "phase_a");
        assert!(rec.segments[0].dur_ns >= 1_000_000, "slept 2ms");
        for s in &rec.segments {
            assert!(s.start_ns + s.dur_ns <= rec.total_ns, "segment escapes trace");
        }
        assert_eq!(rec.meta, vec![("n".to_string(), 5.0)]);
        // Round-trips through the JSON layer.
        let j = Json::parse(&rec.to_json().to_string()).unwrap();
        assert_eq!(j.req_str("kind").unwrap(), "unit_waterfall");
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn segment_overflow_merges_into_the_tail() {
        let _g = lock();
        set_trace_rate(1.0);
        reset_sampler();
        let tr = try_start("unit_overflow").unwrap();
        let t0 = Instant::now();
        for _ in 0..(MAX_SEGMENTS + 10) {
            tr.segment("slice", t0, t0);
        }
        tr.finish(true);
        set_trace_rate(0.0);
        let rec = tracer()
            .recent(TRACE_RING)
            .into_iter()
            .find(|r| r.kind == "unit_overflow")
            .unwrap();
        assert_eq!(rec.segments.len(), MAX_SEGMENTS);
    }

    #[test]
    fn recent_returns_newest_first_and_ring_bounds_history() {
        let t = Tracer::new();
        for i in 0..(TRACE_RING + 5) {
            t.push_record(TraceRecord {
                id: i as u64,
                kind: "k".to_string(),
                total_ns: 1,
                ok: true,
                segments: Vec::new(),
                meta: Vec::new(),
            });
        }
        let recent = t.recent(3);
        let ids: Vec<u64> = recent.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![(TRACE_RING + 4) as u64, (TRACE_RING + 3) as u64, (TRACE_RING + 2) as u64]);
        assert_eq!(t.recent(usize::MAX).len(), TRACE_RING, "ring caps history");
        // Overwritten slots dropped record 0..5.
        assert!(t.recent(usize::MAX).iter().all(|r| r.id >= 5));
    }

    #[test]
    fn sink_writes_validatable_jsonl_per_record() {
        let dir = std::env::temp_dir().join("gfnx_trace_test");
        let path = dir.join("trace.jsonl");
        let _ = std::fs::remove_file(&path);
        let t = Tracer::new();
        t.set_sink("unit", &path).unwrap();
        for i in 0..3u64 {
            t.push_record(TraceRecord {
                id: i,
                kind: "http_request".to_string(),
                total_ns: 100,
                ok: true,
                segments: vec![
                    TraceSegment { name: "queue_wait".to_string(), start_ns: 0, dur_ns: 40 },
                    TraceSegment { name: "drain".to_string(), start_ns: 40, dur_ns: 60 },
                ],
                meta: vec![("status".to_string(), 200.0)],
            });
            // Flush-per-record: every record is on disk *before* clear_sink.
            let text = std::fs::read_to_string(&path).unwrap();
            assert_eq!(text.lines().count() as u64, i + 1);
        }
        t.clear_sink();
        let text = std::fs::read_to_string(&path).unwrap();
        let summary = check_trace_jsonl(&text, &["queue_wait", "drain"]).unwrap();
        assert!(summary.starts_with("ok: 3 traces"), "{summary}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sink_setup_error_surfaces() {
        let t = Tracer::new();
        // A directory is not appendable as a file.
        assert!(t.set_sink("unit", &std::env::temp_dir()).is_err());
    }

    #[test]
    fn validator_rejects_bad_input() {
        assert!(check_trace_jsonl("", &[]).is_err());
        assert!(check_trace_jsonl("not json\n", &[]).is_err());
        // Missing the trace payload.
        assert!(check_trace_jsonl(r#"{"run":"x","step":1,"t":0.5}"#, &[]).is_err());
        // A segment escaping its trace.
        let escape = r#"{"run":"x","step":1,"t":0.5,"trace":{"id":1,"kind":"k","total_ns":10,"ok":true,"meta":{},"segments":[{"name":"s","start_ns":8,"dur_ns":5}]}}"#;
        let err = check_trace_jsonl(escape, &[]).unwrap_err().to_string();
        assert!(err.contains("escapes"), "{err}");
        // Non-boolean ok.
        let bad_ok = r#"{"run":"x","step":1,"t":0.5,"trace":{"id":1,"kind":"k","total_ns":10,"ok":1,"meta":{},"segments":[]}}"#;
        assert!(check_trace_jsonl(bad_ok, &[]).is_err());
        // Required segment missing.
        let good = r#"{"run":"x","step":1,"t":0.5,"trace":{"id":1,"kind":"k","total_ns":10,"ok":true,"meta":{},"segments":[{"name":"s","start_ns":0,"dur_ns":5}]}}"#;
        check_trace_jsonl(good, &["s"]).unwrap();
        assert!(check_trace_jsonl(good, &["absent"]).is_err());
    }

    #[test]
    fn env_grammar_covers_off_on_and_rates() {
        let _g = lock();
        // Can't set env vars safely process-wide in parallel tests; drive
        // the same code path through set_trace_rate + explicit parses.
        set_trace_rate(f64::NAN);
        assert!(!trace_enabled());
        set_trace_rate(DEFAULT_RATE);
        assert!(trace_enabled());
        assert!((trace_rate() - DEFAULT_RATE).abs() < 1e-9);
        set_trace_rate(-1.0);
        assert!(!trace_enabled());
    }

    #[test]
    fn heartbeat_gauge_uses_registry_clock() {
        let reg = Registry::new();
        beat(&reg, "serve.worker_heartbeat_s");
        let hb = reg.gauge("serve.worker_heartbeat_s").get();
        let age = reg.elapsed_s() - hb;
        assert!(hb >= 0.0);
        assert!((0.0..1.0).contains(&age), "fresh heartbeat age ~0, got {age}");
    }
}
