//! Training state management: parameter / optimizer leaves as host literals
//! (round-tripped through the fused train step) plus device-resident
//! parameter buffers for the policy graph.
//!
//! PJRT's `ExecuteOptions` in xla_extension 0.5.1 returns a single tuple
//! buffer (no untupling), so the train step's outputs come back as one tuple
//! literal that we decompose and keep as the next step's inputs. The policy
//! graph's parameter inputs, in contrast, are uploaded to the device **once
//! per train step** (not once per env step) — the rollout then reuses the
//! same buffers for every env step, which is the main L3 perf lever (see
//! EXPERIMENTS.md §Perf).

use super::artifact::{literal_f32, literal_scalar_f32, Artifact};
use super::manifest::Manifest;
use xla::{Literal, PjRtBuffer};

/// Mutable training state bound to one artifact's manifest layout.
pub struct TrainState {
    pub client: xla::PjRtClient,
    /// params + m + v + t literals, in manifest (train_state) order.
    pub state: Vec<Literal>,
    /// Device buffers of the first P leaves (the params), for policy calls.
    pub param_bufs: Vec<PjRtBuffer>,
    /// Dims of each parameter leaf (for synchronous re-upload).
    param_dims: Vec<Vec<usize>>,
    /// Host staging scratch for parameter re-upload.
    upload_scratch: Vec<f32>,
    pub n_params: usize,
    pub steps: u64,
}

fn err(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

impl TrainState {
    /// Deserialize the init blob (f32 little-endian, manifest layout).
    pub fn from_blob(
        manifest: &Manifest,
        blob: &[u8],
        client: xla::PjRtClient,
    ) -> anyhow::Result<TrainState> {
        let mut state = Vec::with_capacity(manifest.blob_layout.len());
        for entry in &manifest.blob_layout {
            let n: usize = entry.shape.iter().product::<usize>().max(1);
            let bytes = &blob[entry.offset..entry.offset + 4 * n];
            let mut data = vec![0f32; n];
            for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            let dims: Vec<usize> = if entry.shape.is_empty() {
                vec![1]
            } else {
                entry.shape.clone()
            };
            state.push(literal_f32(&data, &dims)?);
        }
        let n_params = manifest.n_params();
        let param_dims: Vec<Vec<usize>> = manifest
            .params
            .iter()
            .map(|p| if p.shape.is_empty() { vec![1] } else { p.shape.clone() })
            .collect();
        let max_len = param_dims
            .iter()
            .map(|d| d.iter().product::<usize>())
            .max()
            .unwrap_or(0);
        let mut ts = TrainState {
            client,
            state,
            param_bufs: Vec::new(),
            param_dims,
            upload_scratch: vec![0.0; max_len],
            n_params,
            steps: 0,
        };
        ts.refresh_param_bufs()?;
        Ok(ts)
    }

    /// Re-upload the parameter leaves as device buffers (after a train step).
    ///
    /// Uses `buffer_from_host_buffer` (synchronous `kImmutableOnlyDuringCall`
    /// semantics) rather than `buffer_from_host_literal`, whose copy runs
    /// asynchronously on the client's worker pool and would read the literal
    /// after we drop it on the next train step (observed as a crash in
    /// `AbstractTfrtCpuBuffer::CopyFromLiteral`).
    pub fn refresh_param_bufs(&mut self) -> anyhow::Result<()> {
        self.param_bufs.clear();
        for (lit, dims) in self.state[..self.n_params].iter().zip(&self.param_dims) {
            let n: usize = dims.iter().product();
            let dst = &mut self.upload_scratch[..n];
            lit.copy_raw_to::<f32>(dst).map_err(err)?;
            self.param_bufs
                .push(self.client.buffer_from_host_buffer(dst, dims, None).map_err(err)?);
        }
        Ok(())
    }

    /// Run one fused train step. `batch` are the 8 batch literals in
    /// manifest order. Returns (loss, logZ).
    pub fn train_step(&mut self, art: &Artifact, batch: &[Literal]) -> anyhow::Result<(f32, f32)> {
        debug_assert_eq!(batch.len(), art.manifest.train_batch.len());
        let mut inputs: Vec<&Literal> = self.state.iter().collect();
        inputs.extend(batch.iter());
        let result = art.train_exe.execute::<&Literal>(&inputs).map_err(err)?;
        let tuple = result[0][0].to_literal_sync().map_err(err)?;
        let mut outs = tuple.to_tuple().map_err(err)?;
        // Layout: 3P+1 state leaves, then loss, logZ.
        let logz = literal_scalar_f32(&outs.pop().ok_or_else(|| anyhow::anyhow!("missing logZ"))?)?;
        let loss = literal_scalar_f32(&outs.pop().ok_or_else(|| anyhow::anyhow!("missing loss"))?)?;
        anyhow::ensure!(
            outs.len() == self.state.len(),
            "train step returned {} state leaves, expected {}",
            outs.len(),
            self.state.len()
        );
        self.state = outs;
        self.refresh_param_bufs()?;
        self.steps += 1;
        Ok((loss, logz))
    }

    /// Run the policy graph on host-side obs/mask batches.
    /// Returns (fwd_logp, bwd_logp, log_flow) as flat f32 vectors.
    pub fn policy(
        &self,
        art: &Artifact,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let cfg = &art.manifest.config;
        let b = cfg.batch;
        debug_assert_eq!(obs.len(), b * cfg.obs_dim);
        debug_assert_eq!(fwd_mask.len(), b * cfg.n_actions);
        debug_assert_eq!(bwd_mask.len(), b * cfg.n_bwd_actions);
        let obs_buf = self
            .client
            .buffer_from_host_buffer(obs, &[b, cfg.obs_dim], None)
            .map_err(err)?;
        let fwd_buf = self
            .client
            .buffer_from_host_buffer(fwd_mask, &[b, cfg.n_actions], None)
            .map_err(err)?;
        let bwd_buf = self
            .client
            .buffer_from_host_buffer(bwd_mask, &[b, cfg.n_bwd_actions], None)
            .map_err(err)?;
        let mut inputs: Vec<&PjRtBuffer> = self.param_bufs.iter().collect();
        inputs.push(&obs_buf);
        inputs.push(&fwd_buf);
        inputs.push(&bwd_buf);
        let result = art.policy_exe.execute_b::<&PjRtBuffer>(&inputs).map_err(err)?;
        let tuple = result[0][0].to_literal_sync().map_err(err)?;
        let outs = tuple.to_tuple().map_err(err)?;
        anyhow::ensure!(outs.len() == 3, "policy returned {} outputs", outs.len());
        Ok((
            outs[0].to_vec::<f32>().map_err(err)?,
            outs[1].to_vec::<f32>().map_err(err)?,
            outs[2].to_vec::<f32>().map_err(err)?,
        ))
    }

    /// Fetch a named parameter leaf back to the host (eval/debug).
    pub fn param_by_name(&self, manifest: &Manifest, name: &str) -> Option<Vec<f32>> {
        let idx = manifest.params.iter().position(|p| p.name == name)?;
        self.state[idx].to_vec::<f32>().ok()
    }
}
