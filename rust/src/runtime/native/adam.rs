//! Adam(W) optimizer step over the native parameter leaves, mirroring
//! `python/compile/optim.py`: bias-corrected moments, a dedicated `z_lr`
//! for the `logZ` leaf, and decoupled weight decay applied only to ≥ 2-d
//! leaves (and never to `logZ`), using the *pre-update* parameter value.

use super::net::Leaf;

const B1: f64 = 0.9;
const B2: f64 = 0.999;
const EPS: f64 = 1e-8;

/// Learning-rate hyperparameters (a subset of `NativeConfig`, passed by
/// value so the optimizer never borrows the config).
#[derive(Clone, Copy, Debug)]
pub(crate) struct AdamHyper {
    pub lr: f32,
    pub z_lr: f32,
    pub weight_decay: f32,
}

/// One in-place Adam step. `m`/`v` are the per-leaf first/second moments,
/// `t` the step counter; `grads` is index-aligned with `leaves`.
///
/// `t` is tracked as `u64`: an f32 counter stops incrementing at 2²⁴
/// (f32 + 1.0 == f32 there) and its bias-correction terms drift long before
/// that. The artifact blob still stores `t` as an f32 leaf — the conversion
/// happens only at blob load/save
/// ([`NativeBackend::from_blob`](super::NativeBackend::from_blob)), never
/// inside the step.
pub(crate) fn adam_step(
    leaves: &mut [Leaf],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    t: &mut u64,
    grads: &[Vec<f32>],
    logz_idx: usize,
    h: AdamHyper,
) {
    debug_assert_eq!(leaves.len(), grads.len());
    debug_assert_eq!(leaves.len(), m.len());
    debug_assert_eq!(leaves.len(), v.len());
    *t += 1;
    let tc = *t as f64;
    let c1 = 1.0 - B1.powf(tc);
    let c2 = 1.0 - B2.powf(tc);
    for (idx, leaf) in leaves.iter_mut().enumerate() {
        let is_logz = idx == logz_idx;
        let lr = if is_logz { h.z_lr } else { h.lr } as f64;
        let wd = h.weight_decay as f64;
        let decay = wd > 0.0 && !is_logz && leaf.tensor.shape().len() >= 2;
        let g = &grads[idx];
        let mk = &mut m[idx];
        let vk = &mut v[idx];
        let data = leaf.tensor.data_mut();
        debug_assert_eq!(data.len(), g.len());
        for i in 0..data.len() {
            let gi = g[i] as f64;
            let mi = B1 * mk[i] as f64 + (1.0 - B1) * gi;
            let vi = B2 * vk[i] as f64 + (1.0 - B2) * gi * gi;
            mk[i] = mi as f32;
            vk[i] = vi as f32;
            let m_hat = mi / c1;
            let v_hat = vi / c2;
            let p_old = data[i] as f64;
            let mut p = p_old - lr * m_hat / (v_hat.sqrt() + EPS);
            if decay {
                p -= lr * wd * p_old;
            }
            data[i] = p as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::TensorF32;

    fn leaf(name: &str, shape: &[usize], v: f32) -> Leaf {
        let n: usize = shape.iter().product();
        Leaf { name: name.to_string(), tensor: TensorF32::from_vec(shape, vec![v; n]) }
    }

    #[test]
    fn first_step_moves_by_learning_rate() {
        // With bias correction, the first Adam step is ≈ lr·sign(g).
        let mut leaves = vec![leaf("w0", &[2, 2], 1.0), leaf("logZ", &[1], 0.0)];
        let mut m = vec![vec![0.0; 4], vec![0.0; 1]];
        let mut v = vec![vec![0.0; 4], vec![0.0; 1]];
        let mut t = 0u64;
        let grads = vec![vec![0.5; 4], vec![-2.0; 1]];
        adam_step(&mut leaves, &mut m, &mut v, &mut t, &grads, 1,
                  AdamHyper { lr: 1e-2, z_lr: 0.1, weight_decay: 0.0 });
        assert_eq!(t, 1);
        for &p in leaves[0].tensor.data() {
            assert!((p - (1.0 - 1e-2)).abs() < 1e-5, "w step ≈ lr, got {p}");
        }
        // logZ uses z_lr and moves against the gradient sign.
        let z = leaves[1].tensor.data()[0];
        assert!((z - 0.1).abs() < 1e-5, "logZ step ≈ z_lr, got {z}");
    }

    #[test]
    fn step_counter_advances_past_f32_precision() {
        // Regression: with an f32 counter, t + 1.0 == t at 2²⁴ — the step
        // count silently freezes and bias correction with it. The u64
        // counter keeps counting.
        let mut leaves = vec![leaf("w0", &[1], 0.0)];
        let (mut m, mut v) = (vec![vec![0.0; 1]], vec![vec![0.0; 1]]);
        let mut t = (1u64 << 24) - 1;
        assert_eq!((t as f32 + 1.0) as u64, t + 1); // 2²⁴ itself is exact…
        let grads = vec![vec![1.0; 1]];
        let h = AdamHyper { lr: 1e-3, z_lr: 1e-3, weight_decay: 0.0 };
        adam_step(&mut leaves, &mut m, &mut v, &mut t, &grads, usize::MAX, h);
        assert_eq!(t, 1 << 24);
        let frozen = (t as f32 + 1.0) as u64;
        assert_eq!(frozen, t, "…but f32 increments stop here");
        adam_step(&mut leaves, &mut m, &mut v, &mut t, &grads, usize::MAX, h);
        assert_eq!(t, (1 << 24) + 1, "u64 counter must not freeze");
    }

    #[test]
    fn weight_decay_applies_to_matrices_only() {
        let mut leaves = vec![
            leaf("w0", &[2, 2], 1.0),
            leaf("b0", &[4], 1.0),
            leaf("logZ", &[1], 1.0),
        ];
        let mut m = vec![vec![0.0; 4], vec![0.0; 4], vec![0.0; 1]];
        let mut v = vec![vec![0.0; 4], vec![0.0; 4], vec![0.0; 1]];
        let mut t = 0u64;
        let grads = vec![vec![0.0; 4], vec![0.0; 4], vec![0.0; 1]];
        adam_step(&mut leaves, &mut m, &mut v, &mut t, &grads, 2,
                  AdamHyper { lr: 0.1, z_lr: 0.1, weight_decay: 0.5 });
        // Zero grads: only decay moves parameters, and only the matrix leaf.
        assert!((leaves[0].tensor.data()[0] - (1.0 - 0.1 * 0.5)).abs() < 1e-6);
        assert_eq!(leaves[1].tensor.data()[0], 1.0);
        assert_eq!(leaves[2].tensor.data()[0], 1.0);
    }
}
