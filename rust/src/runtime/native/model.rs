//! The pluggable model layer of the native backend.
//!
//! [`Model`] is the seam between "what the backend does" (losses, Adam,
//! checkpointing, serve snapshots — all generic over a parameter tree of
//! named [`Leaf`]s) and "what the network is" (MLP trunk, transformer
//! encoder). Each implementation owns its leaves in a fixed serialization
//! order, exposes forward/backward over flat `[n, obs_dim]` batches, and
//! describes its architecture for checkpoint headers via [`ModelSpec`].
//!
//! Two implementations ship in-tree:
//! - [`MlpModel`](super::net::MlpModel) — the original MLP trunk + three
//!   heads (`python/compile/models/mlp.py`), bit-for-bit the pre-trait
//!   [`NativeNet`](super::NativeNet) math.
//! - [`TransformerModel`](super::transformer::TransformerModel) — the
//!   pre-LN encoder of `python/compile/models/transformer.py`, with an
//!   optional causal mode + per-slot KV cache for O(T)-per-step serve
//!   decode.

use super::net::{ForwardCache, Grads, Leaf};
use super::transformer::TransformerModel;
use super::NativeConfig;
use crate::util::json::Json;

/// Which architecture a model (or checkpoint) is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Transformer,
}

impl ModelKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Transformer => "transformer",
        }
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Architecture of a [`TransformerModel`]: the flat observation is
/// reshaped to `[seq_len, token_dim]` tokens, embedded into `embed` dims,
/// and run through `NativeConfig::n_layers` pre-LN encoder blocks.
///
/// `causal` switches the attention pattern: `false` is the bidirectional
/// JAX reference (mean-pool over positions); `true` masks attention to
/// `key ≤ query` and pools at the first unfilled position, which is what
/// makes the per-slot KV cache ([`super::transformer::KvCaches`]) exact —
/// only left-to-right appending envs (seq, tfbind8, amp) qualify.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerArch {
    pub seq_len: usize,
    pub token_dim: usize,
    pub embed: usize,
    pub n_heads: usize,
    pub ff_hidden: usize,
    pub causal: bool,
}

impl TransformerArch {
    /// Checkpoint-header descriptor (inverse of [`TransformerArch::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seq_len", Json::Num(self.seq_len as f64)),
            ("token_dim", Json::Num(self.token_dim as f64)),
            ("embed", Json::Num(self.embed as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("ff_hidden", Json::Num(self.ff_hidden as f64)),
            ("causal", Json::Bool(self.causal)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TransformerArch> {
        Ok(TransformerArch {
            seq_len: j.req_usize("seq_len")?,
            token_dim: j.req_usize("token_dim")?,
            embed: j.req_usize("embed")?,
            n_heads: j.req_usize("n_heads")?,
            ff_hidden: j.req_usize("ff_hidden")?,
            causal: j
                .req("causal")?
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("transformer arch: causal is not a bool"))?,
        })
    }
}

impl std::fmt::Display for TransformerArch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "transformer(seq_len={}, token_dim={}, embed={}, heads={}, ff={}, causal={})",
            self.seq_len, self.token_dim, self.embed, self.n_heads, self.ff_hidden, self.causal
        )
    }
}

/// Which model a [`NativeConfig`] builds (plus its architecture, for
/// everything the shared shape fields don't capture).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    Mlp,
    Transformer(TransformerArch),
}

impl ModelSpec {
    pub fn kind(&self) -> ModelKind {
        match self {
            ModelSpec::Mlp => ModelKind::Mlp,
            ModelSpec::Transformer(_) => ModelKind::Transformer,
        }
    }

    /// The `[seq_len, token_dim]` factorization this model imposes on the
    /// flat observation (`None` for models that consume it flat).
    pub fn token_shape(&self) -> Option<(usize, usize)> {
        match self {
            ModelSpec::Mlp => None,
            ModelSpec::Transformer(a) => Some((a.seq_len, a.token_dim)),
        }
    }
}

/// A native policy network architecture: a parameter tree of named leaves
/// plus forward/backward over flat observation batches.
///
/// Everything above this trait (losses, Adam, blob/checkpoint round trips,
/// serve snapshots, the engine) treats the model as an opaque leaf vector;
/// `forward`/`backward` receive the owning [`NativeConfig`] so shared
/// shape/hyperparameter state lives in exactly one place.
pub trait Model: std::fmt::Debug + Send + Sync {
    /// Architecture tag for checkpoint headers and error messages.
    fn kind(&self) -> ModelKind;

    /// Parameter leaves in serialization order.
    fn leaves(&self) -> &[Leaf];

    /// Mutable leaves (optimizer step, checkpoint restore).
    fn leaves_mut(&mut self) -> &mut [Leaf];

    /// Index of the `logZ` leaf.
    fn idx_logz(&self) -> usize;

    /// Forward pass over `n` rows, keeping intermediates for `backward`.
    fn forward(
        &self,
        cfg: &NativeConfig,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
        n: usize,
        with_bwd: bool,
    ) -> ForwardCache;

    /// Backward pass: upstream gradients on the masked forward log-probs
    /// and the flow head → per-leaf parameter gradients.
    fn backward(
        &self,
        cfg: &NativeConfig,
        obs: &[f32],
        cache: &ForwardCache,
        d_fwd_logp: &[f32],
        d_flow: &[f32],
    ) -> Grads;

    /// Clone behind the trait object (snapshots, policy clones).
    fn box_clone(&self) -> Box<dyn Model>;

    /// Downcast hook for the transformer-only serve paths (KV cache).
    fn as_transformer(&self) -> Option<&TransformerModel> {
        None
    }
}

impl Clone for Box<dyn Model> {
    fn clone(&self) -> Box<dyn Model> {
        self.box_clone()
    }
}
