//! Cache-blocked, panel-packed GEMM kernels for the native MLP hot path.
//!
//! The three batched matmuls behind [`super::net::NativeNet`] — forward
//! dispatch (`dense_rows`), weight gradients (`matmul_tn`) and input
//! gradients (`matmul_nt`) — plus the bias-gradient column sum all run
//! through one tiled engine:
//!
//! - The B operand (weights or upstream gradients) is packed once per call
//!   into contiguous `NR`-wide column panels, converting to the
//!   accumulator type during the pack, so the inner loop is stride-1 and
//!   conversion-free in both operands (the old kernels re-converted the
//!   whole weight matrix from f32 once *per output row*).
//! - Output rows are processed in `MR`-row tiles; each tile packs its A
//!   rows into an interleaved `[steps × MR]` strip and runs a micro-kernel
//!   holding an `MR × NR` accumulator block in registers. Tiles are laid
//!   out globally (tile `i` always covers rows `i·MR..`), and workers take
//!   whole tiles, so the result is **bitwise independent of the worker
//!   count** in every mode.
//! - Parallel regions run on the persistent
//!   [`crate::util::threadpool::ThreadPool`] (no per-call thread spawns)
//!   and write straight into the caller's output buffer (no per-block
//!   `Vec` + concat copy).
//!
//! Two accumulation modes:
//!
//! - **Deterministic** (the default, and the only mode the trainer
//!   accepts): every output element is one f64 accumulator advanced in
//!   ascending reduction order — exactly the old scalar kernels' order —
//!   so training, the engine's `--sync` parity and serve determinism all
//!   keep their bitwise guarantees.
//! - **Fast** (`NativeConfig::fastmath` / `GFNX_FASTMATH=1`, serve-only
//!   dispatch): micro-kernels keep eight-wide `[f32; 8]` lane sums and
//!   never widen to f64. Still bit-reproducible for a fixed seed and
//!   worker-count-invariant, but *not* bitwise-equal to the deterministic
//!   mode (error is bounded by the usual `O(k·ε)` dot-product bound; see
//!   the tolerance test below).
//!
//! The zero-skip shortcut for one-hot-heavy observations is adaptive: each
//! A tile's density is counted during packing (which walks every element
//! anyway), and tiles above [`DENSE_PATH_MIN_DENSITY`] take the
//! branch-free path. The choice is a pure function of the tile data, so it
//! cannot break worker-count invariance.

use std::cell::RefCell;

use crate::util::threadpool::ThreadPool;

/// Column-panel width — also the f32 lane width of the fast micro-kernel
/// (`[f32; 8]` lowers to two SSE / one AVX vector; std::simd is nightly).
const NR: usize = 8;
/// Row-tile height of the deterministic (f64) micro-kernel. 2×8 f64
/// accumulators are 8 SSE registers, leaving room for the packed operands.
const MR_DET: usize = 2;
/// Row-tile height of the fast (f32) micro-kernel (4×8 f32 = 8 SSE regs).
const MR_FAST: usize = 4;

/// Fraction of nonzero A-tile entries above which the branch-free
/// micro-kernel wins over the zero-skip path. One-hot observation blocks
/// sit near `1/obs_dim`; dense inputs (ising spins, qm9 features) sit at
/// ~1.0; the crossover is broad, so a coarse threshold is fine.
const DENSE_PATH_MIN_DENSITY: f32 = 0.25;

/// Per-worker work quantum: grant one worker per this many fused
/// multiply-adds. Re-derived for the persistent pool: waking parked
/// workers costs ~1–3 µs (a condvar signal, measured the same way the
/// `telemetry_overhead` bench measures span cost) versus ~20–60 µs for
/// the old spawn/join-per-call design, so the profitable-parallelism
/// threshold drops from 2¹⁸ to 2¹⁶ — 2¹⁶ madds are ~20–60 µs of scalar
/// work, amortizing a pool wake ≥ 10×. Small rollout dispatches (e.g.
/// 4×64×64) still stay single-worker.
pub(crate) const PAR_FLOP_QUANTUM: usize = 1 << 16;

/// Effective worker count: at least 1, at most `rows`, at most the
/// requested count, and at most one worker per [`PAR_FLOP_QUANTUM`] of
/// total work.
#[inline]
pub(crate) fn effective_workers(workers: usize, rows: usize, flops: usize) -> usize {
    (flops / PAR_FLOP_QUANTUM).max(1).min(workers.max(1)).min(rows.max(1))
}

/// A-operand view: element `(row, step)` of the reduction lives at
/// `data[row·row_stride + step·step_stride]`.
#[derive(Clone, Copy)]
struct AView<'a> {
    data: &'a [f32],
    row_stride: usize,
    step_stride: usize,
}

impl AView<'_> {
    #[inline(always)]
    fn at(&self, row: usize, step: usize) -> f32 {
        self.data[row * self.row_stride + step * self.step_stride]
    }
}

/// B-operand view: element `(step, col)` lives at
/// `data[step·step_stride + col·col_stride]`.
#[derive(Clone, Copy)]
struct BView<'a> {
    data: &'a [f32],
    step_stride: usize,
    col_stride: usize,
}

/// Reusable per-thread packing scratch: B panels on the submitting thread,
/// A strips on each executor. Persistent pool workers keep theirs across
/// calls, so the steady-state hot path allocates nothing.
struct Scratch {
    f64buf: Vec<f64>,
    f32buf: Vec<f32>,
}

thread_local! {
    static PACK_B: RefCell<Scratch> =
        RefCell::new(Scratch { f64buf: Vec::new(), f32buf: Vec::new() });
    static PACK_A: RefCell<Scratch> =
        RefCell::new(Scratch { f64buf: Vec::new(), f32buf: Vec::new() });
}

/// Shared output pointer for disjoint tile writes from pool workers.
#[derive(Clone, Copy)]
struct OutPtr(*mut f32);
// SAFETY: every (row, col) cell is written by exactly one executor — row
// tiles partition the rows and each tile is owned by one chunk.
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

impl OutPtr {
    /// SAFETY: caller must guarantee exclusive access to cell `i`.
    #[inline(always)]
    unsafe fn write(self, i: usize, v: f32) {
        *self.0.add(i) = v;
    }
}

// ---------------------------------------------------------------------------
// Deterministic engine: fixed-order f64 accumulation, MR_DET × NR tiles.
// ---------------------------------------------------------------------------

fn pack_b_f64(b: BView, steps: usize, cols: usize, n_panels: usize, buf: &mut Vec<f64>) {
    buf.clear();
    buf.resize(n_panels * steps * NR, 0.0); // padding columns stay 0.0
    for p in 0..n_panels {
        let c0 = p * NR;
        let nc = NR.min(cols - c0);
        let dst = &mut buf[p * steps * NR..(p + 1) * steps * NR];
        for s in 0..steps {
            let base = s * b.step_stride;
            let row = &mut dst[s * NR..s * NR + nc];
            for (cc, slot) in row.iter_mut().enumerate() {
                *slot = b.data[base + (c0 + cc) * b.col_stride] as f64;
            }
        }
    }
}

/// Pack one MR_DET-row strip (zero-padded below `mr`) and count nonzeros
/// for the adaptive density decision.
fn pack_a_f64(a: AView, r0: usize, mr: usize, steps: usize, buf: &mut [f64]) -> usize {
    let mut nnz = 0usize;
    for s in 0..steps {
        for rr in 0..MR_DET {
            let v = if rr < mr { a.at(r0 + rr, s) } else { 0.0 };
            nnz += (v != 0.0) as usize;
            buf[s * MR_DET + rr] = v as f64;
        }
    }
    nnz
}

/// Branch-free micro-kernel: `acc[rr][cc] += a[rr][s] · b[s][cc]` with `s`
/// ascending — the same per-element reduction order as the scalar
/// reference, so results are bitwise tile-layout-invariant.
#[inline]
fn micro_f64(ap: &[f64], panel: &[f64], steps: usize, acc: &mut [[f64; NR]; MR_DET]) {
    for s in 0..steps {
        let bv: &[f64; NR] = panel[s * NR..s * NR + NR].try_into().unwrap();
        let av: &[f64; MR_DET] = ap[s * MR_DET..s * MR_DET + MR_DET].try_into().unwrap();
        for rr in 0..MR_DET {
            let x = av[rr];
            for cc in 0..NR {
                acc[rr][cc] += x * bv[cc];
            }
        }
    }
}

/// Zero-skip micro-kernel for sparse tiles (one-hot-heavy observations).
/// Skipping exact-zero terms keeps the surviving reduction order intact.
#[inline]
fn micro_f64_sparse(
    ap: &[f64],
    panel: &[f64],
    steps: usize,
    mr: usize,
    acc: &mut [[f64; NR]; MR_DET],
) {
    for rr in 0..mr {
        let row = &mut acc[rr];
        for s in 0..steps {
            let x = ap[s * MR_DET + rr];
            if x == 0.0 {
                continue;
            }
            let bv: &[f64; NR] = panel[s * NR..s * NR + NR].try_into().unwrap();
            for cc in 0..NR {
                row[cc] += x * bv[cc];
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_tiles_f64(
    a: AView,
    bp: &[f64],
    bias: Option<&[f32]>,
    relu: bool,
    rows: usize,
    steps: usize,
    cols: usize,
    n_panels: usize,
    t_lo: usize,
    t_hi: usize,
    out: OutPtr,
    ascratch: &mut Vec<f64>,
) {
    ascratch.clear();
    ascratch.resize(steps * MR_DET, 0.0);
    for ti in t_lo..t_hi {
        let r0 = ti * MR_DET;
        let mr = MR_DET.min(rows - r0);
        let nnz = pack_a_f64(a, r0, mr, steps, ascratch);
        let dense = nnz as f32 >= DENSE_PATH_MIN_DENSITY * (mr * steps) as f32;
        for p in 0..n_panels {
            let c0 = p * NR;
            let nc = NR.min(cols - c0);
            let mut acc = [[0f64; NR]; MR_DET];
            if let Some(bias) = bias {
                for row in acc.iter_mut() {
                    for (cc, slot) in row.iter_mut().take(nc).enumerate() {
                        *slot = bias[c0 + cc] as f64;
                    }
                }
            }
            let panel = &bp[p * steps * NR..(p + 1) * steps * NR];
            if dense {
                micro_f64(ascratch, panel, steps, &mut acc);
            } else {
                micro_f64_sparse(ascratch, panel, steps, mr, &mut acc);
            }
            for rr in 0..mr {
                for cc in 0..nc {
                    let v = acc[rr][cc];
                    let v = if relu && v < 0.0 { 0.0 } else { v as f32 };
                    // SAFETY: this chunk owns tiles t_lo..t_hi, and tiles
                    // partition the output rows.
                    unsafe { out.write((r0 + rr) * cols + c0 + cc, v) };
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_f64(
    a: AView,
    b: BView,
    bias: Option<&[f32]>,
    relu: bool,
    rows: usize,
    steps: usize,
    cols: usize,
    workers: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * cols);
    if rows == 0 || cols == 0 {
        return;
    }
    let workers = effective_workers(workers, rows, rows * steps * cols);
    let n_tiles = rows.div_ceil(MR_DET);
    let n_panels = cols.div_ceil(NR);
    PACK_B.with(|cell| {
        let mut pb = cell.borrow_mut();
        pack_b_f64(b, steps, cols, n_panels, &mut pb.f64buf);
        let bp: &[f64] = &pb.f64buf;
        let tiles_per = n_tiles.div_ceil(workers);
        let n_chunks = n_tiles.div_ceil(tiles_per);
        let optr = OutPtr(out.as_mut_ptr());
        ThreadPool::global().run(n_chunks, workers, |chunk| {
            let t_lo = chunk * tiles_per;
            let t_hi = ((chunk + 1) * tiles_per).min(n_tiles);
            PACK_A.with(|acell| {
                let pa = &mut acell.borrow_mut().f64buf;
                run_tiles_f64(
                    a, bp, bias, relu, rows, steps, cols, n_panels, t_lo, t_hi, optr, pa,
                );
            });
        });
    });
}

// ---------------------------------------------------------------------------
// Fast engine: [f32; 8] lane sums, MR_FAST × NR tiles (serve-only mode).
// ---------------------------------------------------------------------------

fn pack_b_f32(b: BView, steps: usize, cols: usize, n_panels: usize, buf: &mut Vec<f32>) {
    buf.clear();
    buf.resize(n_panels * steps * NR, 0.0);
    for p in 0..n_panels {
        let c0 = p * NR;
        let nc = NR.min(cols - c0);
        let dst = &mut buf[p * steps * NR..(p + 1) * steps * NR];
        for s in 0..steps {
            let base = s * b.step_stride;
            let row = &mut dst[s * NR..s * NR + nc];
            for (cc, slot) in row.iter_mut().enumerate() {
                *slot = b.data[base + (c0 + cc) * b.col_stride];
            }
        }
    }
}

fn pack_a_f32(a: AView, r0: usize, mr: usize, steps: usize, buf: &mut [f32]) -> usize {
    let mut nnz = 0usize;
    for s in 0..steps {
        for rr in 0..MR_FAST {
            let v = if rr < mr { a.at(r0 + rr, s) } else { 0.0 };
            nnz += (v != 0.0) as usize;
            buf[s * MR_FAST + rr] = v;
        }
    }
    nnz
}

#[inline]
fn micro_f32(ap: &[f32], panel: &[f32], steps: usize, acc: &mut [[f32; NR]; MR_FAST]) {
    for s in 0..steps {
        let bv: &[f32; NR] = panel[s * NR..s * NR + NR].try_into().unwrap();
        let av: &[f32; MR_FAST] = ap[s * MR_FAST..s * MR_FAST + MR_FAST].try_into().unwrap();
        for rr in 0..MR_FAST {
            let x = av[rr];
            for cc in 0..NR {
                acc[rr][cc] += x * bv[cc];
            }
        }
    }
}

#[inline]
fn micro_f32_sparse(
    ap: &[f32],
    panel: &[f32],
    steps: usize,
    mr: usize,
    acc: &mut [[f32; NR]; MR_FAST],
) {
    for rr in 0..mr {
        let row = &mut acc[rr];
        for s in 0..steps {
            let x = ap[s * MR_FAST + rr];
            if x == 0.0 {
                continue;
            }
            let bv: &[f32; NR] = panel[s * NR..s * NR + NR].try_into().unwrap();
            for cc in 0..NR {
                row[cc] += x * bv[cc];
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_tiles_f32(
    a: AView,
    bp: &[f32],
    bias: Option<&[f32]>,
    relu: bool,
    rows: usize,
    steps: usize,
    cols: usize,
    n_panels: usize,
    t_lo: usize,
    t_hi: usize,
    out: OutPtr,
    ascratch: &mut Vec<f32>,
) {
    ascratch.clear();
    ascratch.resize(steps * MR_FAST, 0.0);
    for ti in t_lo..t_hi {
        let r0 = ti * MR_FAST;
        let mr = MR_FAST.min(rows - r0);
        let nnz = pack_a_f32(a, r0, mr, steps, ascratch);
        let dense = nnz as f32 >= DENSE_PATH_MIN_DENSITY * (mr * steps) as f32;
        for p in 0..n_panels {
            let c0 = p * NR;
            let nc = NR.min(cols - c0);
            let mut acc = [[0f32; NR]; MR_FAST];
            if let Some(bias) = bias {
                for row in acc.iter_mut() {
                    row[..nc].copy_from_slice(&bias[c0..c0 + nc]);
                }
            }
            let panel = &bp[p * steps * NR..(p + 1) * steps * NR];
            if dense {
                micro_f32(ascratch, panel, steps, &mut acc);
            } else {
                micro_f32_sparse(ascratch, panel, steps, mr, &mut acc);
            }
            for rr in 0..mr {
                for cc in 0..nc {
                    let v = acc[rr][cc];
                    let v = if relu && v < 0.0 { 0.0 } else { v };
                    // SAFETY: as in run_tiles_f64 — tiles partition rows.
                    unsafe { out.write((r0 + rr) * cols + c0 + cc, v) };
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_f32(
    a: AView,
    b: BView,
    bias: Option<&[f32]>,
    relu: bool,
    rows: usize,
    steps: usize,
    cols: usize,
    workers: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * cols);
    if rows == 0 || cols == 0 {
        return;
    }
    let workers = effective_workers(workers, rows, rows * steps * cols);
    let n_tiles = rows.div_ceil(MR_FAST);
    let n_panels = cols.div_ceil(NR);
    PACK_B.with(|cell| {
        let mut pb = cell.borrow_mut();
        pack_b_f32(b, steps, cols, n_panels, &mut pb.f32buf);
        let bp: &[f32] = &pb.f32buf;
        let tiles_per = n_tiles.div_ceil(workers);
        let n_chunks = n_tiles.div_ceil(tiles_per);
        let optr = OutPtr(out.as_mut_ptr());
        ThreadPool::global().run(n_chunks, workers, |chunk| {
            let t_lo = chunk * tiles_per;
            let t_hi = ((chunk + 1) * tiles_per).min(n_tiles);
            PACK_A.with(|acell| {
                let pa = &mut acell.borrow_mut().f32buf;
                run_tiles_f32(
                    a, bp, bias, relu, rows, steps, cols, n_panels, t_lo, t_hi, optr, pa,
                );
            });
        });
    });
}

// ---------------------------------------------------------------------------
// Public kernels (bench-facing; `net.rs` re-exports them crate-internally).
// ---------------------------------------------------------------------------

/// `out = act(x · w + bias)` over `n` rows in the requested accumulation
/// mode (`fastmath = false` → deterministic f64, the only mode training
/// accepts; `true` → `[f32; 8]` lane sums for serve-only dispatch).
#[allow(clippy::too_many_arguments)]
pub fn dense_rows_mode(
    x: &[f32],
    n: usize,
    k: usize,
    w: &[f32],
    bias: &[f32],
    m: usize,
    relu: bool,
    workers: usize,
    fastmath: bool,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(w.len(), k * m);
    debug_assert_eq!(bias.len(), m);
    // Per-GEMM span + rows×inner×cols FLOP counter (2 FLOPs per fused
    // multiply-add); the registry derives `native.gemm.dense.gflops`.
    let _t = crate::span!("native.gemm.dense");
    crate::count!("native.gemm.dense.flops", 2 * n * k * m);
    let mut out = vec![0f32; n * m];
    let a = AView { data: x, row_stride: k, step_stride: 1 };
    let b = BView { data: w, step_stride: m, col_stride: 1 };
    if fastmath {
        gemm_f32(a, b, Some(bias), relu, n, k, m, workers, &mut out);
    } else {
        gemm_f64(a, b, Some(bias), relu, n, k, m, workers, &mut out);
    }
    out
}

/// `out = act(x · w + bias)` in deterministic mode (bitwise
/// worker-count-invariant; per-element fixed-order f64 accumulation).
#[allow(clippy::too_many_arguments)]
pub fn dense_rows(
    x: &[f32],
    n: usize,
    k: usize,
    w: &[f32],
    bias: &[f32],
    m: usize,
    relu: bool,
    workers: usize,
) -> Vec<f32> {
    dense_rows_mode(x, n, k, w, bias, m, relu, workers, false)
}

/// `out = xᵀ · g` (`[k, m]` from `x [n, k]`, `g [n, m]`): the weight-grad
/// matmul. Deterministic mode only (it feeds the optimizer).
pub fn matmul_tn(x: &[f32], n: usize, k: usize, g: &[f32], m: usize, workers: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(g.len(), n * m);
    let _t = crate::span!("native.gemm.tn");
    crate::count!("native.gemm.tn.flops", 2 * n * k * m);
    let mut out = vec![0f32; k * m];
    // Output row t, reduction step r: A(t, r) = x[r·k + t].
    let a = AView { data: x, row_stride: 1, step_stride: k };
    let b = BView { data: g, step_stride: m, col_stride: 1 };
    gemm_f64(a, b, None, false, k, n, m, workers, &mut out);
    out
}

/// `out = g · wᵀ` (`[n, k]` from `g [n, m]`, `w [k, m]`): the input-grad
/// matmul. Deterministic mode only.
pub fn matmul_nt(g: &[f32], n: usize, m: usize, w: &[f32], k: usize, workers: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), n * m);
    debug_assert_eq!(w.len(), k * m);
    let _t = crate::span!("native.gemm.nt");
    crate::count!("native.gemm.nt.flops", 2 * n * m * k);
    let mut out = vec![0f32; n * k];
    // Output row r, reduction step j: A(r, j) = g[r·m + j] (stride-1).
    let a = AView { data: g, row_stride: m, step_stride: 1 };
    // Output col t, reduction step j: B(j, t) = w[t·m + j] (transposed).
    let b = BView { data: w, step_stride: 1, col_stride: m };
    gemm_f64(a, b, None, false, n, m, k, workers, &mut out);
    out
}

/// Column sums of `g [n, m]` (bias gradients), f64-accumulated in row
/// order through `[f64; 8]` lane groups (same per-column order as a scalar
/// loop, so results are bitwise unchanged — the lanes are disjoint
/// columns).
pub fn col_sum(g: &[f32], n: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), n * m);
    let _t = crate::span!("native.gemm.colsum");
    // One add per element; the registry derives `native.gemm.colsum.gflops`.
    crate::count!("native.gemm.colsum.flops", n * m);
    let mut acc = vec![0f64; m];
    let lanes = m - m % NR;
    for r in 0..n {
        let grow = &g[r * m..(r + 1) * m];
        let mut j = 0;
        while j < lanes {
            let gv: &[f32; NR] = grow[j..j + NR].try_into().unwrap();
            let av: &mut [f64; NR] = (&mut acc[j..j + NR]).try_into().unwrap();
            for cc in 0..NR {
                av[cc] += gv[cc] as f64;
            }
            j += NR;
        }
        for jj in lanes..m {
            acc[jj] += grow[jj] as f64;
        }
    }
    acc.iter().map(|&v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::threadpool::spawned_threads;

    // Naive references mirroring the pre-tiling scalar kernels exactly
    // (per-element f64 accumulation in ascending reduction order, with the
    // unconditional zero-skip the old kernels applied).
    fn ref_dense(x: &[f32], n: usize, k: usize, w: &[f32], b: &[f32], m: usize, relu: bool) -> Vec<f32> {
        let mut out = vec![0f32; n * m];
        for r in 0..n {
            let mut acc: Vec<f64> = b.iter().map(|&v| v as f64).collect();
            for t in 0..k {
                let xv = x[r * k + t];
                if xv == 0.0 {
                    continue;
                }
                for j in 0..m {
                    acc[j] += xv as f64 * w[t * m + j] as f64;
                }
            }
            for j in 0..m {
                let v = acc[j];
                out[r * m + j] = if relu && v < 0.0 { 0.0 } else { v as f32 };
            }
        }
        out
    }

    fn ref_tn(x: &[f32], n: usize, k: usize, g: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0f32; k * m];
        for t in 0..k {
            let mut acc = vec![0f64; m];
            for r in 0..n {
                let xv = x[r * k + t];
                if xv == 0.0 {
                    continue;
                }
                for j in 0..m {
                    acc[j] += xv as f64 * g[r * m + j] as f64;
                }
            }
            for j in 0..m {
                out[t * m + j] = acc[j] as f32;
            }
        }
        out
    }

    fn ref_nt(g: &[f32], n: usize, m: usize, w: &[f32], k: usize) -> Vec<f32> {
        let mut out = vec![0f32; n * k];
        for r in 0..n {
            for t in 0..k {
                let mut acc = 0f64;
                for j in 0..m {
                    acc += g[r * m + j] as f64 * w[t * m + j] as f64;
                }
                out[r * k + t] = acc as f32;
            }
        }
        out
    }

    fn normal(rng: &mut Rng, len: usize) -> Vec<f32> {
        let mut v = vec![0f32; len];
        rng.fill_normal_f32(&mut v, 1.0);
        v
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Ragged shapes off every tile/lane boundary, including 1×1×1, k < 8
    /// and the m = 1 flow-head shape.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 3, 1),
        (2, 8, 8),
        (3, 5, 2),
        (5, 7, 9),
        (8, 3, 8),
        (9, 16, 7),
        (17, 13, 33),
        (33, 31, 1),
        (16, 9, 24),
    ];

    #[test]
    fn tiled_kernels_match_reference_on_ragged_shapes() {
        for (i, &(n, k, m)) in SHAPES.iter().enumerate() {
            let mut rng = Rng::new(100 + i as u64);
            let x = normal(&mut rng, n * k);
            let w = normal(&mut rng, k * m);
            let g = normal(&mut rng, n * m);
            let b = normal(&mut rng, m);
            for workers in [1usize, 3] {
                assert_eq!(
                    dense_rows(&x, n, k, &w, &b, m, false, workers),
                    ref_dense(&x, n, k, &w, &b, m, false),
                    "dense {n}x{k}x{m} workers {workers}"
                );
                assert_eq!(
                    dense_rows(&x, n, k, &w, &b, m, true, workers),
                    ref_dense(&x, n, k, &w, &b, m, true),
                    "dense+relu {n}x{k}x{m}"
                );
                assert_eq!(
                    matmul_tn(&x, n, k, &g, m, workers),
                    ref_tn(&x, n, k, &g, m),
                    "tn {n}x{k}x{m}"
                );
                assert_eq!(
                    matmul_nt(&g, n, m, &w, k, workers),
                    ref_nt(&g, n, m, &w, k),
                    "nt {n}x{k}x{m}"
                );
            }
            let refsum: Vec<f32> = (0..m)
                .map(|j| (0..n).map(|r| g[r * m + j] as f64).sum::<f64>() as f32)
                .collect();
            assert_eq!(col_sum(&g, n, m), refsum, "colsum {n}x{m}");
        }
    }

    #[test]
    fn deterministic_mode_is_bitwise_worker_invariant() {
        // Include a shape big enough that effective_workers really grants
        // several workers (64·96·80 ≈ 2^19 madds → up to 7).
        let shapes = [(5, 7, 9), (17, 13, 33), (64, 96, 80)];
        for (i, &(n, k, m)) in shapes.iter().enumerate() {
            let mut rng = Rng::new(200 + i as u64);
            let x = normal(&mut rng, n * k);
            let w = normal(&mut rng, k * m);
            let g = normal(&mut rng, n * m);
            let b = normal(&mut rng, m);
            let d1 = dense_rows(&x, n, k, &w, &b, m, true, 1);
            let t1 = matmul_tn(&x, n, k, &g, m, 1);
            let n1 = matmul_nt(&g, n, m, &w, k, 1);
            let f1 = dense_rows_mode(&x, n, k, &w, &b, m, true, 1, true);
            for workers in [2usize, 3, 5, 16] {
                assert_eq!(bits(&d1), bits(&dense_rows(&x, n, k, &w, &b, m, true, workers)));
                assert_eq!(bits(&t1), bits(&matmul_tn(&x, n, k, &g, m, workers)));
                assert_eq!(bits(&n1), bits(&matmul_nt(&g, n, m, &w, k, workers)));
                // The fast mode is also worker-count-invariant (tiles are
                // global), just not bitwise-equal to deterministic mode.
                assert_eq!(
                    bits(&f1),
                    bits(&dense_rows_mode(&x, n, k, &w, &b, m, true, workers, true))
                );
            }
        }
    }

    #[test]
    fn fast_mode_error_is_bounded() {
        let (n, k, m) = (37, 160, 21);
        let mut rng = Rng::new(7);
        let x = normal(&mut rng, n * k);
        let w = normal(&mut rng, k * m);
        let b = normal(&mut rng, m);
        let fast = dense_rows_mode(&x, n, k, &w, &b, m, false, 3, true);
        // Standard dot-product bound: |err| ≤ γ_k · Σ|aᵢbᵢ| with
        // γ_k ≈ k·ε; ×4 margin for the bias add and f32 storage rounding.
        for r in 0..n {
            for j in 0..m {
                let mut exact = b[j] as f64;
                let mut absum = (b[j] as f64).abs();
                for t in 0..k {
                    let p = x[r * k + t] as f64 * w[t * m + j] as f64;
                    exact += p;
                    absum += p.abs();
                }
                let tol = 4.0 * k as f64 * f32::EPSILON as f64 * absum + 1e-6;
                let got = fast[r * m + j] as f64;
                assert!(
                    (got - exact).abs() <= tol,
                    "fast mode error {} exceeds bound {tol} at ({r},{j})",
                    (got - exact).abs()
                );
            }
        }
    }

    #[test]
    fn adaptive_density_paths_agree_with_reference() {
        let mut rng = Rng::new(11);
        let (n, k, m) = (19, 24, 13);
        let w = normal(&mut rng, k * m);
        let b = normal(&mut rng, m);

        // Sparse regime: one-hot rows (density 1/k ≪ threshold takes the
        // zero-skip micro-kernel).
        let mut onehot = vec![0f32; n * k];
        for r in 0..n {
            onehot[r * k + (r * 7) % k] = 1.0;
        }
        let g = normal(&mut rng, n * m);
        assert_eq!(
            dense_rows(&onehot, n, k, &w, &b, m, false, 2),
            ref_dense(&onehot, n, k, &w, &b, m, false),
            "one-hot (sparse path)"
        );
        assert_eq!(
            matmul_tn(&onehot, n, k, &g, m, 2),
            ref_tn(&onehot, n, k, &g, m),
            "one-hot tn (sparse path)"
        );

        // Dense regime: every entry ±1 (ising spins) takes the
        // branch-free micro-kernel.
        let spins: Vec<f32> = (0..n * k)
            .map(|i| if (i * 2654435761) % 3 == 0 { -1.0 } else { 1.0 })
            .collect();
        assert_eq!(
            dense_rows(&spins, n, k, &w, &b, m, false, 2),
            ref_dense(&spins, n, k, &w, &b, m, false),
            "spins (dense path)"
        );
        assert_eq!(
            matmul_tn(&spins, n, k, &g, m, 2),
            ref_tn(&spins, n, k, &g, m),
            "spins tn (dense path)"
        );

        // Mixed regime: one-hot and dense rows interleaved inside the
        // same row tiles — per-tile density sampling must still agree
        // with the reference on both kinds of rows.
        let mut mixed = spins.clone();
        for r in (0..n).step_by(2) {
            for t in 0..k {
                mixed[r * k + t] = if t == r % k { 1.0 } else { 0.0 };
            }
        }
        assert_eq!(
            dense_rows(&mixed, n, k, &w, &b, m, true, 3),
            ref_dense(&mixed, n, k, &w, &b, m, true),
            "mixed tiles"
        );
    }

    #[test]
    fn small_gemms_stay_single_worker() {
        // Pooled-dispatch calibration: 4×64×64 (a small rollout dispatch)
        // is below one PAR_FLOP_QUANTUM and must not wake the pool…
        assert_eq!(effective_workers(8, 4, 4 * 64 * 64), 1);
        // …while a mid-size train-step GEMM (2^20 madds) now gets 16
        // workers where the old spawn-calibrated 2^18 quantum allowed 4.
        assert_eq!(effective_workers(16, 64, 1 << 20), 16);
        // The big-matmul grant the worker-invariance test relies on.
        assert_eq!(effective_workers(4, 256, 256 * 128 * 128), 4);
    }

    #[test]
    fn gemm_dispatch_reuses_pool_threads() {
        let (n, k, m) = (64, 128, 128); // 2^20 madds → genuinely parallel
        let mut rng = Rng::new(21);
        let x = normal(&mut rng, n * k);
        let w = normal(&mut rng, k * m);
        let g = normal(&mut rng, n * m);
        let b = normal(&mut rng, m);
        let _ = dense_rows(&x, n, k, &w, &b, m, true, 4); // warm the pool
        let spawned = spawned_threads();
        for _ in 0..32 {
            let _ = dense_rows(&x, n, k, &w, &b, m, true, 4);
            let _ = matmul_tn(&x, n, k, &g, m, 4);
            let _ = matmul_nt(&g, n, m, &w, k, 4);
        }
        assert_eq!(
            spawned_threads(),
            spawned,
            "GEMM dispatch spawned threads after pool warm-up"
        );
    }
}
