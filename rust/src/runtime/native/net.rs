//! The native policy network front-end ([`NativeNet`]) and the MLP model
//! ([`MlpModel`]): an MLP trunk with forward / backward / flow heads, a
//! hand-written backward pass, and masked log-softmax heads — the
//! pure-Rust counterpart of `python/compile/models/mlp.py` +
//! `kernels/masked_softmax.py`.
//!
//! [`NativeNet`] itself is model-agnostic: it owns a [`NativeConfig`] and
//! a boxed [`Model`] (MLP or transformer, per [`ModelSpec`]) and forwards
//! every call. The MLP's parameter leaves follow the exact artifact
//! init-blob layout (`w0, b0, …, head_fwd_w, head_fwd_b, head_bwd_w,
//! head_bwd_b, head_flow_w, head_flow_b, logZ`), so a [`NativeNet`] can be
//! initialized from the same `Manifest` + blob an XLA artifact uses.
//!
//! All batched matmuls run through the cache-blocked kernels in
//! [`super::gemm`], dispatched on the persistent worker pool. In the
//! default deterministic mode every output element is a fixed-order `f64`
//! accumulation, so results are **bitwise independent of the worker
//! count** (and of how rows are tiled) — the property that keeps the
//! serve subsystem's determinism guarantee intact when a `NativePolicy`
//! backs the slot engine. The serve-only `NativeConfig::fastmath` mode
//! (`GFNX_FASTMATH=1`) switches the forward pass to `[f32; 8]` lane-sum
//! accumulation: still worker-count-invariant and reproducible per seed,
//! but not bitwise-equal to the deterministic mode.

use super::gemm::{col_sum, dense_rows_mode, matmul_nt, matmul_tn};
use super::model::{Model, ModelKind, ModelSpec};
use super::transformer::{self, TransformerModel};
use super::NativeConfig;
use crate::runtime::policy::{masked_uniform_rows, MASKED_NEG};
use crate::util::tensor::TensorF32;
#[cfg(test)]
use super::gemm::{dense_rows, effective_workers};

/// One named parameter leaf (weights `[in, out]`, biases `[out]`, `logZ`
/// `[1]`), stored in the manifest blob layout order.
#[derive(Clone, Debug)]
pub struct Leaf {
    pub name: String,
    pub tensor: TensorF32,
}

impl Leaf {
    pub(crate) fn zeros(name: &str, shape: &[usize]) -> Leaf {
        Leaf { name: name.to_string(), tensor: TensorF32::zeros(shape) }
    }

    pub(crate) fn full(name: &str, shape: &[usize], v: f32) -> Leaf {
        let mut t = TensorF32::zeros(shape);
        t.data_mut().fill(v);
        Leaf { name: name.to_string(), tensor: t }
    }

    pub(crate) fn normal(
        name: &str,
        shape: &[usize],
        rng: &mut crate::util::rng::Rng,
        std: f32,
    ) -> Leaf {
        let mut t = TensorF32::zeros(shape);
        rng.fill_normal_f32(t.data_mut(), std);
        Leaf { name: name.to_string(), tensor: t }
    }
}

/// Per-leaf gradients, index-aligned with [`NativeNet::leaves`].
pub struct Grads {
    pub leaves: Vec<Vec<f32>>,
}

/// Intermediate activations of one forward pass, kept for the backward
/// pass.
pub struct ForwardCache {
    /// Number of rows evaluated.
    pub n: usize,
    /// Post-ReLU trunk activations per layer, each `[n, hidden]` (MLP
    /// model only; empty for the transformer, whose intermediates live in
    /// `tf`).
    pub acts: Vec<Vec<f32>>,
    /// Masked forward log-probabilities `[n, n_actions]`.
    pub fwd_logp: Vec<f32>,
    /// Backward log-probabilities `[n, n_bwd_actions]` (uniform over legal
    /// parents). Empty when the forward pass ran with `with_bwd = false`
    /// (the training path, whose losses read the batch masks directly).
    pub bwd_logp: Vec<f32>,
    /// Log-flow head `[n]`.
    pub flow: Vec<f32>,
    /// Transformer intermediates (attention probabilities, LayerNorm
    /// statistics, residual-stream snapshots); `None` for the MLP.
    pub(crate) tf: Option<Box<transformer::TfCache>>,
}

/// The pure forward part of the native backend: a boxed [`Model`] +
/// config. `Clone + Send`, so a snapshot can be shipped to serve worker
/// threads.
#[derive(Clone, Debug)]
pub struct NativeNet {
    pub cfg: NativeConfig,
    model: Box<dyn Model>,
}

impl NativeNet {
    /// Seed-initialized network for `cfg.model` (He init for the MLP
    /// trunk, the JAX reference's per-leaf scales for the transformer).
    pub fn init(cfg: NativeConfig, seed: u64) -> NativeNet {
        let model: Box<dyn Model> = match cfg.model {
            ModelSpec::Mlp => Box::new(MlpModel::init(&cfg, seed)),
            ModelSpec::Transformer(arch) => {
                Box::new(TransformerModel::init(&cfg, arch, seed))
            }
        };
        NativeNet { cfg, model }
    }

    /// Build from externally loaded leaves (the manifest-blob and
    /// checkpoint paths). The leaf vector must follow `cfg.model`'s
    /// serialization layout.
    pub(super) fn from_leaves(cfg: NativeConfig, leaves: Vec<Leaf>) -> NativeNet {
        let model: Box<dyn Model> = match cfg.model {
            ModelSpec::Mlp => {
                debug_assert_eq!(leaves.len(), Self::n_leaves(cfg.n_layers));
                Box::new(MlpModel { n_layers: cfg.n_layers, leaves })
            }
            ModelSpec::Transformer(arch) => {
                Box::new(TransformerModel::from_leaves(&cfg, arch, leaves))
            }
        };
        NativeNet { cfg, model }
    }

    /// Leaf count of the MLP layout for a given trunk depth.
    pub fn n_leaves(n_layers: usize) -> usize {
        2 * n_layers + 7
    }

    /// Expected `(name, shape)` leaf layout for a config (both models) —
    /// what blob/checkpoint loaders validate against.
    pub fn layout(cfg: &NativeConfig) -> Vec<(String, Vec<usize>)> {
        match cfg.model {
            ModelSpec::Mlp => MlpModel::layout(cfg),
            ModelSpec::Transformer(arch) => transformer::layout(cfg, &arch),
        }
    }

    /// The model's architecture tag.
    pub fn model_kind(&self) -> ModelKind {
        self.model.kind()
    }

    /// Transformer view of the model, when it is one (serve KV path).
    pub(super) fn transformer(&self) -> Option<&TransformerModel> {
        self.model.as_transformer()
    }

    /// Parameter leaves in manifest blob order (read access).
    pub fn leaves(&self) -> &[Leaf] {
        self.model.leaves()
    }

    /// Mutable parameter leaves (optimizer step, checkpoint restore).
    pub fn leaves_mut(&mut self) -> &mut [Leaf] {
        self.model.leaves_mut()
    }

    /// Index of the `logZ` leaf.
    #[inline]
    pub fn idx_logz(&self) -> usize {
        self.model.idx_logz()
    }

    /// Current `logZ` value.
    pub fn log_z(&self) -> f64 {
        let idx = self.idx_logz();
        self.leaves()[idx].tensor.data()[0] as f64
    }

    /// Forward pass over `n` rows of `[n, obs_dim]` observations with
    /// `[n, A]` / `[n, A']` masks, keeping intermediates for backward.
    ///
    /// `with_bwd` controls whether the backward-policy log-probabilities
    /// are produced (the dispatch contract needs them; the training loss
    /// derives its uniform P_B directly from the batch masks, so the
    /// train-step path skips the work and leaves `bwd_logp` empty).
    pub fn forward(
        &self,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
        n: usize,
        with_bwd: bool,
    ) -> ForwardCache {
        self.model.forward(&self.cfg, obs, fwd_mask, bwd_mask, n, with_bwd)
    }

    /// One fixed-shape policy dispatch (`n = cfg.batch` rows).
    pub fn eval(
        &self,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let c = &self.cfg;
        anyhow::ensure!(
            obs.len() == c.batch * c.obs_dim
                && fwd_mask.len() == c.batch * c.n_actions
                && bwd_mask.len() == c.batch * c.n_bwd_actions,
            "native policy: input shape mismatch"
        );
        let _t = crate::span!("native.dispatch");
        let cache = self.forward(obs, fwd_mask, bwd_mask, c.batch, true);
        Ok((cache.fwd_logp, cache.bwd_logp, cache.flow))
    }

    /// Backward pass: upstream gradients on the masked forward
    /// log-probabilities (`[n, A]`) and the flow head (`[n]`) → per-leaf
    /// parameter gradients. The backward-head leaves stay zero under
    /// `uniform_pb` (the head is dead, exactly as in the AOT graph).
    pub fn backward(
        &self,
        obs: &[f32],
        cache: &ForwardCache,
        d_fwd_logp: &[f32],
        d_flow: &[f32],
    ) -> Grads {
        self.model.backward(&self.cfg, obs, cache, d_fwd_logp, d_flow)
    }
}

/// The MLP model: trunk of ReLU dense layers + the three heads, in the
/// artifact init-blob leaf order. The math is byte-for-byte the pre-trait
/// `NativeNet` implementation — every existing golden/bitwise test pins
/// that.
#[derive(Clone, Debug)]
pub(crate) struct MlpModel {
    n_layers: usize,
    leaves: Vec<Leaf>,
}

impl MlpModel {
    /// He-initialized network (mirrors `init_mlp`: He for the trunk,
    /// `1/√h` for the heads, zero biases and logZ).
    pub(crate) fn init(cfg: &NativeConfig, seed: u64) -> MlpModel {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut leaves = Vec::with_capacity(NativeNet::n_leaves(cfg.n_layers));
        let mut fan_in = cfg.obs_dim;
        for i in 0..cfg.n_layers {
            let std = (2.0 / fan_in as f64).sqrt() as f32;
            leaves.push(Leaf::normal(&format!("w{i}"), &[fan_in, cfg.hidden], &mut rng, std));
            leaves.push(Leaf::zeros(&format!("b{i}"), &[cfg.hidden]));
            fan_in = cfg.hidden;
        }
        let h = fan_in;
        let hs = (1.0 / h as f64).sqrt() as f32;
        leaves.push(Leaf::normal("head_fwd_w", &[h, cfg.n_actions], &mut rng, hs));
        leaves.push(Leaf::zeros("head_fwd_b", &[cfg.n_actions]));
        leaves.push(Leaf::normal("head_bwd_w", &[h, cfg.n_bwd_actions], &mut rng, hs));
        leaves.push(Leaf::zeros("head_bwd_b", &[cfg.n_bwd_actions]));
        leaves.push(Leaf::normal("head_flow_w", &[h, 1], &mut rng, hs));
        leaves.push(Leaf::zeros("head_flow_b", &[1]));
        leaves.push(Leaf::zeros("logZ", &[1]));
        MlpModel { n_layers: cfg.n_layers, leaves }
    }

    /// Expected `(name, shape)` layout for a config.
    fn layout(cfg: &NativeConfig) -> Vec<(String, Vec<usize>)> {
        let mut out = Vec::with_capacity(NativeNet::n_leaves(cfg.n_layers));
        let mut fan_in = cfg.obs_dim;
        for i in 0..cfg.n_layers {
            out.push((format!("w{i}"), vec![fan_in, cfg.hidden]));
            out.push((format!("b{i}"), vec![cfg.hidden]));
            fan_in = cfg.hidden;
        }
        let h = fan_in;
        out.push(("head_fwd_w".into(), vec![h, cfg.n_actions]));
        out.push(("head_fwd_b".into(), vec![cfg.n_actions]));
        out.push(("head_bwd_w".into(), vec![h, cfg.n_bwd_actions]));
        out.push(("head_bwd_b".into(), vec![cfg.n_bwd_actions]));
        out.push(("head_flow_w".into(), vec![h, 1]));
        out.push(("head_flow_b".into(), vec![1]));
        out.push(("logZ".into(), vec![1]));
        out
    }

    #[inline]
    fn idx_w(&self, i: usize) -> usize {
        2 * i
    }

    #[inline]
    fn idx_b(&self, i: usize) -> usize {
        2 * i + 1
    }

    #[inline]
    fn idx_head_fwd_w(&self) -> usize {
        2 * self.n_layers
    }

    #[inline]
    fn idx_head_fwd_b(&self) -> usize {
        2 * self.n_layers + 1
    }

    #[inline]
    fn idx_head_flow_w(&self) -> usize {
        2 * self.n_layers + 4
    }

    #[inline]
    fn idx_head_flow_b(&self) -> usize {
        2 * self.n_layers + 5
    }
}

impl Model for MlpModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Mlp
    }

    fn leaves(&self) -> &[Leaf] {
        &self.leaves
    }

    fn leaves_mut(&mut self) -> &mut [Leaf] {
        &mut self.leaves
    }

    #[inline]
    fn idx_logz(&self) -> usize {
        2 * self.n_layers + 6
    }

    fn forward(
        &self,
        cfg: &NativeConfig,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
        n: usize,
        with_bwd: bool,
    ) -> ForwardCache {
        let c = cfg;
        // `NativeConfig::validate` rejects learned-P_B configs on every
        // construction path; a net that reaches here without uniform_pb is
        // a bug, not an input error (the bwd head has no backward pass).
        assert!(c.uniform_pb, "native net supports uniform P_B only");
        debug_assert_eq!(obs.len(), n * c.obs_dim);
        debug_assert_eq!(fwd_mask.len(), n * c.n_actions);
        debug_assert_eq!(bwd_mask.len(), n * c.n_bwd_actions);
        let workers = c.workers.max(1);
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(c.n_layers);
        for i in 0..c.n_layers {
            let (x, k): (&[f32], usize) = if i == 0 {
                (obs, c.obs_dim)
            } else {
                (&acts[i - 1], c.hidden)
            };
            let w = self.leaves[self.idx_w(i)].tensor.data();
            let b = self.leaves[self.idx_b(i)].tensor.data();
            let h = dense_rows_mode(x, n, k, w, b, c.hidden, true, workers, c.fastmath);
            acts.push(h);
        }
        let (h_last, hk): (&[f32], usize) = if c.n_layers == 0 {
            (obs, c.obs_dim)
        } else {
            (&acts[c.n_layers - 1], c.hidden)
        };
        let fwd_logits = dense_rows_mode(
            h_last,
            n,
            hk,
            self.leaves[self.idx_head_fwd_w()].tensor.data(),
            self.leaves[self.idx_head_fwd_b()].tensor.data(),
            c.n_actions,
            false,
            workers,
            c.fastmath,
        );
        let flow = dense_rows_mode(
            h_last,
            n,
            hk,
            self.leaves[self.idx_head_flow_w()].tensor.data(),
            self.leaves[self.idx_head_flow_b()].tensor.data(),
            1,
            false,
            workers,
            c.fastmath,
        );
        let fwd_logp = masked_log_softmax_rows(&fwd_logits, fwd_mask, n, c.n_actions);
        let bwd_logp = if with_bwd {
            let mut out = Vec::new();
            masked_uniform_rows(bwd_mask, n, c.n_bwd_actions, &mut out);
            out
        } else {
            Vec::new()
        };
        ForwardCache { n, acts, fwd_logp, bwd_logp, flow, tf: None }
    }

    fn backward(
        &self,
        cfg: &NativeConfig,
        obs: &[f32],
        cache: &ForwardCache,
        d_fwd_logp: &[f32],
        d_flow: &[f32],
    ) -> Grads {
        let c = cfg;
        let n = cache.n;
        let a = c.n_actions;
        let workers = c.workers.max(1);
        debug_assert_eq!(d_fwd_logp.len(), n * a);
        debug_assert_eq!(d_flow.len(), n);

        let d_logits = masked_log_softmax_backward(&cache.fwd_logp, d_fwd_logp, n, a);

        let mut grads: Vec<Vec<f32>> =
            self.leaves.iter().map(|l| vec![0f32; l.tensor.len()]).collect();
        let (h_last, hk): (&[f32], usize) = if c.n_layers == 0 {
            (obs, c.obs_dim)
        } else {
            (&cache.acts[c.n_layers - 1], c.hidden)
        };

        grads[self.idx_head_fwd_w()] = matmul_tn(h_last, n, hk, &d_logits, a, workers);
        grads[self.idx_head_fwd_b()] = col_sum(&d_logits, n, a);
        grads[self.idx_head_flow_w()] = matmul_tn(h_last, n, hk, d_flow, 1, workers);
        grads[self.idx_head_flow_b()] =
            vec![d_flow.iter().map(|&v| v as f64).sum::<f64>() as f32];

        let mut dh = matmul_nt(
            &d_logits,
            n,
            a,
            self.leaves[self.idx_head_fwd_w()].tensor.data(),
            hk,
            workers,
        );
        let dflow_h = matmul_nt(
            d_flow,
            n,
            1,
            self.leaves[self.idx_head_flow_w()].tensor.data(),
            hk,
            workers,
        );
        for (x, y) in dh.iter_mut().zip(&dflow_h) {
            *x += *y;
        }

        for i in (0..c.n_layers).rev() {
            // ReLU backward: zero where the activation was clamped.
            for (d, &av) in dh.iter_mut().zip(cache.acts[i].iter()) {
                if av <= 0.0 {
                    *d = 0.0;
                }
            }
            let (input, k): (&[f32], usize) = if i == 0 {
                (obs, c.obs_dim)
            } else {
                (&cache.acts[i - 1], c.hidden)
            };
            grads[self.idx_w(i)] = matmul_tn(input, n, k, &dh, c.hidden, workers);
            grads[self.idx_b(i)] = col_sum(&dh, n, c.hidden);
            if i > 0 {
                dh = matmul_nt(
                    &dh,
                    n,
                    c.hidden,
                    self.leaves[self.idx_w(i)].tensor.data(),
                    k,
                    workers,
                );
            }
        }
        Grads { leaves: grads }
    }

    fn box_clone(&self) -> Box<dyn Model> {
        Box::new(self.clone())
    }
}

/// Masked log-softmax backward, shared by every model's head:
/// `dlogit_j = dlogp_j − p_j · Σ dlogp` on legal entries, zero on masked
/// ones. Rows whose upstream gradient is entirely zero are skipped.
pub(crate) fn masked_log_softmax_backward(
    fwd_logp: &[f32],
    d_fwd_logp: &[f32],
    n: usize,
    a: usize,
) -> Vec<f32> {
    let mut d_logits = vec![0f32; n * a];
    for r in 0..n {
        let dl = &d_fwd_logp[r * a..(r + 1) * a];
        let mut s = 0f64;
        for &v in dl {
            s += v as f64;
        }
        if s == 0.0 && dl.iter().all(|&v| v == 0.0) {
            continue;
        }
        let lp = &fwd_logp[r * a..(r + 1) * a];
        let drow = &mut d_logits[r * a..(r + 1) * a];
        for j in 0..a {
            if lp[j] > MASKED_NEG / 2.0 {
                drow[j] = (dl[j] as f64 - (lp[j] as f64).exp() * s) as f32;
            }
        }
    }
    d_logits
}

/// Row-wise masked log-softmax with the kernel's `-1e30` convention:
/// legal entries normalize to probability 1, illegal entries get
/// [`MASKED_NEG`]. Mirrors `masked_log_softmax_ref` in
/// `python/compile/kernels/ref.py`.
pub(crate) fn masked_log_softmax_rows(
    logits: &[f32],
    mask: &[f32],
    n: usize,
    a: usize,
) -> Vec<f32> {
    debug_assert_eq!(logits.len(), n * a);
    debug_assert_eq!(mask.len(), n * a);
    let mut out = vec![0f32; n * a];
    for r in 0..n {
        let lrow = &logits[r * a..(r + 1) * a];
        let mrow = &mask[r * a..(r + 1) * a];
        let mut mx = f64::NEG_INFINITY;
        for j in 0..a {
            if mrow[j] != 0.0 {
                mx = mx.max(lrow[j] as f64);
            }
        }
        let orow = &mut out[r * a..(r + 1) * a];
        if !mx.is_finite() {
            for o in orow.iter_mut() {
                *o = MASKED_NEG;
            }
            continue;
        }
        let mut sum = 0f64;
        for j in 0..a {
            if mrow[j] != 0.0 {
                sum += (lrow[j] as f64 - mx).exp();
            }
        }
        let lse = sum.ln();
        for j in 0..a {
            orow[j] = if mrow[j] != 0.0 {
                (lrow[j] as f64 - mx - lse) as f32
            } else {
                MASKED_NEG
            };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_rows_matches_hand_case() {
        // x = [[1, 2], [0, 3]], w = [[1, 0], [2, 1]], b = [10, 20]
        let x = [1.0, 2.0, 0.0, 3.0];
        let w = [1.0, 0.0, 2.0, 1.0];
        let b = [10.0, 20.0];
        let y = dense_rows(&x, 2, 2, &w, &b, 2, false, 1);
        assert_eq!(y, vec![15.0, 22.0, 16.0, 23.0]);
        // ReLU clamps negatives.
        let y = dense_rows(&x, 2, 2, &w, &[-20.0, -30.0], 2, true, 1);
        assert_eq!(y, vec![0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn matmuls_are_worker_invariant() {
        let mut rng = crate::util::rng::Rng::new(3);
        // Large enough that effective_workers grants several workers and
        // the parallel path really runs.
        let (n, k, m) = (256, 128, 128);
        assert!(effective_workers(4, n, n * k * m) == 4);
        let mut x = vec![0f32; n * k];
        let mut g = vec![0f32; n * m];
        let mut w = vec![0f32; k * m];
        rng.fill_normal_f32(&mut x, 1.0);
        rng.fill_normal_f32(&mut g, 1.0);
        rng.fill_normal_f32(&mut w, 1.0);
        let b = vec![0.5f32; m];
        for workers in [2usize, 4, 16] {
            assert_eq!(dense_rows(&x, n, k, &w, &b, m, false, 1),
                       dense_rows(&x, n, k, &w, &b, m, false, workers));
            assert_eq!(matmul_tn(&x, n, k, &g, m, 1), matmul_tn(&x, n, k, &g, m, workers));
            assert_eq!(matmul_nt(&g, n, m, &w, k, 1), matmul_nt(&g, n, m, &w, k, workers));
        }
    }

    #[test]
    fn matmul_tn_matches_transpose_product() {
        // x [2,3], g [2,2]: out[t][j] = Σ_r x[r][t]·g[r][j]
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let g = [1.0, 0.0, 0.0, 2.0];
        let out = matmul_tn(&x, 2, 3, &g, 2, 1);
        assert_eq!(out, vec![1.0, 8.0, 2.0, 10.0, 3.0, 12.0]);
    }

    #[test]
    fn matmul_nt_matches_hand_case() {
        // g [1,2] · wᵀ with w [3,2]
        let g = [1.0, 2.0];
        let w = [1.0, 0.0, 0.0, 1.0, 2.0, 2.0];
        let out = matmul_nt(&g, 1, 2, &w, 3, 1);
        assert_eq!(out, vec![1.0, 2.0, 6.0]);
    }

    #[test]
    fn masked_log_softmax_normalizes_legal_entries() {
        let logits = [1.0f32, 2.0, 3.0, 0.0, 0.0, 0.0];
        let mask = [1.0f32, 0.0, 1.0, 0.0, 0.0, 0.0];
        let lp = masked_log_softmax_rows(&logits, &mask, 2, 3);
        assert_eq!(lp[1], MASKED_NEG);
        let p: f64 = [(lp[0] as f64).exp(), (lp[2] as f64).exp()].iter().sum();
        assert!((p - 1.0).abs() < 1e-6);
        // Row with no legal entries is fully masked.
        assert!(lp[3..6].iter().all(|&v| v == MASKED_NEG));
    }

    #[test]
    fn mlp_layout_matches_init() {
        let e = crate::envs::hypergrid::HypergridEnv::new(
            2,
            4,
            crate::reward::hypergrid::HypergridReward::standard(4),
        );
        let cfg = NativeConfig::for_env(&e, 2, "tb").with_hidden(8).with_layers(2);
        let net = NativeNet::init(cfg.clone(), 1);
        let layout = NativeNet::layout(&cfg);
        assert_eq!(layout.len(), net.leaves().len());
        for (leaf, (name, shape)) in net.leaves().iter().zip(&layout) {
            assert_eq!(&leaf.name, name);
            assert_eq!(leaf.tensor.shape(), &shape[..]);
        }
    }
}
