//! The native transformer policy: the pre-LN encoder of
//! `python/compile/models/transformer.py` (MHA + FFN blocks on the
//! [`super::gemm`] kernels, learned positional embeddings, pooled heads)
//! with a hand-written backward pass and an optional causal mode whose
//! serve dispatch runs through a per-slot KV cache.
//!
//! The flat `[n, obs_dim]` observation is reshaped to `[seq_len,
//! token_dim]` one-hot-ish tokens, embedded into `embed` dims, offset by a
//! learned positional table, and run through `n_layers` blocks of
//! `x += MHA(LN1(x)); x += FFN(LN2(x))`. Non-causal mode mean-pools over
//! all positions (the JAX reference exactly); causal mode masks attention
//! to `key ≤ query` and pools at the frontier position `p =
//! min(prefix_len, seq_len−1)`, where `prefix_len` counts the leading
//! positions holding a real (non-empty-class) token.
//!
//! Numerics follow the MLP's conventions so every guarantee carries over:
//! f32 storage, fixed-order f64 accumulation in every dense op (the
//! deterministic gemm mode — the transformer ignores
//! `NativeConfig::fastmath`), f64 LayerNorm statistics, f64
//! ascending-key attention scores and softmax with the probabilities cast
//! to f32 before the value mix. Dispatch is bitwise worker-count
//! invariant, and — because the gemm kernels are also row-tiling
//! invariant and the batched and incremental paths share `ln_row` /
//! `attn_row` — the KV-cached decode below is **bitwise equal** to a full
//! causal re-encode.
//!
//! The KV cache ([`KvCaches`]) holds, per serve slot and layer, the K/V
//! rows of every *ingested* (committed) position plus the raw token
//! vectors for prefix matching. One dispatch step re-embeds only the new
//! frontier: positions `lcp..p` are ingested (O(1) amortized per step),
//! the query at `p` is evaluated transiently without being committed, and
//! a prefix mismatch (slot reset, hot-swap, env restart) truncates to the
//! longest bitwise-common prefix. Per token step that is O(T) attention
//! work instead of the O(T²) full re-encode.

use super::gemm::{col_sum, dense_rows_mode, matmul_nt, matmul_tn};
use super::model::{Model, ModelKind, TransformerArch};
use super::net::{
    masked_log_softmax_backward, masked_log_softmax_rows, ForwardCache, Grads, Leaf,
};
use super::NativeConfig;
use crate::runtime::policy::masked_uniform_rows;

/// Leaves per encoder block (qkv, proj, ff1, ff2 weight+bias pairs + two
/// LayerNorm gain/bias pairs).
const LEAVES_PER_LAYER: usize = 12;
/// Leaves before the first block (embed_w, embed_b, pos).
const STEM_LEAVES: usize = 3;
/// Head leaves after the blocks (three weight+bias pairs + logZ).
const HEAD_LEAVES: usize = 7;

/// Expected `(name, shape)` leaf layout — the serialization order used by
/// init, checkpoints, and blob validation.
pub(crate) fn layout(cfg: &NativeConfig, arch: &TransformerArch) -> Vec<(String, Vec<usize>)> {
    let (s, d, e, f) = (arch.seq_len, arch.token_dim, arch.embed, arch.ff_hidden);
    let mut out = Vec::with_capacity(n_leaves(cfg.n_layers));
    out.push(("embed_w".into(), vec![d, e]));
    out.push(("embed_b".into(), vec![e]));
    out.push(("pos".into(), vec![s, e]));
    for l in 0..cfg.n_layers {
        out.push((format!("l{l}_qkv_w"), vec![e, 3 * e]));
        out.push((format!("l{l}_qkv_b"), vec![3 * e]));
        out.push((format!("l{l}_proj_w"), vec![e, e]));
        out.push((format!("l{l}_proj_b"), vec![e]));
        out.push((format!("l{l}_ff1_w"), vec![e, f]));
        out.push((format!("l{l}_ff1_b"), vec![f]));
        out.push((format!("l{l}_ff2_w"), vec![f, e]));
        out.push((format!("l{l}_ff2_b"), vec![e]));
        out.push((format!("l{l}_ln1_g"), vec![e]));
        out.push((format!("l{l}_ln1_b"), vec![e]));
        out.push((format!("l{l}_ln2_g"), vec![e]));
        out.push((format!("l{l}_ln2_b"), vec![e]));
    }
    out.push(("head_fwd_w".into(), vec![e, cfg.n_actions]));
    out.push(("head_fwd_b".into(), vec![cfg.n_actions]));
    out.push(("head_bwd_w".into(), vec![e, cfg.n_bwd_actions]));
    out.push(("head_bwd_b".into(), vec![cfg.n_bwd_actions]));
    out.push(("head_flow_w".into(), vec![e, 1]));
    out.push(("head_flow_b".into(), vec![1]));
    out.push(("logZ".into(), vec![1]));
    out
}

/// Leaf count of the transformer layout for a given block depth.
pub(crate) fn n_leaves(n_layers: usize) -> usize {
    STEM_LEAVES + LEAVES_PER_LAYER * n_layers + HEAD_LEAVES
}

/// Intermediates of one batched transformer forward pass, kept on the
/// [`ForwardCache`] for the backward pass.
#[derive(Debug)]
pub(crate) struct TfCache {
    layers: Vec<TfLayerCache>,
    /// Pooled residual-stream rows `[n, E]` feeding the heads.
    pooled: Vec<f32>,
    /// Causal pool positions per row (empty in non-causal mode).
    pool_pos: Vec<usize>,
}

#[derive(Debug)]
struct TfLayerCache {
    /// Residual stream entering the block `[n·S, E]`.
    x_in: Vec<f32>,
    /// LN1 output `[n·S, E]`.
    h1: Vec<f32>,
    /// LN1 per-row `(mean, rstd)` statistics `[n·S]`.
    st1: Vec<(f64, f64)>,
    /// Fused q/k/v projections `[n·S, 3E]`.
    qkv: Vec<f32>,
    /// Attention probabilities `[n, H, S, S]` (zeros at `key > query` in
    /// causal mode).
    att: Vec<f32>,
    /// Head-concatenated attention mix `[n·S, E]`.
    att_out: Vec<f32>,
    /// Residual stream after the attention residual `[n·S, E]`.
    x_mid: Vec<f32>,
    /// LN2 output `[n·S, E]`.
    h2: Vec<f32>,
    /// LN2 per-row statistics `[n·S]`.
    st2: Vec<(f64, f64)>,
    /// Post-ReLU FFN hidden `[n·S, F]`.
    f1: Vec<f32>,
}

/// Per-slot, per-layer key/value cache for the incremental causal decode.
#[derive(Clone, Debug)]
pub struct KvCaches {
    slots: Vec<KvSlot>,
}

#[derive(Clone, Debug)]
struct KvSlot {
    /// Number of ingested (committed) positions.
    len: usize,
    /// Raw token vectors of the ingested positions `[len, D]`, compared
    /// bitwise against incoming observations to find the reusable prefix.
    tokens: Vec<f32>,
    /// Per layer: cached K rows `[len, E]`.
    k: Vec<Vec<f32>>,
    /// Per layer: cached V rows `[len, E]`.
    v: Vec<Vec<f32>>,
}

impl KvCaches {
    pub fn new(batch: usize, n_layers: usize) -> KvCaches {
        KvCaches {
            slots: (0..batch)
                .map(|_| KvSlot {
                    len: 0,
                    tokens: Vec::new(),
                    k: vec![Vec::new(); n_layers],
                    v: vec![Vec::new(); n_layers],
                })
                .collect(),
        }
    }
}

/// The transformer model. Like [`super::net::MlpModel`], shared
/// shape/hyperparameter state stays on the [`NativeConfig`] (`n_layers`,
/// head widths); everything transformer-specific lives in the
/// [`TransformerArch`].
#[derive(Clone, Debug)]
pub struct TransformerModel {
    arch: TransformerArch,
    n_layers: usize,
    leaves: Vec<Leaf>,
}

impl TransformerModel {
    /// Seed-initialized model with the JAX reference's per-leaf scales:
    /// `1/√fan_in` normals for projections (`2/fan_in` for the ReLU ff1),
    /// 0.02 for the positional table, ones for LayerNorm gains, zeros for
    /// biases and logZ.
    pub(crate) fn init(cfg: &NativeConfig, arch: TransformerArch, seed: u64) -> TransformerModel {
        let mut rng = crate::util::rng::Rng::new(seed);
        let (e, f) = (arch.embed, arch.ff_hidden);
        let std_of = |name: &str| -> Option<f32> {
            let fan_inv = match name {
                "embed_w" => 1.0 / arch.token_dim as f64,
                "pos" => return Some(0.02),
                n if n.ends_with("_ff1_w") => 2.0 / e as f64,
                n if n.ends_with("_ff2_w") => 1.0 / f as f64,
                n if n.ends_with("_w") => 1.0 / e as f64,
                _ => return None,
            };
            Some(fan_inv.sqrt() as f32)
        };
        let leaves = layout(cfg, &arch)
            .into_iter()
            .map(|(name, shape)| {
                if let Some(std) = std_of(&name) {
                    Leaf::normal(&name, &shape, &mut rng, std)
                } else if name.ends_with("_g") {
                    Leaf::full(&name, &shape, 1.0)
                } else {
                    Leaf::zeros(&name, &shape)
                }
            })
            .collect();
        TransformerModel { arch, n_layers: cfg.n_layers, leaves }
    }

    /// Build from externally loaded leaves (checkpoint restore). The
    /// loader validates names/shapes against [`layout`] before calling.
    pub(crate) fn from_leaves(
        cfg: &NativeConfig,
        arch: TransformerArch,
        leaves: Vec<Leaf>,
    ) -> TransformerModel {
        assert_eq!(
            leaves.len(),
            n_leaves(cfg.n_layers),
            "transformer leaf count mismatch"
        );
        TransformerModel { arch, n_layers: cfg.n_layers, leaves }
    }

    pub(crate) fn arch(&self) -> &TransformerArch {
        &self.arch
    }

    // Leaf indices in layout order.
    #[inline]
    fn idx_embed_w(&self) -> usize {
        0
    }
    #[inline]
    fn idx_embed_b(&self) -> usize {
        1
    }
    #[inline]
    fn idx_pos(&self) -> usize {
        2
    }
    /// Base index of block `l`'s 12 leaves (qkv_w, qkv_b, proj_w, proj_b,
    /// ff1_w, ff1_b, ff2_w, ff2_b, ln1_g, ln1_b, ln2_g, ln2_b).
    #[inline]
    fn idx_layer(&self, l: usize) -> usize {
        STEM_LEAVES + LEAVES_PER_LAYER * l
    }
    #[inline]
    fn idx_heads(&self) -> usize {
        STEM_LEAVES + LEAVES_PER_LAYER * self.n_layers
    }

    #[inline]
    fn leaf(&self, idx: usize) -> &[f32] {
        self.leaves[idx].tensor.data()
    }

    /// `prefix_len` of one `[S·D]` observation row: the number of leading
    /// positions holding a real token (any nonzero entry outside the
    /// empty-class column `D−1`).
    fn prefix_len(&self, obs_row: &[f32]) -> usize {
        let (s_len, d) = (self.arch.seq_len, self.arch.token_dim);
        for s in 0..s_len {
            let tok = &obs_row[s * d..(s + 1) * d];
            if !tok[..d - 1].iter().any(|&x| x != 0.0) {
                return s;
            }
        }
        s_len
    }

    /// Causal pool position for one observation row.
    #[inline]
    fn pool_position(&self, obs_row: &[f32]) -> usize {
        self.prefix_len(obs_row).min(self.arch.seq_len - 1)
    }

    /// One position through all blocks using the slot's cached K/V;
    /// mirrors the batched forward row-for-row (same `ln_row`/`attn_row`
    /// helpers, same gemm kernels), which is what makes incremental decode
    /// bitwise-equal to full re-encode. `commit` appends this position's
    /// K/V rows to the cache (ingest); the query step leaves the cache
    /// untouched. Returns the final residual-stream row `[E]`.
    fn kv_step(&self, token: &[f32], pos_idx: usize, slot: &mut KvSlot, commit: bool) -> Vec<f32> {
        let a = &self.arch;
        let (d, e) = (a.token_dim, a.embed);
        let hd = e / a.n_heads;
        let mut x = dense_rows_mode(
            token,
            1,
            d,
            self.leaf(self.idx_embed_w()),
            self.leaf(self.idx_embed_b()),
            e,
            false,
            1,
            false,
        );
        let pos = self.leaf(self.idx_pos());
        for i in 0..e {
            x[i] += pos[pos_idx * e + i];
        }
        let mut h = vec![0f32; e];
        let mut att_tmp = vec![0f32; a.seq_len];
        let mut head_out = vec![0f32; hd];
        for l in 0..self.n_layers {
            let lb = self.idx_layer(l);
            ln_row(&x, self.leaf(lb + 8), self.leaf(lb + 9), &mut h);
            let qkv = dense_rows_mode(
                &h,
                1,
                e,
                self.leaf(lb),
                self.leaf(lb + 1),
                3 * e,
                false,
                1,
                false,
            );
            let n_keys = slot.len + 1;
            // Contiguous [n_keys, E] K/V scratch: cached rows + this
            // position's own k/v (attended to but only cached on commit).
            let mut keys = Vec::with_capacity(n_keys * e);
            keys.extend_from_slice(&slot.k[l]);
            keys.extend_from_slice(&qkv[e..2 * e]);
            let mut vals = Vec::with_capacity(n_keys * e);
            vals.extend_from_slice(&slot.v[l]);
            vals.extend_from_slice(&qkv[2 * e..3 * e]);
            let mut att_out = vec![0f32; e];
            for hh in 0..a.n_heads {
                attn_row(
                    &qkv[hh * hd..(hh + 1) * hd],
                    hd,
                    &keys,
                    e,
                    hh * hd,
                    &vals,
                    e,
                    hh * hd,
                    n_keys,
                    &mut att_tmp[..n_keys],
                    &mut head_out,
                );
                att_out[hh * hd..(hh + 1) * hd].copy_from_slice(&head_out);
            }
            let proj = dense_rows_mode(
                &att_out,
                1,
                e,
                self.leaf(lb + 2),
                self.leaf(lb + 3),
                e,
                false,
                1,
                false,
            );
            for i in 0..e {
                x[i] += proj[i];
            }
            ln_row(&x, self.leaf(lb + 10), self.leaf(lb + 11), &mut h);
            let f1 = dense_rows_mode(
                &h,
                1,
                e,
                self.leaf(lb + 4),
                self.leaf(lb + 5),
                a.ff_hidden,
                true,
                1,
                false,
            );
            let f2 = dense_rows_mode(
                &f1,
                1,
                a.ff_hidden,
                self.leaf(lb + 6),
                self.leaf(lb + 7),
                e,
                false,
                1,
                false,
            );
            for i in 0..e {
                x[i] += f2[i];
            }
            if commit {
                slot.k[l].extend_from_slice(&keys[slot.len * e..]);
                slot.v[l].extend_from_slice(&vals[slot.len * e..]);
            }
        }
        if commit {
            slot.tokens.extend_from_slice(token);
            slot.len += 1;
        }
        x
    }

    /// Incremental causal dispatch over a full batch: per slot, reuse the
    /// bitwise-matching cached prefix, ingest the new positions, evaluate
    /// the frontier query, then run the heads. Output contract is
    /// identical to the batched `eval` path.
    pub(crate) fn eval_kv(
        &self,
        cfg: &NativeConfig,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
        kv: &mut KvCaches,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let c = cfg;
        let a = &self.arch;
        anyhow::ensure!(a.causal, "KV-cached decode requires causal mode");
        anyhow::ensure!(
            obs.len() == c.batch * c.obs_dim
                && fwd_mask.len() == c.batch * c.n_actions
                && bwd_mask.len() == c.batch * c.n_bwd_actions,
            "native policy: input shape mismatch"
        );
        anyhow::ensure!(
            kv.slots.len() == c.batch,
            "KV cache sized for {} slots, batch is {}",
            kv.slots.len(),
            c.batch
        );
        let _t = crate::span!("native.dispatch");
        let (d, e) = (a.token_dim, a.embed);
        let hb = self.idx_heads();
        let mut fwd_logits = vec![0f32; c.batch * c.n_actions];
        let mut flow = vec![0f32; c.batch];
        let mut ingested = 0usize;
        for r in 0..c.batch {
            let obs_row = &obs[r * c.obs_dim..(r + 1) * c.obs_dim];
            let p = self.pool_position(obs_row);
            let slot = &mut kv.slots[r];
            // Longest bitwise-common prefix of the cached tokens and this
            // observation, capped at the ingest frontier.
            let mut lcp = 0;
            while lcp < slot.len.min(p) {
                let cached = &slot.tokens[lcp * d..(lcp + 1) * d];
                let fresh = &obs_row[lcp * d..(lcp + 1) * d];
                if !cached
                    .iter()
                    .zip(fresh)
                    .all(|(x, y)| x.to_bits() == y.to_bits())
                {
                    break;
                }
                lcp += 1;
            }
            if lcp < slot.len {
                slot.len = lcp;
                slot.tokens.truncate(lcp * d);
                for l in 0..self.n_layers {
                    slot.k[l].truncate(lcp * e);
                    slot.v[l].truncate(lcp * e);
                }
            }
            for j in slot.len..p {
                let tok: Vec<f32> = obs_row[j * d..(j + 1) * d].to_vec();
                self.kv_step(&tok, j, slot, true);
                ingested += 1;
            }
            let x_q = self.kv_step(&obs_row[p * d..(p + 1) * d], p, slot, false);
            let frow = dense_rows_mode(
                &x_q,
                1,
                e,
                self.leaf(hb),
                self.leaf(hb + 1),
                c.n_actions,
                false,
                1,
                false,
            );
            fwd_logits[r * c.n_actions..(r + 1) * c.n_actions].copy_from_slice(&frow);
            flow[r] = dense_rows_mode(
                &x_q,
                1,
                e,
                self.leaf(hb + 4),
                self.leaf(hb + 5),
                1,
                false,
                1,
                false,
            )[0];
        }
        crate::count!("native.kv_ingest", ingested);
        let fwd_logp = masked_log_softmax_rows(&fwd_logits, fwd_mask, c.batch, c.n_actions);
        let mut bwd_logp = Vec::new();
        masked_uniform_rows(bwd_mask, c.batch, c.n_bwd_actions, &mut bwd_logp);
        Ok((fwd_logp, bwd_logp, flow))
    }
}

impl Model for TransformerModel {
    fn kind(&self) -> ModelKind {
        ModelKind::Transformer
    }

    fn leaves(&self) -> &[Leaf] {
        &self.leaves
    }

    fn leaves_mut(&mut self) -> &mut [Leaf] {
        &mut self.leaves
    }

    #[inline]
    fn idx_logz(&self) -> usize {
        self.idx_heads() + 6
    }

    fn forward(
        &self,
        cfg: &NativeConfig,
        obs: &[f32],
        fwd_mask: &[f32],
        bwd_mask: &[f32],
        n: usize,
        with_bwd: bool,
    ) -> ForwardCache {
        let c = cfg;
        assert!(c.uniform_pb, "native net supports uniform P_B only");
        let a = &self.arch;
        let (s_len, d, e, f) = (a.seq_len, a.token_dim, a.embed, a.ff_hidden);
        let hd = e / a.n_heads;
        debug_assert_eq!(obs.len(), n * c.obs_dim);
        debug_assert_eq!(c.obs_dim, s_len * d);
        debug_assert_eq!(fwd_mask.len(), n * c.n_actions);
        debug_assert_eq!(bwd_mask.len(), n * c.n_bwd_actions);
        let workers = c.workers.max(1);
        let ns = n * s_len;

        // Embed every position, then add the positional table (plain f32
        // adds, matching the incremental path).
        let mut x = dense_rows_mode(
            obs,
            ns,
            d,
            self.leaf(self.idx_embed_w()),
            self.leaf(self.idx_embed_b()),
            e,
            false,
            workers,
            false,
        );
        let pos = self.leaf(self.idx_pos());
        for r in 0..n {
            for s in 0..s_len {
                let row = &mut x[(r * s_len + s) * e..(r * s_len + s + 1) * e];
                for i in 0..e {
                    row[i] += pos[s * e + i];
                }
            }
        }

        let mut layers = Vec::with_capacity(self.n_layers);
        let mut att_tmp_head = vec![0f32; hd];
        for l in 0..self.n_layers {
            let lb = self.idx_layer(l);
            let x_in = x.clone();
            let mut h1 = vec![0f32; ns * e];
            let mut st1 = vec![(0f64, 0f64); ns];
            for rs in 0..ns {
                st1[rs] = ln_row(
                    &x[rs * e..(rs + 1) * e],
                    self.leaf(lb + 8),
                    self.leaf(lb + 9),
                    &mut h1[rs * e..(rs + 1) * e],
                );
            }
            let qkv = dense_rows_mode(
                &h1,
                ns,
                e,
                self.leaf(lb),
                self.leaf(lb + 1),
                3 * e,
                false,
                workers,
                false,
            );
            let mut att = vec![0f32; n * a.n_heads * s_len * s_len];
            let mut att_out = vec![0f32; ns * e];
            for r in 0..n {
                let buf = &qkv[r * s_len * 3 * e..(r + 1) * s_len * 3 * e];
                for hh in 0..a.n_heads {
                    for s in 0..s_len {
                        let kk = if a.causal { s + 1 } else { s_len };
                        let att_row = &mut att[((r * a.n_heads + hh) * s_len + s) * s_len..]
                            [..kk];
                        attn_row(
                            &buf[s * 3 * e + hh * hd..s * 3 * e + (hh + 1) * hd],
                            hd,
                            buf,
                            3 * e,
                            e + hh * hd,
                            buf,
                            3 * e,
                            2 * e + hh * hd,
                            kk,
                            att_row,
                            &mut att_tmp_head,
                        );
                        att_out[(r * s_len + s) * e + hh * hd..(r * s_len + s) * e
                            + (hh + 1) * hd]
                            .copy_from_slice(&att_tmp_head);
                    }
                }
            }
            let proj = dense_rows_mode(
                &att_out,
                ns,
                e,
                self.leaf(lb + 2),
                self.leaf(lb + 3),
                e,
                false,
                workers,
                false,
            );
            for (xi, pi) in x.iter_mut().zip(&proj) {
                *xi += *pi;
            }
            let x_mid = x.clone();
            let mut h2 = vec![0f32; ns * e];
            let mut st2 = vec![(0f64, 0f64); ns];
            for rs in 0..ns {
                st2[rs] = ln_row(
                    &x[rs * e..(rs + 1) * e],
                    self.leaf(lb + 10),
                    self.leaf(lb + 11),
                    &mut h2[rs * e..(rs + 1) * e],
                );
            }
            let f1 = dense_rows_mode(
                &h2,
                ns,
                e,
                self.leaf(lb + 4),
                self.leaf(lb + 5),
                f,
                true,
                workers,
                false,
            );
            let f2 = dense_rows_mode(
                &f1,
                ns,
                f,
                self.leaf(lb + 6),
                self.leaf(lb + 7),
                e,
                false,
                workers,
                false,
            );
            for (xi, fi) in x.iter_mut().zip(&f2) {
                *xi += *fi;
            }
            layers.push(TfLayerCache {
                x_in,
                h1,
                st1,
                qkv,
                att,
                att_out,
                x_mid,
                h2,
                st2,
                f1,
            });
        }

        // Pool: frontier row in causal mode, f64 ascending mean otherwise.
        let mut pooled = vec![0f32; n * e];
        let mut pool_pos = Vec::new();
        if a.causal {
            pool_pos.reserve(n);
            for r in 0..n {
                let p = self.pool_position(&obs[r * c.obs_dim..(r + 1) * c.obs_dim]);
                pool_pos.push(p);
                pooled[r * e..(r + 1) * e]
                    .copy_from_slice(&x[(r * s_len + p) * e..(r * s_len + p + 1) * e]);
            }
        } else {
            for r in 0..n {
                for i in 0..e {
                    let mut acc = 0f64;
                    for s in 0..s_len {
                        acc += x[(r * s_len + s) * e + i] as f64;
                    }
                    pooled[r * e + i] = (acc / s_len as f64) as f32;
                }
            }
        }

        let hb = self.idx_heads();
        let fwd_logits = dense_rows_mode(
            &pooled,
            n,
            e,
            self.leaf(hb),
            self.leaf(hb + 1),
            c.n_actions,
            false,
            workers,
            false,
        );
        let flow = dense_rows_mode(
            &pooled,
            n,
            e,
            self.leaf(hb + 4),
            self.leaf(hb + 5),
            1,
            false,
            workers,
            false,
        );
        let fwd_logp = masked_log_softmax_rows(&fwd_logits, fwd_mask, n, c.n_actions);
        let bwd_logp = if with_bwd {
            let mut out = Vec::new();
            masked_uniform_rows(bwd_mask, n, c.n_bwd_actions, &mut out);
            out
        } else {
            Vec::new()
        };
        ForwardCache {
            n,
            acts: Vec::new(),
            fwd_logp,
            bwd_logp,
            flow,
            tf: Some(Box::new(TfCache { layers, pooled, pool_pos })),
        }
    }

    fn backward(
        &self,
        cfg: &NativeConfig,
        obs: &[f32],
        cache: &ForwardCache,
        d_fwd_logp: &[f32],
        d_flow: &[f32],
    ) -> Grads {
        let c = cfg;
        let a = &self.arch;
        let (s_len, d, e, f) = (a.seq_len, a.token_dim, a.embed, a.ff_hidden);
        let hd = e / a.n_heads;
        let n = cache.n;
        let na = c.n_actions;
        let workers = c.workers.max(1);
        let ns = n * s_len;
        let tf = cache
            .tf
            .as_ref()
            .expect("transformer backward requires a transformer forward cache");
        debug_assert_eq!(d_fwd_logp.len(), n * na);
        debug_assert_eq!(d_flow.len(), n);

        let d_logits = masked_log_softmax_backward(&cache.fwd_logp, d_fwd_logp, n, na);

        let mut grads: Vec<Vec<f32>> =
            self.leaves.iter().map(|l| vec![0f32; l.tensor.len()]).collect();
        let hb = self.idx_heads();

        grads[hb] = matmul_tn(&tf.pooled, n, e, &d_logits, na, workers);
        grads[hb + 1] = col_sum(&d_logits, n, na);
        grads[hb + 4] = matmul_tn(&tf.pooled, n, e, d_flow, 1, workers);
        grads[hb + 5] = vec![d_flow.iter().map(|&v| v as f64).sum::<f64>() as f32];

        let mut d_pooled = matmul_nt(&d_logits, n, na, self.leaf(hb), e, workers);
        let d_pooled_flow = matmul_nt(d_flow, n, 1, self.leaf(hb + 4), e, workers);
        for (x, y) in d_pooled.iter_mut().zip(&d_pooled_flow) {
            *x += *y;
        }

        // Pool backward: scatter to the frontier row (causal) or broadcast
        // the f32 mean weight (non-causal).
        let mut dx = vec![0f32; ns * e];
        if a.causal {
            for r in 0..n {
                let p = tf.pool_pos[r];
                dx[(r * s_len + p) * e..(r * s_len + p + 1) * e]
                    .copy_from_slice(&d_pooled[r * e..(r + 1) * e]);
            }
        } else {
            let inv = 1.0f32 / s_len as f32;
            for r in 0..n {
                for s in 0..s_len {
                    for i in 0..e {
                        dx[(r * s_len + s) * e + i] = d_pooled[r * e + i] * inv;
                    }
                }
            }
        }

        let scale = 1.0 / (hd as f64).sqrt();
        for l in (0..self.n_layers).rev() {
            let lb = self.idx_layer(l);
            let lc = &tf.layers[l];

            // FFN backward.
            grads[lb + 6] = matmul_tn(&lc.f1, ns, f, &dx, e, workers);
            grads[lb + 7] = col_sum(&dx, ns, e);
            let mut d_f1 = matmul_nt(&dx, ns, e, self.leaf(lb + 6), f, workers);
            for (dv, &fv) in d_f1.iter_mut().zip(&lc.f1) {
                if fv <= 0.0 {
                    *dv = 0.0;
                }
            }
            grads[lb + 4] = matmul_tn(&lc.h2, ns, e, &d_f1, f, workers);
            grads[lb + 5] = col_sum(&d_f1, ns, f);
            let d_h2 = matmul_nt(&d_f1, ns, f, self.leaf(lb + 4), e, workers);

            // LN2 backward into the post-attention residual stream.
            let mut dx_mid = dx.clone();
            let mut dg2 = vec![0f64; e];
            let mut db2 = vec![0f64; e];
            for rs in 0..ns {
                ln_backward_row(
                    &d_h2[rs * e..(rs + 1) * e],
                    &lc.x_mid[rs * e..(rs + 1) * e],
                    lc.st2[rs],
                    self.leaf(lb + 10),
                    &mut dx_mid[rs * e..(rs + 1) * e],
                    &mut dg2,
                    &mut db2,
                );
            }
            for i in 0..e {
                grads[lb + 10][i] = dg2[i] as f32;
                grads[lb + 11][i] = db2[i] as f32;
            }

            // Attention backward.
            grads[lb + 2] = matmul_tn(&lc.att_out, ns, e, &dx_mid, e, workers);
            grads[lb + 3] = col_sum(&dx_mid, ns, e);
            let d_att_out = matmul_nt(&dx_mid, ns, e, self.leaf(lb + 2), e, workers);
            let mut d_qkv = vec![0f32; ns * 3 * e];
            // f64 per-(row, head) scratch; causal zeros in the cached
            // probabilities make the full-S loops correct in both modes.
            let mut d_att = vec![0f64; s_len * s_len];
            let mut d_score = vec![0f64; s_len * s_len];
            for r in 0..n {
                let qkv = &lc.qkv[r * s_len * 3 * e..(r + 1) * s_len * 3 * e];
                for hh in 0..a.n_heads {
                    let att =
                        &lc.att[((r * a.n_heads + hh) * s_len) * s_len..][..s_len * s_len];
                    let q_at = |s: usize, i: usize| qkv[s * 3 * e + hh * hd + i] as f64;
                    let k_at = |s: usize, i: usize| qkv[s * 3 * e + e + hh * hd + i] as f64;
                    let v_at =
                        |s: usize, i: usize| qkv[s * 3 * e + 2 * e + hh * hd + i] as f64;
                    let dout_at =
                        |s: usize, i: usize| d_att_out[(r * s_len + s) * e + hh * hd + i] as f64;
                    // d_v[k] = Σ_q att[q][k] · d_out[q]
                    for k in 0..s_len {
                        for i in 0..hd {
                            let mut acc = 0f64;
                            for q in 0..s_len {
                                acc += att[q * s_len + k] as f64 * dout_at(q, i);
                            }
                            d_qkv[(r * s_len + k) * 3 * e + 2 * e + hh * hd + i] = acc as f32;
                        }
                    }
                    // d_att[q][k] = d_out[q] · v[k]
                    for q in 0..s_len {
                        for k in 0..s_len {
                            let mut acc = 0f64;
                            for i in 0..hd {
                                acc += dout_at(q, i) * v_at(k, i);
                            }
                            d_att[q * s_len + k] = acc;
                        }
                    }
                    // Softmax backward: d_score = att ⊙ (d_att − Σ_k d_att ⊙ att)
                    for q in 0..s_len {
                        let mut rowsum = 0f64;
                        for k in 0..s_len {
                            rowsum += d_att[q * s_len + k] * att[q * s_len + k] as f64;
                        }
                        for k in 0..s_len {
                            d_score[q * s_len + k] =
                                att[q * s_len + k] as f64 * (d_att[q * s_len + k] - rowsum);
                        }
                    }
                    // d_q[q] = Σ_k d_score[q][k] · k[k] · scale
                    for q in 0..s_len {
                        for i in 0..hd {
                            let mut acc = 0f64;
                            for k in 0..s_len {
                                acc += d_score[q * s_len + k] * k_at(k, i);
                            }
                            d_qkv[(r * s_len + q) * 3 * e + hh * hd + i] =
                                (acc * scale) as f32;
                        }
                    }
                    // d_k[k] = Σ_q d_score[q][k] · q[q] · scale
                    for k in 0..s_len {
                        for i in 0..hd {
                            let mut acc = 0f64;
                            for q in 0..s_len {
                                acc += d_score[q * s_len + k] * q_at(q, i);
                            }
                            d_qkv[(r * s_len + k) * 3 * e + e + hh * hd + i] =
                                (acc * scale) as f32;
                        }
                    }
                }
            }
            grads[lb] = matmul_tn(&lc.h1, ns, e, &d_qkv, 3 * e, workers);
            grads[lb + 1] = col_sum(&d_qkv, ns, 3 * e);
            let d_h1 = matmul_nt(&d_qkv, ns, 3 * e, self.leaf(lb), e, workers);

            // LN1 backward into the block's input stream.
            dx = dx_mid;
            let mut dg1 = vec![0f64; e];
            let mut db1 = vec![0f64; e];
            for rs in 0..ns {
                ln_backward_row(
                    &d_h1[rs * e..(rs + 1) * e],
                    &lc.x_in[rs * e..(rs + 1) * e],
                    lc.st1[rs],
                    self.leaf(lb + 8),
                    &mut dx[rs * e..(rs + 1) * e],
                    &mut dg1,
                    &mut db1,
                );
            }
            for i in 0..e {
                grads[lb + 8][i] = dg1[i] as f32;
                grads[lb + 9][i] = db1[i] as f32;
            }
        }

        // Stem backward: positional table (f64 column sums over rows),
        // then the embedding projection.
        let mut g_pos = vec![0f64; s_len * e];
        for r in 0..n {
            for s in 0..s_len {
                for i in 0..e {
                    g_pos[s * e + i] += dx[(r * s_len + s) * e + i] as f64;
                }
            }
        }
        for (gp, &v) in grads[self.idx_pos()].iter_mut().zip(&g_pos) {
            *gp = v as f32;
        }
        grads[self.idx_embed_w()] = matmul_tn(obs, ns, d, &dx, e, workers);
        grads[self.idx_embed_b()] = col_sum(&dx, ns, e);

        Grads { leaves: grads }
    }

    fn box_clone(&self) -> Box<dyn Model> {
        Box::new(self.clone())
    }

    fn as_transformer(&self) -> Option<&TransformerModel> {
        Some(self)
    }
}

/// LayerNorm one row: f64 mean / biased variance / rstd (eps 1e-5),
/// `y = x̂·g + b` cast to f32. Returns `(mean, rstd)` for backward.
fn ln_row(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) -> (f64, f64) {
    let e = x.len();
    let mut mu = 0f64;
    for &v in x {
        mu += v as f64;
    }
    mu /= e as f64;
    let mut var = 0f64;
    for &v in x {
        let dv = v as f64 - mu;
        var += dv * dv;
    }
    var /= e as f64;
    let rstd = 1.0 / (var + 1e-5).sqrt();
    for i in 0..e {
        out[i] = ((x[i] as f64 - mu) * rstd * g[i] as f64 + b[i] as f64) as f32;
    }
    (mu, rstd)
}

/// LayerNorm backward one row:
/// `dx = rstd·(dx̂ − mean(dx̂) − x̂·mean(dx̂·x̂))`, accumulated onto
/// `dx_acc` as f32 (matching the residual add); `dg += dy·x̂`, `db += dy`
/// stay in f64 across the batch.
fn ln_backward_row(
    dy: &[f32],
    x: &[f32],
    (mu, rstd): (f64, f64),
    g: &[f32],
    dx_acc: &mut [f32],
    dg: &mut [f64],
    db: &mut [f64],
) {
    let e = x.len();
    let mut m1 = 0f64;
    let mut m2 = 0f64;
    for i in 0..e {
        let xhat = (x[i] as f64 - mu) * rstd;
        let dyf = dy[i] as f64;
        dg[i] += dyf * xhat;
        db[i] += dyf;
        let dxhat = dyf * g[i] as f64;
        m1 += dxhat;
        m2 += dxhat * xhat;
    }
    m1 /= e as f64;
    m2 /= e as f64;
    for i in 0..e {
        let xhat = (x[i] as f64 - mu) * rstd;
        let dxhat = dy[i] as f64 * g[i] as f64;
        dx_acc[i] += (rstd * (dxhat - m1 - xhat * m2)) as f32;
    }
}

/// One (query, head) attention row over `n_keys` keys: f64 ascending-key
/// score dots (· 1/√hd), f64 softmax with probabilities cast to f32 into
/// `att`, then the value mix accumulated in f64 ascending-key order.
///
/// `keys`/`vals` are row-major buffers whose key `k` head-slice starts at
/// `k·stride + off` — the batched path points both at the fused `[S, 3E]`
/// qkv block, the KV path at contiguous `[n_keys, E]` scratch. Reads and
/// arithmetic order are identical either way, which is what the bitwise
/// KV-equals-full guarantee rests on.
#[allow(clippy::too_many_arguments)]
fn attn_row(
    q: &[f32],
    hd: usize,
    keys: &[f32],
    k_stride: usize,
    k_off: usize,
    vals: &[f32],
    v_stride: usize,
    v_off: usize,
    n_keys: usize,
    att: &mut [f32],
    out: &mut [f32],
) {
    debug_assert_eq!(q.len(), hd);
    debug_assert!(att.len() >= n_keys && out.len() == hd);
    let scale = 1.0 / (hd as f64).sqrt();
    let mut mx = f64::NEG_INFINITY;
    let mut scores = [0f64; 64];
    let scores = if n_keys <= 64 {
        &mut scores[..n_keys]
    } else {
        // Fallback for very long sequences; heap-allocating per call.
        return attn_row_long(
            q, hd, keys, k_stride, k_off, vals, v_stride, v_off, n_keys, att, out,
        );
    };
    for k in 0..n_keys {
        let kb = &keys[k * k_stride + k_off..k * k_stride + k_off + hd];
        let mut acc = 0f64;
        for i in 0..hd {
            acc += q[i] as f64 * kb[i] as f64;
        }
        let sc = acc * scale;
        scores[k] = sc;
        if sc > mx {
            mx = sc;
        }
    }
    let mut sum = 0f64;
    for k in 0..n_keys {
        scores[k] = (scores[k] - mx).exp();
        sum += scores[k];
    }
    for k in 0..n_keys {
        att[k] = (scores[k] / sum) as f32;
    }
    for i in 0..hd {
        let mut acc = 0f64;
        for k in 0..n_keys {
            acc += att[k] as f64 * vals[k * v_stride + v_off + i] as f64;
        }
        out[i] = acc as f32;
    }
}

#[allow(clippy::too_many_arguments)]
fn attn_row_long(
    q: &[f32],
    hd: usize,
    keys: &[f32],
    k_stride: usize,
    k_off: usize,
    vals: &[f32],
    v_stride: usize,
    v_off: usize,
    n_keys: usize,
    att: &mut [f32],
    out: &mut [f32],
) {
    let scale = 1.0 / (hd as f64).sqrt();
    let mut scores = vec![0f64; n_keys];
    let mut mx = f64::NEG_INFINITY;
    for k in 0..n_keys {
        let kb = &keys[k * k_stride + k_off..k * k_stride + k_off + hd];
        let mut acc = 0f64;
        for i in 0..hd {
            acc += q[i] as f64 * kb[i] as f64;
        }
        scores[k] = acc * scale;
        if scores[k] > mx {
            mx = scores[k];
        }
    }
    let mut sum = 0f64;
    for k in 0..n_keys {
        scores[k] = (scores[k] - mx).exp();
        sum += scores[k];
    }
    for k in 0..n_keys {
        att[k] = (scores[k] / sum) as f32;
    }
    for i in 0..hd {
        let mut acc = 0f64;
        for k in 0..n_keys {
            acc += att[k] as f64 * vals[k * v_stride + v_off + i] as f64;
        }
        out[i] = acc as f32;
    }
}
